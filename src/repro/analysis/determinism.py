"""Determinism lint for the replay/consensus-critical modules.

Bit-identical recovery is a headline guarantee: a recovered run must
reproduce the fault-free run exactly, and the JOIN consensus must reach
the same answer on every rank. Three things silently break that —
wall-clock reads, unseeded RNGs, and iteration over `set`s (Python set
order varies with hash randomization and insertion history, which is
how PR 2's float-summation flake happened). This lint forbids them in
the modules behind the guarantees:

  wall-clock       time.time()/time_ns() — decisions must use
                   time.monotonic() (durations) or step counters
  unseeded-random  the module-level `random` RNG, `default_rng()` /
                   `Random()` / `RandomState()` with no seed
  set-iteration    for / comprehension / sum() / list() / tuple()
                   directly over a set-typed value — wrap in sorted()

Order-independent uses (membership, len, min/max, sorted, any/all, set
algebra) pass. Set-typedness is inferred locally: set literals and
comprehensions, set()/frozenset() calls, set-algebra expressions, and
names / self-attributes assigned any of those (including values of
dict-of-set comprehensions reached via subscript or .pop()).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.source import Module, SourceTree, is_self_attr

CHECKER = "determinism"
PREFIXES = (
    "repro/runtime/",
    "repro/core/",
    "repro/checkpoint/",
    "repro/serve/",
    "repro/scenarios/schema.py",
)

_GLOBAL_RNG_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "getrandbits", "randbytes", "seed",
}
_NP_RNG_FNS = {
    "rand", "randn", "randint", "random", "choice", "shuffle",
    "permutation", "normal", "uniform", "seed",
}
# iteration wrappers whose result is order-independent
_ORDER_FREE = {"sorted", "min", "max", "len", "any", "all", "set",
               "frozenset"}
# wrappers that *freeze* the nondeterministic order into a sequence
_ORDER_FREEZING = {"sum", "list", "tuple"}


def _dotted(node: ast.AST) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class _SetEnv:
    """Which names/attributes hold sets, inferred per class + function."""

    def __init__(self, set_attrs: Set[str]):
        self.attrs = set_attrs          # self.<attr> known to be a set
        self.names: Set[str] = set()    # local names known to be sets
        self.dict_of_sets: Set[str] = set()

    def is_set(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id in ("set", "frozenset"):
                return True
            # d.pop(k) on a dict-of-sets, s.difference(...), s.union(...)
            if isinstance(fn, ast.Attribute):
                if (fn.attr == "pop" and isinstance(fn.value, ast.Name)
                        and fn.value.id in self.dict_of_sets):
                    return True
                if fn.attr in ("difference", "union", "intersection",
                               "symmetric_difference", "copy"):
                    return self.is_set(fn.value)
            return False
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.BitXor, ast.Sub)):
            return self.is_set(node.left) or self.is_set(node.right)
        if isinstance(node, ast.Name):
            return node.id in self.names
        if is_self_attr(node):
            return node.attr in self.attrs
        if isinstance(node, ast.Subscript):
            return (isinstance(node.value, ast.Name)
                    and node.value.id in self.dict_of_sets)
        return False

    def learn(self, stmt: ast.AST) -> None:
        if isinstance(stmt, ast.Assign):
            value, targets = stmt.value, stmt.targets
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            value, targets = stmt.value, [stmt.target]
        else:
            return
        names = [t.id for t in targets if isinstance(t, ast.Name)]
        if self.is_set(value):
            self.names.update(names)
        elif isinstance(value, ast.DictComp) and self.is_set(value.value):
            self.dict_of_sets.update(names)
        else:
            self.names.difference_update(names)
            self.dict_of_sets.difference_update(names)


def _class_set_attrs(cls: ast.ClassDef) -> Set[str]:
    env = _SetEnv(set())
    attrs: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and env.is_set(node.value):
            attrs.update(t.attr for t in node.targets if is_self_attr(t))
        elif (isinstance(node, ast.AnnAssign) and node.value is not None
                and env.is_set(node.value) and is_self_attr(node.target)):
            attrs.add(node.target.attr)
    return attrs


class _Visitor(ast.NodeVisitor):
    def __init__(self, mod: Module, env: _SetEnv, findings: List):
        self.mod = mod
        self.env = env
        self.findings = findings

    def _flag(self, node: ast.AST, code: str, subject: str, msg: str):
        from repro.analysis import Finding
        self.findings.append(
            Finding(CHECKER, self.mod.rel, node.lineno, code, subject,
                    msg))

    def _check_iter(self, node: ast.AST, where: str):
        if self.env.is_set(node):
            self._flag(node, "set-iteration", _dotted(node) or "<set>",
                       f"{where} iterates a set — order varies across "
                       f"processes; use sorted(...)")

    def visit_Assign(self, node):
        self.env.learn(node)
        self.generic_visit(node)

    def visit_AnnAssign(self, node):
        self.env.learn(node)
        self.generic_visit(node)

    def visit_For(self, node: ast.For):
        self._check_iter(node.iter, "for loop")
        self.generic_visit(node)

    def _visit_comp(self, node):
        for gen in node.generators:
            self._check_iter(gen.iter, "comprehension")
        self.generic_visit(node)

    visit_ListComp = visit_SetComp = visit_GeneratorExp = _visit_comp
    visit_DictComp = _visit_comp

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        if name in ("time.time", "time.time_ns"):
            self._flag(node, "wall-clock", name,
                       f"{name}() in a replay-critical module — use "
                       f"time.monotonic() or a step counter")
        elif name.startswith("random.") and \
                name.split(".", 1)[1] in _GLOBAL_RNG_FNS:
            self._flag(node, "unseeded-random", name,
                       f"{name}() uses the process-global RNG — "
                       f"construct random.Random(seed)")
        elif name.split(".")[-1] in ("default_rng", "RandomState") \
                and not node.args and not node.keywords:
            self._flag(node, "unseeded-random", name,
                       f"{name}() with no seed is entropy-seeded — "
                       f"pass an explicit seed")
        elif name.endswith(".Random") and not node.args \
                and not node.keywords:
            self._flag(node, "unseeded-random", name,
                       f"{name}() with no seed is entropy-seeded — "
                       f"pass an explicit seed")
        elif name in ("np.random." + f for f in _NP_RNG_FNS) or \
                name in ("numpy.random." + f for f in _NP_RNG_FNS):
            self._flag(node, "unseeded-random", name,
                       f"{name}() uses numpy's global RNG — use "
                       f"np.random.default_rng(seed)")
        elif (isinstance(node.func, ast.Name)
                and node.func.id in _ORDER_FREEZING and node.args
                and self.env.is_set(node.args[0])):
            self._flag(node, "set-iteration",
                       _dotted(node.args[0]) or "<set>",
                       f"{node.func.id}() over a set freezes a "
                       f"nondeterministic order — use sorted(...)")
        self.generic_visit(node)


def check(tree: SourceTree) -> List:
    findings: List = []
    for mod in tree.scan(PREFIXES):
        if mod.rel.startswith("repro/analysis/"):
            continue
        # module-level statements + each function with its own env
        module_env = _SetEnv(set())
        v = _Visitor(mod, module_env, findings)
        for stmt in mod.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            v.visit(stmt)
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                cls_attrs: Set[str] = set()
                env = _SetEnv(cls_attrs)
                fv = _Visitor(mod, env, findings)
                for child in node.body:
                    fv.visit(child)
            elif isinstance(node, ast.ClassDef):
                attrs = _class_set_attrs(node)
                for fn in node.body:
                    if isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                        env = _SetEnv(set(attrs))
                        fv = _Visitor(mod, env, findings)
                        for child in fn.body:
                            fv.visit(child)
    # methods get visited twice (as bare FunctionDef and via ClassDef);
    # dedupe by site
    seen, out = set(), []
    for f in findings:
        site = (f.path, f.line, f.code, f.subject)
        if site not in seen:
            seen.add(site)
            out.append(f)
    return out
