"""Hook-point checker.

The fault-injection choke points are stringly typed three times over:
`hooks.fire("X", ...)` call sites, the `POINTS`/`SERVE_POINTS`
registries in `scenarios/schema.py`, and the `point=` fields of catalog
cells. A typo in any of them silently tests the fault-free path — the
scenario still passes, it just never injects. This checker closes the
triangle:

  unknown-point    a fire() site names a point the registries don't know
  dynamic-point    a fire() site whose point is not a string literal
                   (unverifiable statically — spell it out)
  dead-point       a registered point with no fire site anywhere
  unfired-point    a catalog cell whose fault point has no fire site
  kwarg-drift      the same point fired with different kwarg sets at
                   different sites (an injector keyed on `step=` would
                   silently never match the bare site)
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.source import (Module, SourceTree, const_str,
                                   const_str_seq)

CHECKER = "hook-point"
SCHEMA_REL = "repro/scenarios/schema.py"
CATALOG_REL = "repro/scenarios/catalog.py"

# Fault(target, rank, step, point, how) — positional index of `point`,
# and the dataclass defaults the catalog relies on
_FAULT_POINT_POS = 3
_FAULT_POINT_DEFAULT = "step"
_SERVE_POINT_DEFAULT = "serve.decode.step"


def _registry_points(mod: Module) -> Dict[str, int]:
    """POINTS/SERVE_POINTS module-level tuples -> {point: lineno}."""
    points: Dict[str, int] = {}
    for node in mod.tree.body:
        if not isinstance(node, ast.Assign):
            continue
        names = {t.id for t in node.targets if isinstance(t, ast.Name)}
        if not names & {"POINTS", "SERVE_POINTS"}:
            continue
        seq = const_str_seq(node.value)
        if seq:
            for value, lineno in seq:
                points.setdefault(value, lineno)
    return points


def _fire_sites(tree: SourceTree):
    """Every `hooks.fire(...)` / `fire(...)` call in the tree ->
    [(module, call node, point or None)]."""
    sites = []
    for mod in tree.modules().values():
        if mod.rel.startswith("repro/analysis/"):
            continue            # the linter's own fixtures/prose
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            named_fire = (isinstance(fn, ast.Attribute) and fn.attr == "fire"
                          and isinstance(fn.value, ast.Name)
                          and fn.value.id == "hooks")
            bare_fire = isinstance(fn, ast.Name) and fn.id == "fire"
            if not (named_fire or bare_fire):
                continue
            point = const_str(node.args[0]) if node.args else None
            sites.append((mod, node, point))
    return sites


def _catalog_cells(mod: Module) -> List[Tuple[str, int, str]]:
    """Fault(...) / ServeScenario(...) calls -> [(point, lineno, cell)].
    `cell` is a best-effort context string for the message."""
    cells = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else None)
        if name == "Fault":
            point: Optional[str] = _FAULT_POINT_DEFAULT
            if len(node.args) > _FAULT_POINT_POS:
                point = const_str(node.args[_FAULT_POINT_POS])
            for kw in node.keywords:
                if kw.arg == "point":
                    point = const_str(kw.value)
            cells.append((point or "<dynamic>", node.lineno, "Fault"))
        elif name == "ServeScenario":
            point = _SERVE_POINT_DEFAULT
            cell = "ServeScenario"
            for kw in node.keywords:
                if kw.arg == "fault_point":
                    point = const_str(kw.value) or "<dynamic>"
                if kw.arg == "name":
                    cell = const_str(kw.value) or cell
            cells.append((point, node.lineno, cell))
    return cells


def check(tree: SourceTree) -> List:
    from repro.analysis import Finding
    findings: List[Finding] = []

    schema = tree.get(SCHEMA_REL)
    registry = _registry_points(schema) if schema else {}
    sites = _fire_sites(tree)

    fired: Dict[str, List[Tuple[Module, ast.Call]]] = {}
    for mod, node, point in sites:
        if point is None:
            findings.append(Finding(
                CHECKER, mod.rel, node.lineno, "dynamic-point",
                "<dynamic>",
                "fire() with a non-literal point cannot be checked "
                "against the registry — use a string literal"))
            continue
        fired.setdefault(point, []).append((mod, node))
        if registry and point not in registry:
            findings.append(Finding(
                CHECKER, mod.rel, node.lineno, "unknown-point", point,
                f"fire({point!r}) names a point absent from schema "
                f"POINTS/SERVE_POINTS — typo or unregistered hook"))

    # registered but never fired: the registry advertises an injection
    # site the runtime does not have
    if schema:
        for point, lineno in sorted(registry.items()):
            if point not in fired:
                findings.append(Finding(
                    CHECKER, SCHEMA_REL, lineno, "dead-point", point,
                    f"registered point {point!r} has no fire() site — "
                    f"scenarios selecting it can never inject"))

    # catalog cells must target fireable points
    catalog = tree.get(CATALOG_REL)
    if catalog:
        for point, lineno, cell in _catalog_cells(catalog):
            if point != "<dynamic>" and point not in fired:
                findings.append(Finding(
                    CHECKER, CATALOG_REL, lineno, "unfired-point", point,
                    f"{cell} cell targets point {point!r} which has no "
                    f"fire() site — the cell silently tests the "
                    f"fault-free path"))

    # kwarg drift: the canonical set is the first site in path order
    for point, plist in sorted(fired.items()):
        plist = sorted(plist, key=lambda mn: (mn[0].rel, mn[1].lineno))
        canon: Optional[frozenset] = None
        for mod, node in plist:
            kwargs = frozenset(kw.arg or "**" for kw in node.keywords)
            if canon is None:
                canon = kwargs
            elif kwargs != canon:
                findings.append(Finding(
                    CHECKER, mod.rel, node.lineno, "kwarg-drift", point,
                    f"fire({point!r}) passes kwargs "
                    f"{sorted(kwargs) or '[]'} but the first site "
                    f"passes {sorted(canon) or '[]'} — injectors keyed "
                    f"on a kwarg will silently skip one of them"))
    return findings
