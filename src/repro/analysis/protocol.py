"""Protocol message-flow checker.

Control-plane messages are dicts with an ALL-CAPS `"type"` tag sent over
`transport.send_msg` and dispatched by string comparison at the
receiving role. Nothing ties a send to a handler: PR 8's never-appended
`done` ledger and the class of "root broadcasts X, worker dispatches
X_TYPO" bugs only surface as a hung barrier in a scenario run. This
checker extracts both sides from the ASTs of the runtime + serve layers
and cross-checks the whole role graph (pooled across roles — relays
forward tags verbatim, so a tag is healthy iff *someone* constructs it
and *someone* dispatches it):

  orphan-tag     a constructed message tag no role ever dispatches
  dead-handler   a dispatch arm for a tag no role ever constructs

Reply-style tags consumed positionally (an inline `recv_msg` after a
request, e.g. HB_ACK) have no dispatch arm by design — those live in
the committed baseline with a justification.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Tuple

from repro.analysis.source import Module, SourceTree, const_str

CHECKER = "protocol"
PREFIXES = ("repro/runtime/", "repro/serve/")

# message tags are SHOUTY_SNAKE, >= 3 chars (ACK, SYNC, REINIT, ...)
TAG_RE = re.compile(r"^[A-Z][A-Z0-9_]{2,}$")


def _tag(node: ast.AST):
    s = const_str(node)
    return s if s is not None and TAG_RE.match(s) else None


def _collect(mod: Module):
    """-> (sent, handled): {tag: [lineno]} for message constructions
    ({"type": "TAG", ...} dict literals) and dispatch sites (equality /
    membership comparisons against tag constants)."""
    sent: Dict[str, List[int]] = {}
    handled: Dict[str, List[int]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Dict):
            for k, v in zip(node.keys, node.values):
                if k is not None and const_str(k) == "type":
                    t = _tag(v)
                    if t:
                        sent.setdefault(t, []).append(v.lineno)
        elif isinstance(node, ast.Compare):
            for op, comp in zip(node.ops, node.comparators):
                if isinstance(op, (ast.Eq, ast.NotEq)):
                    for side in (node.left, comp):
                        t = _tag(side)
                        if t:
                            handled.setdefault(t, []).append(side.lineno)
                elif isinstance(op, (ast.In, ast.NotIn)):
                    if isinstance(comp, (ast.Tuple, ast.List, ast.Set)):
                        for elt in comp.elts:
                            t = _tag(elt)
                            if t:
                                handled.setdefault(t, []).append(
                                    elt.lineno)
    return sent, handled


def check(tree: SourceTree) -> List:
    from repro.analysis import Finding
    sent: Dict[str, List[Tuple[str, int]]] = {}
    handled: Dict[str, List[Tuple[str, int]]] = {}
    for mod in tree.scan(PREFIXES):
        ms, mh = _collect(mod)
        for t, lines in ms.items():
            sent.setdefault(t, []).extend((mod.rel, ln) for ln in lines)
        for t, lines in mh.items():
            handled.setdefault(t, []).extend((mod.rel, ln)
                                             for ln in lines)

    findings: List[Finding] = []
    for t in sorted(set(sent) - set(handled)):
        rel, line = min(sent[t])
        findings.append(Finding(
            CHECKER, rel, line, "orphan-tag", t,
            f"message tag {t!r} is constructed but no role dispatches "
            f"it — the receiver drops it on the floor"))
    for t in sorted(set(handled) - set(sent)):
        rel, line = min(handled[t])
        findings.append(Finding(
            CHECKER, rel, line, "dead-handler", t,
            f"dispatch arm for tag {t!r} which nothing constructs — "
            f"dead code or a renamed sender"))
    return findings
