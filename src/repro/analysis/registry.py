"""Strategy-registry drift checker.

`core.recovery.STRATEGIES` is the single source of truth for the five
recovery strategies; every strategy-keyed surface — the scenario
schema's vocabulary, the Table-2 checkpoint policy, the real-runtime
engine's mode map, the root/train CLIs, the alias table — must derive
from (or exactly cover) it. PR 6 guarded this with a test; promoting it
into reprolint means drift fails the `static-analysis` CI job in
seconds, and the test becomes a thin wrapper over `check()`.

Unlike the AST checkers this one imports the (jax-free) live modules:
the derived surfaces are computed values, and comparing the computed
values is the whole point. Findings anchor to the surface's assignment
line found in the source tree when available.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.source import SourceTree

CHECKER = "registry"

_SURFACES = {
    "STRATEGY_KEYS": "repro/scenarios/schema.py",
    "TABLE2": "repro/checkpoint/policy.py",
    "MODES": "repro/runtime/root.py",
    "REAL_MODES": "repro/scenarios/engine.py",
    "STRATEGIES": "repro/core/recovery.py",
    "STRATEGY_ALIASES": "repro/core/recovery.py",
}


def _anchor(tree: SourceTree, surface: str) -> tuple:
    rel = _SURFACES.get(surface, "repro/core/recovery.py")
    mod = tree.get(rel)
    if mod is not None:
        for node in mod.tree.body:
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == surface
                    for t in node.targets):
                return rel, node.lineno
        return rel, 1
    return rel, 1


def check(tree: SourceTree) -> List:
    from repro.analysis import Finding
    findings: List[Finding] = []

    def drift(surface: str, message: str):
        rel, line = _anchor(tree, surface)
        findings.append(Finding(CHECKER, rel, line, "strategy-drift",
                                surface, message))

    try:
        from repro.checkpoint.policy import TABLE2
        from repro.core.recovery import (STRATEGIES, STRATEGY_ALIASES,
                                         get_strategy)
        from repro.launch.train import STRATEGIES as launch_strategies
        from repro.runtime.root import MODES
        from repro.scenarios import engine, schema
    except Exception as e:        # pragma: no cover - import breakage
        findings.append(Finding(CHECKER, "repro/core/recovery.py", 1,
                                "import-error", "registry",
                                f"could not import strategy surfaces: "
                                f"{e!r}"))
        return findings

    keys = set(STRATEGIES)
    if set(schema.STRATEGY_KEYS) != keys:
        drift("STRATEGY_KEYS",
              f"schema.STRATEGY_KEYS {sorted(schema.STRATEGY_KEYS)} != "
              f"registry keys {sorted(keys)}")
    want_t2 = {(f, s) for f in ("process", "node") for s in keys}
    if set(TABLE2) != want_t2:
        drift("TABLE2",
              f"checkpoint.policy.TABLE2 cells do not cover "
              f"(process|node) x registry keys: missing "
              f"{sorted(want_t2 - set(TABLE2))}, extra "
              f"{sorted(set(TABLE2) - want_t2)}")
    if set(MODES) != keys - {"ulfm"}:
        drift("MODES",
              f"root MODES {sorted(MODES)} != registry keys minus the "
              f"sim-only ulfm {sorted(keys - {'ulfm'})}")
    if set(engine.REAL_MODES) != set(MODES):
        drift("REAL_MODES",
              f"engine.REAL_MODES {sorted(engine.REAL_MODES)} != root "
              f"MODES {sorted(MODES)}")
    if set(launch_strategies) != keys:
        drift("STRATEGIES",
              f"launch.train strategy choices "
              f"{sorted(launch_strategies)} != registry keys "
              f"{sorted(keys)}")
    bad_aliases = set(STRATEGY_ALIASES.values()) - keys
    if bad_aliases:
        drift("STRATEGY_ALIASES",
              f"aliases resolve outside the registry: "
              f"{sorted(bad_aliases)}")
    for k in sorted(keys):
        try:
            ok = get_strategy(k).key == k
        except Exception:
            ok = False
        if not ok:
            drift("STRATEGIES",
                  f"get_strategy({k!r}) does not round-trip to its "
                  f"registry key")
    return findings
