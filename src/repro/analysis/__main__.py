"""reprolint CLI: `python -m repro.analysis [--strict] [--baseline F]`.

Exit codes: 0 clean (or report-only mode), 1 new findings under
--strict, 2 usage/setup errors. The committed baseline holds accepted
findings (keyed without line numbers); `--write-baseline` regenerates
it from the current tree, preserving existing justifications.
"""
from __future__ import annotations

import argparse
import os
import sys

import repro.analysis as analysis
from repro.analysis.source import SourceTree


def find_repo_root() -> str:
    """The directory holding pyproject.toml + src/repro — tried from
    this file's location (editable/source layout), then from cwd up."""
    here = os.path.dirname(os.path.abspath(__file__))
    candidates = [os.path.abspath(os.path.join(here, "..", "..", ".."))]
    d = os.getcwd()
    while True:
        candidates.append(d)
        parent = os.path.dirname(d)
        if parent == d:
            break
        d = parent
    for c in candidates:
        if (os.path.isfile(os.path.join(c, "pyproject.toml"))
                and os.path.isdir(os.path.join(c, "src", "repro"))):
            return c
    raise SystemExit("reprolint: cannot locate the repo root "
                     "(pyproject.toml + src/repro); pass --root")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis for protocol, hook-point, "
                    "lock-discipline, and determinism conventions")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any finding not in the baseline")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: "
                         "<root>/reprolint-baseline.json)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--checker", action="append", default=None,
                    choices=analysis.checker_names(),
                    help="run only this checker (repeatable)")
    ap.add_argument("--all", action="store_true",
                    help="also list baselined (accepted) findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings, "
                         "keeping existing justifications")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root) if args.root else find_repo_root()
    src = os.path.join(root, "src")
    if not os.path.isdir(src):
        print(f"reprolint: no src/ under {root}", file=sys.stderr)
        return 2
    baseline_path = args.baseline or os.path.join(
        root, "reprolint-baseline.json")

    tree = SourceTree(src)
    findings = analysis.run(tree, args.checker)
    baseline = analysis.load_baseline(baseline_path)
    new, accepted, stale = analysis.split_by_baseline(findings, baseline)

    if args.write_baseline:
        analysis.save_baseline(baseline_path, findings, baseline)
        print(f"reprolint: wrote {len(findings)} entr"
              f"{'y' if len(findings) == 1 else 'ies'} to "
              f"{baseline_path}")
        return 0

    for f in new:
        print(f.render())
    if args.all:
        for f in accepted:
            reason = baseline.get(f.key, "")
            print(f"{f.render()}  [baselined: {reason}]")
    for key in stale:
        print(f"reprolint: stale baseline entry (no longer matches): "
              f"{key}", file=sys.stderr)

    n_checkers = len(args.checker) if args.checker else len(
        analysis.checker_names())
    print(f"reprolint: {len(new)} new finding(s), {len(accepted)} "
          f"baselined, {len(stale)} stale baseline entr"
          f"{'y' if len(stale) == 1 else 'ies'} "
          f"({n_checkers} checker(s), "
          f"{len(tree.modules())} modules)")
    if args.strict and new:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
