"""Parsed-source substrate shared by the reprolint checkers.

A `SourceTree` walks one directory of Python sources (normally the
repo's `src/`, or a test fixture tree laid out the same way), parses
each file once, and hands checkers `(rel path, source, AST, lines)`
bundles. Trees are tiny (~100 files) so everything is parsed eagerly on
first use and cached for the run.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple


@dataclass
class Module:
    rel: str                  # posix path relative to the tree root
    path: str                 # absolute path
    source: str
    tree: ast.Module
    lines: List[str]          # source.splitlines(); lines[lineno-1]

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


class SourceTree:
    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        self._modules: Optional[Dict[str, Module]] = None
        self._errors: List[Tuple[str, SyntaxError]] = []

    def _load(self) -> Dict[str, Module]:
        if self._modules is not None:
            return self._modules
        mods: Dict[str, Module] = {}
        for dirpath, dirnames, filenames in os.walk(self.root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__",))
            for name in sorted(filenames):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, self.root).replace(os.sep, "/")
                try:
                    with open(path, encoding="utf-8") as f:
                        source = f.read()
                    tree = ast.parse(source, filename=path)
                except (SyntaxError, UnicodeDecodeError) as e:
                    self._errors.append((rel, e))  # surfaced as findings
                    continue
                mods[rel] = Module(rel, path, source, tree,
                                   source.splitlines())
        self._modules = mods
        return mods

    def modules(self) -> Dict[str, Module]:
        return self._load()

    def errors(self) -> List[Tuple[str, SyntaxError]]:
        self._load()
        return list(self._errors)

    def get(self, rel: str) -> Optional[Module]:
        return self._load().get(rel)

    def match(self, prefixes: Iterable[str]) -> List[Module]:
        """Modules under any of `prefixes` (exact file paths match too).
        Returns [] when nothing matches — callers scanning a fixture
        tree that doesn't mirror the real layout fall back to
        `modules()` themselves."""
        out = []
        for rel, mod in self._load().items():
            if any(rel == p or rel.startswith(p) for p in prefixes):
                out.append(mod)
        return out

    def scan(self, prefixes: Iterable[str]) -> List[Module]:
        """`match(prefixes)`, falling back to every module when the
        tree doesn't contain the canonical layout (fixture trees)."""
        return self.match(prefixes) or list(self._load().values())


# ------------------------------------------------------------ AST helpers

def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def const_str_seq(node: ast.AST) -> Optional[List[Tuple[str, int]]]:
    """A tuple/list/set of string constants -> [(value, lineno)], else
    None if any element is non-constant."""
    if not isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return None
    out = []
    for elt in node.elts:
        s = const_str(elt)
        if s is None:
            return None
        out.append((s, elt.lineno))
    return out


def is_self_attr(node: ast.AST, attr: Optional[str] = None) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and (attr is None or node.attr == attr))
