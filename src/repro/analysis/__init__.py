"""reprolint — static analysis for the repo's own correctness conventions.

The recovery paths this repo reproduces only work if every message a
role sends has a handler in the receiving role, every injected fault
point actually fires, annotated shared fields are touched under their
lock, and replayed recoveries are bit-deterministic. All of those are
string- or convention-level properties the type system cannot see, so
this package checks them from the ASTs:

  hook-point    fire() call-sites vs the schema POINTS registries,
                catalog cells vs live fire sites, kwarg drift
  protocol      message tags sent vs dispatched across the
                root/daemon/worker roles and the serve layer
  locks         `# guarded-by: <lock>` field annotations enforced
  determinism   wall-clock, unseeded RNGs, and set-iteration in the
                replay/consensus-critical modules
  registry      every strategy-keyed surface derives from
                core.recovery.STRATEGIES

Run as `python -m repro.analysis [--strict] [--baseline FILE]`.
Pre-existing accepted findings live in the committed baseline file
(keyed without line numbers, so they survive unrelated edits);
`--strict` fails on anything not baselined.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, List, Optional

from repro.analysis.source import SourceTree


def live_source_tree() -> SourceTree:
    """The tree this very package was imported from (the repo's src/)."""
    import repro
    pkg = os.path.abspath(list(repro.__path__)[0])
    return SourceTree(os.path.dirname(pkg))


@dataclasses.dataclass(frozen=True)
class Finding:
    """One checker hit. `key` intentionally omits the line number so a
    baseline entry survives edits elsewhere in the file; `subject` is
    the stable name the finding is about (a tag, a point, a field)."""
    checker: str          # checker id, e.g. "protocol"
    path: str             # path relative to the scanned source root
    line: int             # 1-based
    code: str             # short finding class, e.g. "orphan-tag"
    subject: str          # the tag / point / field / surface concerned
    message: str          # one-line human explanation

    @property
    def key(self) -> str:
        return f"{self.checker}:{self.path}:{self.code}:{self.subject}"

    def render(self) -> str:
        return (f"src/{self.path}:{self.line}: "
                f"[{self.checker}/{self.code}] {self.message}")


def _checker_table() -> Dict[str, Callable[[SourceTree], List[Finding]]]:
    # imported lazily so `import repro.analysis` stays dependency-free
    from repro.analysis import (determinism, hook_points, locks, protocol,
                                registry)
    return {
        "hook-point": hook_points.check,
        "protocol": protocol.check,
        "locks": locks.check,
        "determinism": determinism.check,
        "registry": registry.check,
    }


def checker_names() -> List[str]:
    return list(_checker_table())


def run(tree: SourceTree,
        checkers: Optional[List[str]] = None) -> List[Finding]:
    """Run the named checkers (default: all) over `tree`; findings come
    back sorted by location. Unparsable files surface as findings, not
    exceptions, so a syntax error cannot silently skip a checker."""
    table = _checker_table()
    names = checkers if checkers is not None else list(table)
    out: List[Finding] = []
    for rel, exc in tree.errors():
        out.append(Finding("parse", rel, getattr(exc, "lineno", 1) or 1,
                           "syntax-error", rel,
                           f"could not parse: {exc}"))
    for name in names:
        out.extend(table[name](tree))
    out.sort(key=lambda f: (f.path, f.line, f.checker, f.code, f.subject))
    return out


# --------------------------------------------------------------- baseline

def load_baseline(path: str) -> Dict[str, str]:
    """baseline file -> {finding key: justification}. Missing file is an
    empty baseline (the tool still runs; --strict then demands a fully
    clean tree)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    return {e["key"]: e.get("reason", "") for e in data.get("entries", ())}


def save_baseline(path: str, findings: List[Finding],
                  reasons: Optional[Dict[str, str]] = None) -> None:
    reasons = reasons or {}
    entries = []
    for key in sorted({f.key for f in findings}):
        entries.append({"key": key,
                        "reason": reasons.get(key, "TODO: justify")})
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": entries}, f, indent=2)
        f.write("\n")


def split_by_baseline(findings: List[Finding], baseline: Dict[str, str]):
    """-> (new, accepted, stale_keys): findings not in the baseline,
    findings the baseline accepts, and baseline keys that no longer
    match anything (candidates for pruning)."""
    new = [f for f in findings if f.key not in baseline]
    accepted = [f for f in findings if f.key in baseline]
    live = {f.key for f in findings}
    stale = sorted(k for k in baseline if k not in live)
    return new, accepted, stale
