"""Lock-discipline checker.

The runtime's threaded modules guard shared fields with per-object
locks, but nothing enforces that a new access site takes the lock — the
daemon reading `self.workers[r].pid` a line after its `with self.lock:`
block closed is exactly the race this catches. Fields opt in with a
trailing annotation on their defining assignment:

    self.workers = {}        # guarded-by: lock

Every `self.<field>` access (read or write) in that class must then sit
inside a `with self.<lockname>:` block. Two escapes:

  * `__init__` is construction — unchecked.
  * a method the caller must enter with the lock held declares it:

        def _prune(self, d):      # holds-lock: _lock

Nested functions (thread targets, callbacks) start with an empty held
set: they run later, when the enclosing `with` has long exited.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.source import Module, SourceTree, is_self_attr

CHECKER = "locks"
GUARD_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
HOLDS_RE = re.compile(r"#\s*holds-lock:\s*([A-Za-z_]\w*)")


def _guarded_fields(mod: Module,
                    cls: ast.ClassDef) -> Dict[str, Tuple[str, int]]:
    """{field: (lockname, annotation lineno)} from `self.x = ...`
    assignments whose source line carries a guarded-by comment."""
    fields: Dict[str, Tuple[str, int]] = {}
    for node in ast.walk(cls):
        targets: List[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            targets = [node.target]
        for t in targets:
            if is_self_attr(t):
                m = GUARD_RE.search(mod.line(t.lineno))
                if m:
                    fields.setdefault(t.attr, (m.group(1), t.lineno))
    return fields


def _holds_locks(mod: Module, fn: ast.FunctionDef) -> Set[str]:
    """holds-lock annotations on the def line, a decorator line, or the
    comment line directly above the def."""
    held: Set[str] = set()
    first = min([fn.lineno] + [d.lineno for d in fn.decorator_list])
    for lineno in range(max(1, first - 1), fn.body[0].lineno):
        m = HOLDS_RE.search(mod.line(lineno))
        if m:
            held.add(m.group(1))
    return held


def _with_locks(stmt: ast.With) -> Set[str]:
    out: Set[str] = set()
    for item in stmt.items:
        ctx = item.context_expr
        if isinstance(ctx, ast.Call):     # e.g. lock.acquire_timeout(...)
            ctx = ctx.func
        if is_self_attr(ctx):
            out.add(ctx.attr)
        elif isinstance(ctx, ast.Name):
            out.add(ctx.id)
    return out


class _MethodVisitor(ast.NodeVisitor):
    def __init__(self, mod: Module, fields: Dict[str, Tuple[str, int]],
                 held: Set[str], findings: List):
        self.mod = mod
        self.fields = fields
        self.held = held
        self.findings = findings
        self.seen: Set[Tuple[str, int]] = set()

    def visit_With(self, node: ast.With):
        for item in node.items:
            self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        added = _with_locks(node) - self.held
        self.held |= added
        for stmt in node.body:
            self.visit(stmt)
        self.held -= added

    def visit_Attribute(self, node: ast.Attribute):
        if is_self_attr(node) and node.attr in self.fields:
            lock, _ = self.fields[node.attr]
            if lock not in self.held:
                site = (node.attr, node.lineno)
                if site not in self.seen:
                    self.seen.add(site)
                    from repro.analysis import Finding
                    self.findings.append(Finding(
                        CHECKER, self.mod.rel, node.lineno,
                        "unguarded-access", node.attr,
                        f"self.{node.attr} is guarded-by {lock} but "
                        f"accessed without `with self.{lock}:` held"))
        self.generic_visit(node)

    def _enter_nested(self, node):
        # a nested def/lambda runs later: locks held *here* don't count
        held = (_holds_locks(self.mod, node)
                if isinstance(node, ast.FunctionDef) else set())
        sub = _MethodVisitor(self.mod, self.fields, held, self.findings)
        sub.seen = self.seen
        for child in ast.iter_child_nodes(node):
            sub.visit(child)

    def visit_FunctionDef(self, node):
        self._enter_nested(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        self._enter_nested(node)


def check(tree: SourceTree) -> List:
    findings: List = []
    for mod in tree.modules().values():
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            fields = _guarded_fields(mod, node)
            if not fields:
                continue
            for fn in node.body:
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                if fn.name == "__init__":
                    continue
                held = _holds_locks(mod, fn)
                v = _MethodVisitor(mod, fields, held, findings)
                for child in fn.body:
                    v.visit(child)
    return findings
