from .costs import ClusterCosts, AppProfile, APPS
from .cluster import (simulate_run, SimResult, recovery_time, recovery_e2e,
                      replica_break_even, simulate_scenario,
                      ScenarioSimResult)
