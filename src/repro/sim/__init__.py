from .costs import ClusterCosts, AppProfile, APPS
from .cluster import (simulate_run, SimResult, recovery_time, recovery_e2e,
                      rehost_break_even, replica_break_even,
                      simulate_scenario,
                      ScenarioSimResult)
