"""Calibrated α–β cost model for the cluster simulator.

Constants come from two sources, and EXPERIMENTS.md reports which is which:
  (a) measured on this machine's real-process runtime (spawn cost, detect
      latency, control-message latency), and
  (b) the paper's absolute numbers at known scales (CR ≈ 3 s re-deploy,
      Reinit++ ≈ 0.5 s process / 1.5 s node, ULFM ≈ 3× Reinit++ at 1024
      ranks, Lustre-bound checkpoint writes) — used to pin the constants
      that depend on datacenter hardware we cannot measure here.

The simulator charges these costs to *protocol event timelines* generated
by the same Algorithm-1/2 implementation the runtime uses; the figures
emerge from the protocol, not from hard-coded curves.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ClusterCosts:
    # --- control plane
    msg_latency_s: float = 2.0e-4       # one control hop (TCP, measured)
    sigchld_detect_s: float = 1.0e-3    # daemon notices a dead child
    channel_detect_s: float = 5.0e-3    # root notices a broken channel
    signal_s: float = 1.0e-4            # SIGREINIT delivery

    # --- process management (calibrated: Reinit++ ≈0.5 s process /
    # ≈1.5 s node, CR ≈3 s — the paper's §5.3/§5.4 absolute numbers)
    spawn_proc_s: float = 0.45          # fork+exec+MPI-init of one rank
    spawn_parallelism: int = 8          # concurrent spawns per daemon
    node_rehost_s: float = 0.5          # node failure: wire-up on new host
    scheduler_redeploy_s: float = 1.5   # CR: allocator + relaunch
    teardown_s: float = 0.6             # CR: kill + drain the old job

    # --- legacy serialized recovery engine (pre-pipelining): the old
    # runtime polled instead of waiting on events, and ran respawn,
    # drain and restore strictly one after another. Charged only to the
    # non-overlapped e2e path (measured: the removed sleeps were a 0.3 s
    # respawn/drain poll in the daemon and a 0.5 s drain in the root).
    poll_respawn_s: float = 0.3         # poll period: expected wait /2
    poll_drain_s: float = 0.5           # fixed teardown drain sleep

    # --- ULFM collectives [Bosilca et al.]: revoke is a flood; shrink and
    # agree are tree/allreduce-style with a per-rank linear component the
    # prototype exhibits at scale (paper Fig. 6: on par with Reinit++ up to
    # 64 ranks, ≈3× at 1024)
    ulfm_round_alpha_s: float = 2.0e-3      # per round, log2(n) factor
    ulfm_round_beta_s: float = 2.2e-4       # per round, linear-in-n factor
    ulfm_rounds: int = 4                    # revoke, shrink, agree, merge
    heartbeat_detect_s: float = 0.05        # observation period / 2

    # --- replica failover: promotion swaps a warm shadow in for the
    # failed rank — a PROMOTE broadcast, the shadow composing its
    # already-streamed frames from local memory, and the rejoin barrier.
    # No spawn, no file read, no recomputed steps.
    promote_compose_s: float = 0.02     # shadow composes warm delta frames
    standby_sync_s: float = 0.01        # standby root: final table catch-up
    rehome_s: float = 2.0e-3            # one daemon reconnects to standby

    # --- elastic shrinking recovery: no respawn anywhere — a SHRINK
    # broadcast, SIGREINIT to survivors, then the batch re-balance
    # (re-partitioning the step's work over the contracted data axis:
    # a metadata exchange plus per-survivor reassignment, not bulk state
    # movement — survivors restore from their own local copies)
    shrink_rebalance_s: float = 0.05

    # --- storage
    lustre_agg_bw_MBps: float = 50_000.0    # shared parallel-FS aggregate
    lustre_latency_s: float = 0.02
    mem_copy_bw_MBps: float = 8_000.0       # local DRAM/HBM snapshot
    nic_bw_MBps: float = 1_200.0            # buddy copy, per rank pair

    # --- barrier (ORTE tree over root<->daemon<->rank)
    def tree_barrier_s(self, n_ranks: int, ranks_per_node: int) -> float:
        n_nodes = max(1, n_ranks // ranks_per_node)
        depth = 2 + math.ceil(math.log2(max(n_nodes, 2)))
        return depth * self.msg_latency_s

    def file_write_s(self, n_ranks: int, mb_per_rank: float) -> float:
        """All ranks write simultaneously to the shared filesystem: the
        aggregate bandwidth is the bottleneck → linear in world size."""
        return self.lustre_latency_s + \
            (n_ranks * mb_per_rank) / self.lustre_agg_bw_MBps

    def file_read_s(self, n_ranks: int, mb_per_rank: float,
                    readers: int | None = None) -> float:
        """Reads after recovery: only `readers` ranks hit the FS at once
        (CR: all; Reinit node: the re-spawned node's ranks)."""
        r = n_ranks if readers is None else readers
        return self.lustre_latency_s + \
            (r * mb_per_rank) / self.lustre_agg_bw_MBps

    def mem_ckpt_s(self, mb_per_rank: float) -> float:
        """Local snapshot + buddy push overlap; pairs are parallel."""
        return mb_per_rank / self.mem_copy_bw_MBps + \
            mb_per_rank / self.nic_bw_MBps

    def shrink_recovery_s(self, n_ranks: int, ranks_per_node: int) -> float:
        """SHRINK broadcast over the root->daemon tree + survivor signals
        + batch re-balance + the rejoin barrier. No spawn term at all —
        that absence is the mechanism's whole advantage."""
        n_nodes = max(1, n_ranks // max(ranks_per_node, 1))
        bcast = self.msg_latency_s * (1 + math.ceil(
            math.log2(max(n_nodes, 2))))
        return bcast + self.signal_s * ranks_per_node \
            + self.shrink_rebalance_s \
            + self.tree_barrier_s(n_ranks, ranks_per_node)

    def grow_recovery_s(self, n_ranks: int, ranks_per_node: int,
                        n_added: int) -> float:
        """Grow-back at a checkpoint boundary: the GROW broadcast over the
        root->daemon tree, SIGREINIT to survivors, the rejoined daemon's
        parallel spawn of the re-admitted ranks (wired up on the repaired
        host), and the rejoin barrier over the re-expanded world. The
        restore term (re-admitted ranks re-reading their pinned files) is
        charged separately, like every other recovery's read."""
        n_nodes = max(1, n_ranks // max(ranks_per_node, 1))
        bcast = self.msg_latency_s * (1 + math.ceil(
            math.log2(max(n_nodes, 2))))
        waves = math.ceil(n_added / max(self.spawn_parallelism, 1))
        return bcast + self.signal_s * max(n_ranks - n_added, 0) \
            + waves * self.spawn_proc_s + self.node_rehost_s \
            + self.tree_barrier_s(n_ranks, ranks_per_node)

    def promote_s(self, n_ranks: int, ranks_per_node: int,
                  n_promoted: int = 1) -> float:
        """Zero-rollback failover: PROMOTE broadcast over the root->daemon
        tree, the promoted shadows composing their streamed frames from
        local memory (parallel across shadows), and the rejoin barrier
        that re-forms the ring. Every other recovery's dominant terms —
        spawn, file read, rolled-back recompute — are absent, which is
        the strategy's entire point."""
        n_nodes = max(1, n_ranks // max(ranks_per_node, 1))
        bcast = self.msg_latency_s * (1 + math.ceil(
            math.log2(max(n_nodes, 2))))
        return bcast + self.promote_compose_s \
            + self.tree_barrier_s(n_ranks, ranks_per_node)

    def standby_takeover_s(self, n_ranks: int, ranks_per_node: int) -> float:
        """Root loss under replica: daemons notice the dead channel,
        re-home to the warm standby (parallel reconnects, charged once),
        the standby reconciles its mirrored tables, and the cluster
        resumes — no external relaunch, no worker ever restarts."""
        return self.channel_detect_s + self.rehome_s \
            + self.standby_sync_s \
            + self.tree_barrier_s(n_ranks, ranks_per_node)

    def degraded_step_s(self, step_time_s: float,
                        slow_factor: float) -> float:
        """Whole-job step time with one gray (degraded) member: the BSP
        barrier couples the world to its slowest rank, so a single node
        running at 1/slow_factor throughput slows *every* step to the
        victim's pace. This is what makes tolerating a gray failure a
        per-step tax on the whole job rather than a local problem."""
        return step_time_s * max(slow_factor, 1.0)

    def ulfm_recovery_collectives_s(self, n_ranks: int) -> float:
        per_round = self.ulfm_round_alpha_s * math.log2(max(n_ranks, 2)) \
            + self.ulfm_round_beta_s * n_ranks
        return self.ulfm_rounds * per_round


@dataclasses.dataclass(frozen=True)
class AppProfile:
    """Proxy-app stand-ins (weak scaling: per-rank work constant).

    step_time_s / ckpt_mb_per_rank are synthetic but sized like the paper's
    proxies (CoMD molecular dynamics, HPCCG CG solver, LULESH hydro)."""
    name: str
    step_time_s: float
    ckpt_mb_per_rank: float
    n_steps: int


APPS = {
    "comd": AppProfile("CoMD", step_time_s=1.10, ckpt_mb_per_rank=60.0,
                       n_steps=20),
    "hpccg": AppProfile("HPCCG", step_time_s=0.45, ckpt_mb_per_rank=30.0,
                        n_steps=25),
    "lulesh": AppProfile("LULESH", step_time_s=0.70, ckpt_mb_per_rank=45.0,
                         n_steps=20),
}
