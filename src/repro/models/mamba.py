"""Mamba1 (selective scan) and Mamba2 (SSD) blocks with train + decode paths.

Training uses a *chunked* scan: a sequential `lax.scan` over sequence chunks
carrying the SSM state, with fully parallel (associative-scan / matmul) work
inside each chunk. This bounds activation memory to O(B * chunk * d_inner *
d_state) regardless of sequence length — the reason SSM archs run the
long_500k cell at all. The inner chunk computation is the part the Pallas
kernel (repro.kernels.mamba_scan) replaces on TPU.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.partition import shard_constraint

from .config import ModelConfig
from .layers import _init, rmsnorm, rmsnorm_init

Params = Any


def _constrain_chunks(*arrs, inner="heads"):
    """Pin stacked per-chunk scan inputs (nchunk, B, c, d…) to
    (replicated, batch, replicated, inner): without this GSPMD may shard
    the leading scan axis and reshard every iteration (measured: ~540 MB
    all-to-all per layer per chunk on falcon-mamba train_4k)."""
    out = []
    for a in arrs:
        axes = [None, "batch", None] + [None] * (a.ndim - 3)
        if inner is not None and a.ndim >= 4:
            axes[3] = inner
        out.append(shard_constraint(a, *axes))
    return tuple(out)


# ------------------------------------------------------------------- mamba1

def mamba1_init(key, cfg: ModelConfig, dtype):
    D, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(D // 16, 1)
    ks = jax.random.split(key, 7)
    return {
        # split projections (not one fused (D, 2di) matrix): each output is
        # then independently model-sharded, so the xi/z split never crosses
        # shard boundaries (a fused split costs an all-to-all per layer)
        "in_x": _init(ks[0], (D, di), dtype),
        "in_z": _init(ks[5], (D, di), dtype),
        "conv_w": _init(ks[1], (cfg.ssm_conv, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _init(ks[2], (di, dt_rank + 2 * ds), dtype),
        "dt_proj": _init(ks[3], (dt_rank, di), dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds)).astype(dtype)),
        "D": jnp.ones((di,), dtype),
        "out_proj": _init(ks[4], (di, D), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init_state: jnp.ndarray | None = None):
    """Depthwise causal conv. x: (B,S,C); w: (K,C). Returns (y, last K-1 x)."""
    K = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return y + b, xp[:, -(K - 1):, :] if K > 1 else init_state


def _chunk_scan_m1(dA, dBx, h0):
    """Intra-chunk associative scan. dA,dBx: (B,c,di,ds); h0: (B,di,ds)."""
    # prepend the carry as an extra step with A=1
    ones = jnp.ones_like(dA[:, :1])
    A = jnp.concatenate([ones, dA], axis=1)
    b = jnp.concatenate([h0[:, None], dBx], axis=1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, hs = jax.lax.associative_scan(combine, (A, b), axis=1)
    return hs[:, 1:], hs[:, -1]           # per-step states, final carry


def mamba1_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                   compute_dtype=jnp.bfloat16):
    """x: (B,S,D) -> (B,S,D). Chunked selective scan."""
    B, S, D = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    dt_rank = max(D // 16, 1)
    c = min(cfg.ssm_chunk, S)
    assert S % c == 0, f"seq {S} not divisible by chunk {c}"

    xc = x.astype(compute_dtype)
    xi = xc @ p["in_x"].astype(compute_dtype)
    z = xc @ p["in_z"].astype(compute_dtype)
    xi, _ = _causal_conv(xi, p["conv_w"].astype(compute_dtype),
                         p["conv_b"].astype(compute_dtype))
    xi = jax.nn.silu(xi)

    proj = xi @ p["x_proj"].astype(compute_dtype)
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(compute_dtype)
                         + p["dt_bias"].astype(compute_dtype))   # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (di,ds)

    nchunk = S // c
    xi = shard_constraint(xi, "batch", None, "heads")
    dt = shard_constraint(dt, "batch", None, "heads")
    xs = xi.reshape(B, nchunk, c, di)
    dts = dt.reshape(B, nchunk, c, di)
    Bs = Bc.reshape(B, nchunk, c, ds)
    Cs = Cc.reshape(B, nchunk, c, ds)

    def chunk_body(h, inp):
        xc, dtc, bc, cc = inp                            # (B,c,...)
        dtf = dtc.astype(jnp.float32)
        dA = jnp.exp(dtf[..., None] * A)                 # (B,c,di,ds)
        dBx = (dtf * xc.astype(jnp.float32))[..., None] * bc.astype(jnp.float32)[..., None, :]
        hs, h_last = _chunk_scan_m1(dA, dBx, h)
        y = jnp.einsum("bcds,bcs->bcd", hs, cc.astype(jnp.float32))
        return h_last, y.astype(compute_dtype)

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, h0,
                         _constrain_chunks(
                             xs.transpose(1, 0, 2, 3),
                             dts.transpose(1, 0, 2, 3), inner="heads")
                         + _constrain_chunks(
                             Bs.transpose(1, 0, 2, 3),
                             Cs.transpose(1, 0, 2, 3), inner=None))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = y + xi * p["D"].astype(compute_dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(compute_dtype)


def mamba1_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32,
                      abstract: bool = False):
    di, ds, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    shapes = {"h": (batch, di, ds), "conv": (batch, K - 1, di)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in shapes.items()}
    return {k: jnp.zeros(s, dtype) for k, s in shapes.items()}


def mamba1_step(p: Params, x: jnp.ndarray, state, cfg: ModelConfig,
                compute_dtype=jnp.bfloat16):
    """Single-token decode. x: (B,1,D); state: {h:(B,di,ds), conv:(B,K-1,di)}."""
    B = x.shape[0]
    D, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(D // 16, 1)
    xc = x.astype(compute_dtype)
    xi = xc @ p["in_x"].astype(compute_dtype)
    z = xc @ p["in_z"].astype(compute_dtype)
    xi, conv_state = _causal_conv(xi, p["conv_w"].astype(compute_dtype),
                                  p["conv_b"].astype(compute_dtype),
                                  state["conv"].astype(compute_dtype))
    xi = jax.nn.silu(xi)
    proj = xi @ p["x_proj"].astype(compute_dtype)
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(compute_dtype)
                         + p["dt_bias"].astype(compute_dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtf = dt[:, 0].astype(jnp.float32)                       # (B,di)
    dA = jnp.exp(dtf[..., None] * A)                         # (B,di,ds)
    dBx = (dtf * xi[:, 0].astype(jnp.float32))[..., None] \
        * Bc[:, 0].astype(jnp.float32)[:, None, :]
    h = state["h"] * dA + dBx
    y = jnp.einsum("bds,bs->bd", h, Cc[:, 0].astype(jnp.float32))
    y = y.astype(compute_dtype) + xi[:, 0] * p["D"].astype(compute_dtype)
    y = y * jax.nn.silu(z[:, 0])
    out = y @ p["out_proj"].astype(compute_dtype)
    return out[:, None, :], {"h": h, "conv": conv_state.astype(state["conv"].dtype)}


# ------------------------------------------------------------------- mamba2

def mamba2_init(key, cfg: ModelConfig, dtype):
    D, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.n_ssm_heads
    ks = jax.random.split(key, 7)
    return {
        # split projections: see mamba1_init — keeps every output aligned
        # to its own sharding (z/x over "heads", small B/C/dt replicated)
        "in_z": _init(ks[0], (D, di), dtype),
        "in_x": _init(ks[3], (D, di), dtype),
        "in_bc": _init(ks[4], (D, 2 * ds), dtype),
        "in_dt": _init(ks[5], (D, nh), dtype),
        "conv_w": _init(ks[1], (cfg.ssm_conv, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "conv_bc_w": _init(ks[6], (cfg.ssm_conv, 2 * ds), dtype, scale=0.5),
        "conv_bc_b": jnp.zeros((2 * ds,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "A_log": jnp.zeros((nh,), dtype),
        "D": jnp.ones((nh,), dtype),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": _init(ks[2], (di, D), dtype),
    }


def _segsum(x):
    """x: (..., c) -> (..., c, c) lower-triangular segment sums."""
    c = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_chunk(xc, dtc, bc, cc, A, h0):
    """One SSD chunk. xc: (B,c,nh,hp); dtc: (B,c,nh); bc,cc: (B,c,ds);
    A: (nh,); h0: (B,nh,hp,ds). Returns (y (B,c,nh,hp), h_next)."""
    dA = dtc * A                                             # (B,c,nh)
    seg = _segsum(dA.transpose(0, 2, 1))                     # (B,nh,c,c)
    L = jnp.exp(seg)
    # diagonal (intra-chunk) term: attention-like matmuls
    G = jnp.einsum("bqs,bks->bqk", cc, bc)                   # (B,c,c)
    M = G[:, None] * L                                       # (B,nh,c,c)
    y_diag = jnp.einsum("bhqk,bkh,bkhp->bqhp", M, dtc, xc)
    # state at chunk end
    cum = jnp.cumsum(dA, axis=1)
    decay_to_end = jnp.exp(cum[:, -1:, :] - cum)             # (B,c,nh)
    h_new = jnp.einsum("bkh,bkh,bkhp,bks->bhps",
                       decay_to_end, dtc, xc, bc)
    h_next = h0 * jnp.exp(cum[:, -1])[:, :, None, None] + h_new
    # off-diagonal: contribution of the incoming state
    decay_from_start = jnp.exp(cum)                          # (B,c,nh)
    y_off = jnp.einsum("bqs,bqh,bhps->bqhp", cc, decay_from_start, h0)
    return y_diag + y_off, h_next


def mamba2_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                   compute_dtype=jnp.bfloat16):
    B, S, D = x.shape
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    c = min(cfg.ssm_chunk, S)
    assert S % c == 0

    xc0 = x.astype(compute_dtype)
    z = xc0 @ p["in_z"].astype(compute_dtype)
    xi = xc0 @ p["in_x"].astype(compute_dtype)
    bc = xc0 @ p["in_bc"].astype(compute_dtype)
    dt = xc0 @ p["in_dt"].astype(compute_dtype)
    xi, _ = _causal_conv(xi, p["conv_w"].astype(compute_dtype),
                         p["conv_b"].astype(compute_dtype))
    bc, _ = _causal_conv(bc, p["conv_bc_w"].astype(compute_dtype),
                         p["conv_bc_b"].astype(compute_dtype))
    xi = jax.nn.silu(xi)
    bc = jax.nn.silu(bc)
    Bc, Cc = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(compute_dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (nh,)

    nchunk = S // c
    xh = xi.reshape(B, nchunk, c, nh, hp).astype(jnp.float32)
    dts = dt.reshape(B, nchunk, c, nh).astype(jnp.float32)
    Bs = Bc.reshape(B, nchunk, c, ds).astype(jnp.float32)
    Cs = Cc.reshape(B, nchunk, c, ds).astype(jnp.float32)

    def chunk_body(h, inp):
        xc, dtc, bc, cc = inp
        y, h = _ssd_chunk(xc, dtc, bc, cc, A, h)
        return h, y.astype(compute_dtype)

    h0 = jnp.zeros((B, nh, hp, ds), jnp.float32)
    _, ys = jax.lax.scan(
        chunk_body, h0,
        _constrain_chunks(xh.transpose(1, 0, 2, 3, 4), inner="heads")
        + _constrain_chunks(dts.transpose(1, 0, 2, 3),
                            Bs.transpose(1, 0, 2, 3),
                            Cs.transpose(1, 0, 2, 3), inner=None))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, di)
    y = y + xi * jnp.repeat(p["D"].astype(compute_dtype), hp)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"].astype(compute_dtype)


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32,
                      abstract: bool = False):
    di, ds, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    nh, hp = cfg.n_ssm_heads, cfg.ssm_head_dim
    shapes = {"h": (batch, nh, hp, ds), "conv": (batch, K - 1, di + 2 * ds)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in shapes.items()}
    return {k: jnp.zeros(s, dtype) for k, s in shapes.items()}


def mamba2_step(p: Params, x: jnp.ndarray, state, cfg: ModelConfig,
                compute_dtype=jnp.bfloat16):
    """Single-token decode for Mamba2."""
    B = x.shape[0]
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    xc0 = x.astype(compute_dtype)
    z = xc0 @ p["in_z"].astype(compute_dtype)
    xi = xc0 @ p["in_x"].astype(compute_dtype)
    bc = xc0 @ p["in_bc"].astype(compute_dtype)
    dt = xc0 @ p["in_dt"].astype(compute_dtype)
    xi, conv_state_x = _causal_conv(
        xi, p["conv_w"].astype(compute_dtype),
        p["conv_b"].astype(compute_dtype),
        state["conv"][..., :di].astype(compute_dtype))
    bc, conv_state_bc = _causal_conv(
        bc, p["conv_bc_w"].astype(compute_dtype),
        p["conv_bc_b"].astype(compute_dtype),
        state["conv"][..., di:].astype(compute_dtype))
    conv_state = jnp.concatenate(
        [conv_state_x, conv_state_bc], axis=-1)
    xi = jax.nn.silu(xi)
    bc = jax.nn.silu(bc)
    Bc, Cc = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(compute_dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xf = xi[:, 0].reshape(B, nh, hp).astype(jnp.float32)
    dtf = dt[:, 0].astype(jnp.float32)                        # (B,nh)
    dA = jnp.exp(dtf * A)                                     # (B,nh)
    h = state["h"] * dA[:, :, None, None] \
        + jnp.einsum("bh,bhp,bs->bhps", dtf, xf, Bc[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhps,bs->bhp", h, Cc[:, 0].astype(jnp.float32))
    y = y + xf * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, di).astype(compute_dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z[:, 0]), cfg.norm_eps)
    out = y @ p["out_proj"].astype(compute_dtype)
    return out[:, None, :], {"h": h, "conv": conv_state.astype(state["conv"].dtype)}


# ------------------------------------------------- prefill (state capture)

def mamba1_forward_with_state(p, x, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    """Single-pass mamba1 forward that also returns the final recurrent state.

    Used by the prefill path of SSM/hybrid archs.
    """
    B, S, D = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    dt_rank = max(D // 16, 1)
    c = min(cfg.ssm_chunk, S)
    xc0 = x.astype(compute_dtype)
    xi_pre = xc0 @ p["in_x"].astype(compute_dtype)
    z = xc0 @ p["in_z"].astype(compute_dtype)
    conv_tail = xi_pre[:, -(cfg.ssm_conv - 1):].astype(jnp.float32)
    xi, _ = _causal_conv(xi_pre, p["conv_w"].astype(compute_dtype),
                         p["conv_b"].astype(compute_dtype))
    xi = jax.nn.silu(xi)
    proj = xi @ p["x_proj"].astype(compute_dtype)
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(compute_dtype)
                         + p["dt_bias"].astype(compute_dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    nchunk = S // c

    def body(h, inp):
        xc, dtc, bc, cc = inp
        dtf = dtc.astype(jnp.float32)
        dA = jnp.exp(dtf[..., None] * A)
        dBx = (dtf * xc.astype(jnp.float32))[..., None] \
            * bc.astype(jnp.float32)[..., None, :]
        hs, h = _chunk_scan_m1(dA, dBx, h)
        y = jnp.einsum("bcds,bcs->bcd", hs, cc.astype(jnp.float32))
        return h, y.astype(compute_dtype)

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h, ys = jax.lax.scan(
        body, h0,
        _constrain_chunks(
            xi.reshape(B, nchunk, c, di).transpose(1, 0, 2, 3),
            dt.reshape(B, nchunk, c, di).transpose(1, 0, 2, 3),
            inner="heads")
        + _constrain_chunks(
            Bc.reshape(B, nchunk, c, ds).transpose(1, 0, 2, 3),
            Cc.reshape(B, nchunk, c, ds).transpose(1, 0, 2, 3),
            inner=None))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = y + xi * p["D"].astype(compute_dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(compute_dtype)
    return out, {"h": h, "conv": conv_tail}


def mamba2_forward_with_state(p, x, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    B, S, D = x.shape
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    c = min(cfg.ssm_chunk, S)
    xc0 = x.astype(compute_dtype)
    z = xc0 @ p["in_z"].astype(compute_dtype)
    xi_pre = xc0 @ p["in_x"].astype(compute_dtype)
    bc_pre = xc0 @ p["in_bc"].astype(compute_dtype)
    dt = xc0 @ p["in_dt"].astype(compute_dtype)
    conv_tail = jnp.concatenate(
        [xi_pre[:, -(cfg.ssm_conv - 1):],
         bc_pre[:, -(cfg.ssm_conv - 1):]], axis=-1).astype(jnp.float32)
    xi, _ = _causal_conv(xi_pre, p["conv_w"].astype(compute_dtype),
                         p["conv_b"].astype(compute_dtype))
    bc, _ = _causal_conv(bc_pre, p["conv_bc_w"].astype(compute_dtype),
                         p["conv_bc_b"].astype(compute_dtype))
    xi = jax.nn.silu(xi)
    bc = jax.nn.silu(bc)
    Bc, Cc = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(compute_dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    nchunk = S // c
    xh = xi.reshape(B, nchunk, c, nh, hp).astype(jnp.float32)
    dts = dt.reshape(B, nchunk, c, nh).astype(jnp.float32)
    Bs = Bc.reshape(B, nchunk, c, ds).astype(jnp.float32)
    Cs = Cc.reshape(B, nchunk, c, ds).astype(jnp.float32)

    def chunk_body(h, inp):
        xc, dtc, bc, cc = inp
        y, h = _ssd_chunk(xc, dtc, bc, cc, A, h)
        return h, y.astype(compute_dtype)

    h0 = jnp.zeros((B, nh, hp, ds), jnp.float32)
    h, ys = jax.lax.scan(
        chunk_body, h0,
        _constrain_chunks(xh.transpose(1, 0, 2, 3, 4), inner="heads")
        + _constrain_chunks(dts.transpose(1, 0, 2, 3),
                            Bs.transpose(1, 0, 2, 3),
                            Cs.transpose(1, 0, 2, 3), inner=None))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, di)
    y = y + xi * jnp.repeat(p["D"].astype(compute_dtype), hp)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"].astype(compute_dtype)
    return out, {"h": h, "conv": conv_tail}


def mamba_forward_with_state(p, x, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    return mamba1_forward_with_state(p, x, cfg, compute_dtype) \
        if cfg.ssm_version == 1 else mamba2_forward_with_state(p, x, cfg, compute_dtype)


# ------------------------------------------------------------- dispatchers

def mamba_init(key, cfg: ModelConfig, dtype):
    return mamba1_init(key, cfg, dtype) if cfg.ssm_version == 1 \
        else mamba2_init(key, cfg, dtype)


def mamba_forward(p, x, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    return mamba1_forward(p, x, cfg, compute_dtype) if cfg.ssm_version == 1 \
        else mamba2_forward(p, x, cfg, compute_dtype)


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32,
                     abstract: bool = False):
    return mamba1_init_state(cfg, batch, dtype, abstract) if cfg.ssm_version == 1 \
        else mamba2_init_state(cfg, batch, dtype, abstract)


def mamba_step(p, x, state, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    return mamba1_step(p, x, state, cfg, compute_dtype) if cfg.ssm_version == 1 \
        else mamba2_step(p, x, state, cfg, compute_dtype)


# ------------------------------------------------- Pallas kernel binding

def mamba1_forward_pallas(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                          compute_dtype=jnp.bfloat16, *,
                          interpret: bool = False,
                          chunk: int = 256, block_d: int = 512):
    """mamba1_forward with the selective scan executed by the Pallas TPU
    kernel (repro.kernels.mamba_scan) instead of the chunked jnp scan.

    Identical math (tested against mamba1_forward); `interpret=True` runs
    the kernel body in Python on CPU. On TPU this is the production path
    for the SSM hot loop.
    """
    from repro.kernels.mamba_scan.ops import mamba_scan

    B, S, D = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    dt_rank = max(D // 16, 1)
    xc = x.astype(compute_dtype)
    xi = xc @ p["in_x"].astype(compute_dtype)
    z = xc @ p["in_z"].astype(compute_dtype)
    xi, _ = _causal_conv(xi, p["conv_w"].astype(compute_dtype),
                         p["conv_b"].astype(compute_dtype))
    xi = jax.nn.silu(xi)
    proj = xi @ p["x_proj"].astype(compute_dtype)
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(compute_dtype)
                         + p["dt_bias"].astype(compute_dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = mamba_scan(xi.astype(jnp.float32), dt.astype(jnp.float32),
                      Bc.astype(jnp.float32), Cc.astype(jnp.float32), A,
                      interpret=interpret, chunk=chunk, block_d=block_d)
    y = y.astype(compute_dtype)
    y = y + xi * p["D"].astype(compute_dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(compute_dtype)
