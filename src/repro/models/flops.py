"""Analytic FLOP / HBM-byte model per (arch × shape) cell.

Why analytic: XLA's cost_analysis() counts scan bodies once (validated in
tests/test_roofline_model.py) and reports per-device numbers after fusion,
so the roofline harness uses an explicit count of the model's matmul-level
work, *validated against cost_analysis on small unrolled configs*, plus the
compiled HLO for the collective schedule (launch/hlo_analysis.py corrects
while-body trip counts there).

Conventions:
  - FLOPs are totals across the mesh for ONE step of the cell's kind
    (train_step / prefill / decode_step); divide by chips for per-chip.
  - A matmul (M,K)x(K,N) costs 2·M·K·N.
  - Train = 3× forward matmul FLOPs (fwd + 2× bwd) + remat recompute
    (= +1× fwd for the layer stack under the "full" policy).
  - Causal attention counts the full S² unless `flash=True` (the Pallas
    kernel skips above-diagonal blocks → ×0.5): the baseline chunked-jnp
    lowering really does compute the full square.
  - HBM bytes are a fusion-level estimate with documented multipliers —
    good for term dominance, not for ±5% accuracy.
"""
from __future__ import annotations

import dataclasses

from .config import ModelConfig, ShapeConfig


@dataclasses.dataclass
class CellCost:
    flops: float                  # total FLOPs / step across the mesh
    hbm_bytes: float              # total HBM traffic / step across mesh
    details: dict

    def per_chip(self, chips: int) -> tuple[float, float]:
        return self.flops / chips, self.hbm_bytes / chips


def _bytes_of(dtype: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}[dtype]


# --------------------------------------------------------------- attention

def attn_flops(cfg: ModelConfig, B: int, Sq: int, Sk: int, *,
               causal: bool, flash: bool) -> float:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * B * Sq * D * (H * hd)            # q
    proj += 2 * 2 * B * Sk * D * (Hkv * hd)     # k, v (projected from Sk)
    proj += 2 * B * Sq * (H * hd) * D           # o
    core = 2 * 2 * B * H * Sq * Sk * hd         # scores + AV
    if causal and flash and Sq == Sk:
        core *= 0.5
    return proj + core


def mlp_flops(cfg: ModelConfig, B: int, S: int) -> float:
    m = 3 if cfg.mlp_gated else 2
    return m * 2 * B * S * cfg.d_model * cfg.d_ff


def moe_flops(cfg: ModelConfig, B: int, S: int, group: int = 512) -> float:
    T = B * S
    E, k, D, F = cfg.n_experts, cfg.experts_per_token, cfg.d_model, cfg.d_ff
    g = min(group, T)
    cap = max(int(cfg.capacity_factor * k * g / E), 4)
    router = 2 * T * D * E
    # dispatch + combine one-hot einsums (GShard formulation cost)
    dispatch = 2 * 2 * T * E * cap * D
    experts = 3 * 2 * (T // g * E * cap) * D * F
    return router + dispatch + experts


def mamba_flops(cfg: ModelConfig, B: int, S: int) -> float:
    D, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    f = 2 * B * S * D * 2 * di                  # in_proj
    f += 2 * cfg.ssm_conv * B * S * di          # depthwise conv
    f += 2 * B * S * di * D                     # out_proj
    if cfg.ssm_version == 1:
        dtr = max(D // 16, 1)
        f += 2 * B * S * di * (dtr + 2 * ds)    # x_proj
        f += 2 * B * S * dtr * di               # dt_proj
        f += 8 * B * S * di * ds                # scan: dA, dBx, h, y
    else:
        nh, hp, c = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_chunk
        c = min(c, S)
        f += 2 * B * S * c * ds                 # G = C·Bᵀ per chunk
        f += 2 * B * nh * S * c * hp            # M @ x (intra-chunk)
        f += 4 * B * S * nh * hp * ds           # state update + off-diag
    return f


def _block_flops(cfg: ModelConfig, B: int, S: int, *, flash: bool,
                 moe_group: int = 512) -> float:
    """One decoder layer, forward."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return attn_flops(cfg, B, S, S, causal=True, flash=flash) \
            + mlp_flops(cfg, B, S)
    if fam == "moe":
        return attn_flops(cfg, B, S, S, causal=True, flash=flash) \
            + moe_flops(cfg, B, S, group=moe_group)
    if fam == "ssm":
        return mamba_flops(cfg, B, S)
    if fam == "hybrid":
        # per mamba layer; the shared attn block is charged per group
        return mamba_flops(cfg, B, S)
    if fam == "encdec":
        return 2 * attn_flops(cfg, B, S, S, causal=True, flash=flash) \
            + mlp_flops(cfg, B, S)     # self + cross (approx: Sk=S)
    raise ValueError(fam)


def forward_flops(cfg: ModelConfig, B: int, S: int, *,
                  flash: bool = False, moe_group: int = 512) -> float:
    f = cfg.n_layers * _block_flops(cfg, B, S, flash=flash,
                                    moe_group=moe_group)
    if cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        f += G * (attn_flops(cfg, B, S, S, causal=True, flash=flash)
                  + mlp_flops(cfg, B, S))
    if cfg.family == "encdec":
        f += cfg.n_enc_layers * (
            attn_flops(cfg, B, cfg.enc_seq_len, cfg.enc_seq_len,
                       causal=False, flash=flash)
            + mlp_flops(cfg, B, cfg.enc_seq_len))
    f += 2 * B * S * cfg.d_model * cfg.vocab_size      # unembed logits
    return f


def decode_flops(cfg: ModelConfig, B: int, Sk: int, *,
                 flash: bool = False) -> float:
    """One-token decode against a Sk-long state."""
    fam = cfg.family
    D = cfg.d_model

    def attn_decode():
        H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        proj = 2 * B * D * (H + 2 * Hkv) * hd + 2 * B * (H * hd) * D
        core = 2 * 2 * B * H * Sk * hd
        return proj + core

    def mlp_dec():
        return (3 if cfg.mlp_gated else 2) * 2 * B * D * cfg.d_ff

    if fam in ("dense", "vlm"):
        per = attn_decode() + mlp_dec()
    elif fam == "moe":
        per = attn_decode() + moe_flops(cfg, B, 1)
    elif fam == "ssm":
        per = mamba_flops(cfg, B, 1)
    elif fam == "hybrid":
        per = mamba_flops(cfg, B, 1)
    elif fam == "encdec":
        # self-attn decode + cross-attn over enc_seq_len + mlp
        H, hd = cfg.n_heads, cfg.head_dim
        cross = 2 * 2 * B * H * cfg.enc_seq_len * hd \
            + 2 * B * D * H * hd * 2
        per = attn_decode() + cross + mlp_dec()
    else:
        raise ValueError(fam)
    f = cfg.n_layers * per
    if fam == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        shared = 2 * B * D * (H + 2 * Hkv) * hd + 2 * B * (H * hd) * D \
            + 2 * 2 * B * H * Sk * hd + mlp_dec()
        f += G * shared
    f += 2 * B * D * cfg.vocab_size
    return f


# ------------------------------------------------------------------ bytes

def kv_cache_bytes(cfg: ModelConfig, B: int, Smax: int) -> float:
    """Device-resident decode state size (bf16 KV / fp32 SSM)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        per_layer = 2 * B * Smax * cfg.n_kv_heads * cfg.head_dim * 2
        total = cfg.n_layers * per_layer
        if fam == "encdec":
            total += cfg.n_layers * 2 * B * cfg.enc_seq_len * \
                cfg.n_kv_heads * cfg.head_dim * 2
        return total
    ssm = cfg.n_layers * B * 4 * (
        (cfg.d_inner * cfg.ssm_state if cfg.ssm_version == 1
         else cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state)
        + (cfg.ssm_conv - 1) * (cfg.d_inner if cfg.ssm_version == 1
                                else cfg.d_inner + 2 * cfg.ssm_state))
    if fam == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        ssm += G * 2 * B * Smax * cfg.n_kv_heads * cfg.head_dim * 2
    return ssm


def cell_cost(cfg: ModelConfig, shape: ShapeConfig, *,
              flash: bool = False, remat: bool = True,
              moe_group: int = 512) -> CellCost:
    """Roofline terms for one step of this cell (totals across the mesh)."""
    B, S = shape.global_batch, shape.seq_len
    P = cfg.param_count()
    P_active = cfg.param_count(active_only=True)
    pbytes = _bytes_of(cfg.param_dtype)
    d = {}

    if shape.kind == "train":
        fwd = forward_flops(cfg, B, S, flash=flash, moe_group=moe_group)
        flops = 3 * fwd
        if remat:
            # recompute the layer stack (not the unembed) in backward
            flops += fwd - 2 * B * S * cfg.d_model * cfg.vocab_size
        # params: fwd read + bwd read + grad write + Adam m/v r+w + p write
        param_traffic = P * pbytes * 2 + P * 4 * (1 + 4 + 1)
        # activations (full remat): store+read one (B,S,D) per layer in bf16
        act = 4 * cfg.n_layers * B * S * cfg.d_model * 2
        # within-layer traffic: x/out plus ff/kv intermediates ≈ 8×(B,S,D)
        act += 8 * cfg.n_layers * B * S * cfg.d_model * 2 * (2 if remat else 1)
        logits = 2 * B * S * cfg.vocab_size * 2 / 8        # chunked
        hbm = param_traffic + act + logits
        d = {"fwd_flops": fwd, "param_traffic": param_traffic, "act": act}

    elif shape.kind == "prefill":
        flops = forward_flops(cfg, B, S, flash=flash, moe_group=moe_group)
        act = 10 * cfg.n_layers * B * S * cfg.d_model * 2
        hbm = P * pbytes + act + kv_cache_bytes(cfg, B, S)
        d = {"kv_write": kv_cache_bytes(cfg, B, S)}

    else:  # decode
        flops = decode_flops(cfg, B, S, flash=flash)
        state = kv_cache_bytes(cfg, B, S)
        # decode reads all params + the full state once per token
        hbm = P * pbytes + state + B * cfg.d_model * cfg.n_layers * 2 * 10
        d = {"state_bytes": state}

    d["model_flops"] = (6 * P_active * B * S if shape.kind == "train"
                        else 2 * P_active * B * (S if shape.kind == "prefill"
                                                 else 1))
    return CellCost(flops=float(flops), hbm_bytes=float(hbm), details=d)
