"""Model configuration for all assigned architecture families.

A single frozen dataclass covers dense / MoE / SSM / hybrid / enc-dec / VLM
families; family-specific fields default to "off". Configs are pure data so
they can be hashed into jit static args and serialized into checkpoints.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_gated: bool = True      # False -> 2-matmul (up, down) MLP
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # MoE (d_ff is the per-expert width for moe archs)
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba1/mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64      # mamba2 only
    ssm_version: int = 1        # 1 = selective scan, 2 = SSD
    ssm_chunk: int = 128        # chunked-scan block length

    # hybrid (zamba2-style): a weight-shared attention block applied after
    # every `attn_every` mamba layers.
    attn_every: int = 0

    # encoder-decoder
    n_enc_layers: int = 0
    enc_seq_len: int = 0        # fixed encoder context for decode shapes

    # modality frontend stub: embeddings are provided by input_specs()
    frontend: Optional[str] = None   # "vision" | "audio"
    n_frontend_tokens: int = 0

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports very long context decode (O(1)-ish state)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ----- parameter counting (used for MODEL_FLOPS = 6 N D) -----
    def _attn_params(self) -> int:
        hd = self.head_dim
        p = self.d_model * (self.n_heads * hd)            # q
        p += 2 * self.d_model * (self.n_kv_heads * hd)    # k, v
        p += (self.n_heads * hd) * self.d_model           # o
        if self.qkv_bias:
            p += (self.n_heads + 2 * self.n_kv_heads) * hd
        return p

    def _mlp_params(self) -> int:
        m = 3 if self.mlp_gated else 2
        return m * self.d_model * self.d_ff               # (gate,) up, down

    def _moe_params(self, active_only: bool) -> int:
        e = self.experts_per_token if active_only else self.n_experts
        return self.d_model * self.n_experts + e * 3 * self.d_model * self.d_ff

    def _mamba_params(self) -> int:
        di, ds = self.d_inner, self.ssm_state
        p = self.d_model * 2 * di                          # in_proj (x, z)
        p += self.ssm_conv * di                            # depthwise conv
        p += di * self.d_model                             # out_proj
        if self.ssm_version == 1:
            dt_rank = max(self.d_model // 16, 1)
            p += di * (dt_rank + 2 * ds) + dt_rank * di    # x_proj, dt_proj
            p += di * ds + di                              # A_log, D
        else:  # mamba2 / SSD
            nh = self.n_ssm_heads
            p += self.d_model * (2 * ds + nh)              # B, C, dt projections
            p += nh + nh + di                              # A_log, D, norm
        return p

    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; active_only counts routed experts only."""
        emb = self.vocab_size * self.d_model
        total = emb if self.tie_embeddings else 2 * emb
        if self.frontend:
            total += self.d_model  # stub projection scale only

        def block_dense():
            return self._attn_params() + self._mlp_params() + 2 * self.d_model

        if self.family in ("dense", "vlm"):
            total += self.n_layers * block_dense()
        elif self.family == "moe":
            per = self._attn_params() + self._moe_params(active_only) + 2 * self.d_model
            total += self.n_layers * per
        elif self.family == "ssm":
            total += self.n_layers * (self._mamba_params() + self.d_model)
        elif self.family == "hybrid":
            total += self.n_layers * (self._mamba_params() + self.d_model)
            total += block_dense()                         # one shared attn block
        elif self.family == "encdec":
            # encoder: self-attn + mlp; decoder: self + cross + mlp
            enc = self.n_enc_layers * (self._attn_params() + self._mlp_params()
                                       + 2 * self.d_model)
            dec = self.n_layers * (2 * self._attn_params() + self._mlp_params()
                                   + 3 * self.d_model)
            total += enc + dec
        else:
            raise ValueError(f"unknown family {self.family}")
        return total


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str       # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode skipped (DESIGN.md §4)"
    return True, ""
