"""Attention: MHA/GQA/MQA with qk-norm, QKV bias, RoPE, KV-cache decode.

Three interchangeable inner implementations (same math):
  - "naive":   materializes (B,H,S,S) scores — reference / tiny tests only.
  - "chunked": flash-style streaming over KV blocks in pure jnp — bounded
               memory, used for CPU dry-runs and as the oracle-scale impl.
  - "pallas":  the TPU Pallas flash kernel (repro.kernels.flash_attention).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.sharding.partition import shard_constraint

from .config import ModelConfig
from .layers import _init, apply_rope, rmsnorm, rmsnorm_init

Params = Any

NEG_INF = -1e30


def attention_init(key, cfg: ModelConfig, dtype):
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (cfg.d_model, cfg.n_heads * hd), dtype),
        "wk": _init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), dtype),
        "wv": _init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), dtype),
        "wo": _init(ks[3], (cfg.n_heads * hd, cfg.d_model), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions, compute_dtype):
    B, S, _ = x.shape
    hd = cfg.head_dim
    xc = x.astype(compute_dtype)
    q = xc @ p["wq"].astype(compute_dtype)
    k = xc @ p["wk"].astype(compute_dtype)
    v = xc @ p["wv"].astype(compute_dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(compute_dtype)
        k = k + p["bk"].astype(compute_dtype)
        v = v + p["bv"].astype(compute_dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # keep batch data-sharded and heads model-sharded through the attention
    # core — without these constraints GSPMD re-shards activations when the
    # head count doesn't divide the model axis (28/56-head archs) and the
    # batch axis silently replicates.
    q = shard_constraint(q, "batch", None, "heads", None)
    k = shard_constraint(k, "batch", None, "kv_heads", None)
    v = shard_constraint(v, "batch", None, "kv_heads", None)
    return q, k, v


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def naive_attention(q, k, v, *, causal: bool, q_offset=0) -> jnp.ndarray:
    """q: (B,Sq,H,hd); k,v: (B,Sk,Hkv,hd). Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, H // Hkv)
    v = _repeat_kv(v, H // Hkv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Sk)[None, :]
        scores = jnp.where(kpos <= qpos, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                      kv_chunk: int = 1024) -> jnp.ndarray:
    """Flash-style online-softmax over KV chunks. Same math as naive.

    Peak memory is O(Sq * kv_chunk) per head instead of O(Sq * Sk).
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    n_rep = H // Hkv
    kv_chunk = min(kv_chunk, Sk)
    if Sk % kv_chunk != 0:
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset)
    n_chunks = Sk // kv_chunk

    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qpos = jnp.arange(Sq)[:, None] + q_offset

    def body(carry, ckv):
        acc, m, denom, idx = carry
        kb, vb = ckv
        # the GQA expansion happens AFTER the heads constraint: K/V are
        # replicated over the model axis (small), so each chip expands
        # only its local q-heads' slice — no repeated-tensor gathers.
        kb = _repeat_kv(kb, n_rep).astype(jnp.float32)
        vb = _repeat_kv(vb, n_rep).astype(jnp.float32)
        kb = shard_constraint(kb, "batch", None, "heads", None)
        vb = shard_constraint(vb, "batch", None, "heads", None)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb) * scale
        if causal:
            kpos = idx * kv_chunk + jnp.arange(kv_chunk)[None, :]
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        denom = denom * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb)
        return (acc, m_new, denom, idx + 1), None

    # flash-backward semantics: recompute the (B,H,Sq,chunk) score/softmax
    # tensors per chunk in the backward pass instead of stacking them over
    # all chunks as scan residuals (which costs n_chunks × B·H·Sq·chunk·4B
    # of HBM and defeats the point of streaming attention).
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)

    acc0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    d0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, _, denom, _), _ = jax.lax.scan(body, (acc0, m0, d0, 0), (kc, vc))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
              positions: Optional[jnp.ndarray] = None,
              causal: bool = True,
              impl: str = "chunked",
              kv_input: Optional[jnp.ndarray] = None,
              compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Full attention block: proj -> inner attention -> output proj.

    kv_input: encoder output (B, S_enc, D) for cross-attention; K/V are then
    projected from it (no RoPE, non-causal).
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if kv_input is not None:
        q, _, _ = _project_qkv(p, x, cfg, None, compute_dtype)
        _, k, v = _project_qkv(p, kv_input, cfg, None, compute_dtype)
        causal = False
    else:
        q, k, v = _project_qkv(p, x, cfg, positions, compute_dtype)
    if impl == "naive":
        o = naive_attention(q, k, v, causal=causal)
    elif impl == "chunked":
        o = chunked_attention(q, k, v, causal=causal)
    elif impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=causal)
    else:
        raise ValueError(impl)
    o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
    o = shard_constraint(o, "batch", None, "heads")
    return o @ p["wo"].astype(compute_dtype)


def attention_with_kv(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                      positions=None, impl: str = "chunked",
                      compute_dtype=jnp.bfloat16):
    """Prefill path: returns (out, k, v) so the caller can build a KV cache."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions, compute_dtype)
    if impl == "naive":
        o = naive_attention(q, k, v, causal=True)
    elif impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=True)
    else:
        o = chunked_attention(q, k, v, causal=True)
    o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return o @ p["wo"].astype(compute_dtype), k, v


def project_cross_kv(p: Params, enc_out: jnp.ndarray, cfg: ModelConfig,
                     compute_dtype=jnp.bfloat16):
    """Cross-attention K/V from encoder output (computed once, then cached)."""
    _, k, v = _project_qkv(p, enc_out, cfg, None, compute_dtype)
    return k, v


# ------------------------------------------------------------- decode paths

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.bfloat16, abstract: bool = False):
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if abstract:
        return {"k": jax.ShapeDtypeStruct(shape, dtype),
                "v": jax.ShapeDtypeStruct(shape, dtype)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cross_decode_attention(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                           cross_k: jnp.ndarray, cross_v: jnp.ndarray,
                           compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Decode-time cross-attention over a static encoder K/V cache."""
    B = x.shape[0]
    q, _, _ = _project_qkv(p, x, cfg, None, compute_dtype)
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kf = _repeat_kv(cross_k.astype(compute_dtype), H // Hkv)
    vf = _repeat_kv(cross_v.astype(compute_dtype), H // Hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32)
    s = s / jnp.sqrt(hd).astype(jnp.float32)
    w = jax.nn.softmax(s, axis=-1).astype(compute_dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vf).reshape(B, 1, H * hd)
    return o @ p["wo"].astype(compute_dtype)


def decode_attention(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                     cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     pos: jnp.ndarray, compute_dtype=jnp.bfloat16):
    """One-token decode. x: (B,1,D); cache_*: (B,Smax,Hkv,hd); pos is a
    scalar (every row at the same position — the training/roofline decode
    cells) or a (B,) vector of *per-row* positions (continuous-batching
    serving: each slot carries its own clock, so ragged occupancy decodes
    exactly like B independent single-sequence streams).

    Returns (out (B,1,D), new_cache_k, new_cache_v). GQA-grouped einsums —
    K/V heads are never replicated to H (a `repeat_kv` here would multiply
    the dominant HBM read of the roofline by H/Hkv). The cache sequence
    axis may be mesh-sharded (flash-decode): the softmax then reduces over
    a sharded axis and GSPMD emits tiny normalizer all-reduces instead of
    gathering the cache.
    """
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    positions = pos[:, None] if per_row else jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, compute_dtype)
    if per_row:
        # row i's K/V lands at its own position: one batched scatter
        rows = jnp.arange(B)
        cache_k = cache_k.at[rows, pos].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, pos].set(v[:, 0].astype(cache_v.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    Smax = cache_k.shape[1]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = H // Hkv
    qg = q.reshape(B, Hkv, rep, hd)                       # (B,g,r,hd)
    kf = cache_k.astype(compute_dtype)                    # (B,S,g,hd)
    vf = cache_v.astype(compute_dtype)
    s = jnp.einsum("bgrd,bsgd->bgrs", qg, kf).astype(jnp.float32)
    s = s / jnp.sqrt(hd).astype(jnp.float32)
    if per_row:
        mask = (jnp.arange(Smax)[None, :] <= pos[:, None])[:, None, None, :]
    else:
        mask = (jnp.arange(Smax) <= pos)[None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrs,bsgd->bgrd", w.astype(compute_dtype), vf)
    o = o.reshape(B, 1, H * hd)
    return o @ p["wo"].astype(compute_dtype), cache_k, cache_v
