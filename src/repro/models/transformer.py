"""Layer-stack composition for every architecture family.

All stacks scan over layers with stacked parameters (leading L axis) so the
compiled HLO contains one while-loop body per homogeneous block type — this
keeps 512-way GSPMD compiles fast and memory-bounded. Hybrid (zamba2-style)
stacks scan over *groups* of `attn_every` mamba layers followed by one
application of a weight-shared attention block.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import moe as moe_mod
from .config import ModelConfig
from .layers import mlp, mlp_init, rmsnorm, rmsnorm_init

Params = Any


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Execution knobs (hillclimb levers) — static under jit."""
    attn_impl: str = "chunked"        # naive | chunked | pallas
    remat_policy: str = "full"        # none | full | dots
    xent_chunks: int = 4
    scan_layers: bool = True
    microbatches: int = 1             # grad-accumulation inner loop
    seq_parallel: bool = False        # sequence-shard the residual stream
    moe_group: int = 256              # MoE routing group size (tokens)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if policy == "outs":
        # save each sublayer's post-all-reduce output: backward recompute
        # then skips re-running the forward TP collectives (≈1/3 of the
        # activation all-reduce traffic) for ~2×(B,S,D) bf16 per layer
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.
                              save_only_these_names(
                                  "attn_out", "mlp_out", "moe_out",
                                  "mamba_out"))
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(policy)


# -------------------------------------------------------------- block defs

def dense_block_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_mod.attention_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype,
                        cfg.mlp_gated),
    }


def dense_block(p, x, cfg: ModelConfig, ec: ExecConfig, positions, dt):
    from repro.sharding.partition import shard_constraint

    def sp(t):
        # Megatron-style sequence parallelism: the residual stream lives
        # sequence-sharded over the model axis between sublayers; GSPMD
        # turns the row-parallel all-reduce into reduce-scatter(+gather)
        # and norms/adds run 1/TP-sized.
        return shard_constraint(t, "batch", "seq", None) \
            if ec.seq_parallel else t

    h = sp(x + checkpoint_name(
        attn_mod.attention(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                           cfg, positions=positions, impl=ec.attn_impl,
                           compute_dtype=dt), "attn_out"))
    h = sp(h + checkpoint_name(
        mlp(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps), dt), "mlp_out"))
    return h


def moe_block_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_mod.attention_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "moe": moe_mod.moe_init(k2, cfg, dtype),
    }


def moe_block(p, x, cfg: ModelConfig, ec: ExecConfig, positions, dt):
    h = x + checkpoint_name(
        attn_mod.attention(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                           cfg, positions=positions, impl=ec.attn_impl,
                           compute_dtype=dt), "attn_out")
    y, aux = moe_mod.moe_mlp(p["moe"], rmsnorm(p["ln2"], h, cfg.norm_eps),
                             cfg, dt, group_size=ec.moe_group)
    return h + checkpoint_name(y, "moe_out"), aux


def mamba_block_init(key, cfg: ModelConfig, dtype):
    return {
        "ln": rmsnorm_init(cfg.d_model, dtype),
        "mamba": mamba_mod.mamba_init(key, cfg, dtype),
    }


def mamba_block(p, x, cfg: ModelConfig, dt):
    return x + checkpoint_name(
        mamba_mod.mamba_forward(p["mamba"],
                                rmsnorm(p["ln"], x, cfg.norm_eps),
                                cfg, dt), "mamba_out")


def encdec_block_init(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_mod.attention_init(k1, cfg, dtype),
        "ln_x": rmsnorm_init(cfg.d_model, dtype),
        "cross": attn_mod.attention_init(k2, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype,
                        cfg.mlp_gated),
    }


def encdec_block(p, x, enc_out, cfg: ModelConfig, ec: ExecConfig, positions, dt):
    h = x + attn_mod.attention(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                               cfg, positions=positions, impl=ec.attn_impl,
                               compute_dtype=dt)
    h = h + attn_mod.attention(p["cross"], rmsnorm(p["ln_x"], h, cfg.norm_eps),
                               cfg, kv_input=enc_out, impl=ec.attn_impl,
                               compute_dtype=dt)
    h = h + mlp(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps), dt)
    return h


# ------------------------------------------------------------- stack: init

def _stack_init(key, n: int, block_init, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(k, *args))(keys)


def stack_init(key, cfg: ModelConfig, dtype) -> Params:
    """Stacked layer params for the decoder stack of any family."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"layers": _stack_init(key, cfg.n_layers, dense_block_init, cfg, dtype)}
    if fam == "moe":
        return {"layers": _stack_init(key, cfg.n_layers, moe_block_init, cfg, dtype)}
    if fam == "ssm":
        return {"layers": _stack_init(key, cfg.n_layers, mamba_block_init, cfg, dtype)}
    if fam == "hybrid":
        k1, k2, k3 = jax.random.split(key, 3)
        G, tail = divmod(cfg.n_layers, cfg.attn_every)
        p = {"shared": dense_block_init(k1, cfg, dtype)}
        grouped = _stack_init(k2, G * cfg.attn_every, mamba_block_init, cfg, dtype)
        p["layers"] = jax.tree.map(
            lambda a: a.reshape(G, cfg.attn_every, *a.shape[1:]), grouped)
        if tail:
            p["tail"] = _stack_init(k3, tail, mamba_block_init, cfg, dtype)
        return p
    if fam == "encdec":
        k1, k2 = jax.random.split(key)
        return {
            "enc_layers": _stack_init(k1, cfg.n_enc_layers, dense_block_init, cfg, dtype),
            "layers": _stack_init(k2, cfg.n_layers, encdec_block_init, cfg, dtype),
        }
    raise ValueError(fam)


# ---------------------------------------------------------- stack: forward

def _scan_blocks(body, x, layers, ec: ExecConfig):
    body = _remat(body, ec.remat_policy)
    if ec.scan_layers:
        x, aux = jax.lax.scan(body, x, layers)
        return x, jnp.sum(aux)
    n = jax.tree.leaves(layers)[0].shape[0]
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(n):
        x, aux = body(x, jax.tree.map(lambda a: a[i], layers))
        aux_total = aux_total + aux
    return x, aux_total


def stack_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig, ec: ExecConfig,
                  positions, dt, enc_out: Optional[jnp.ndarray] = None):
    """x: (B,S,D) -> ((B,S,D), aux_loss)."""
    fam = cfg.family

    if fam in ("dense", "vlm"):
        def body(h, lp):
            return dense_block(lp, h, cfg, ec, positions, dt), jnp.zeros((), jnp.float32)
        return _scan_blocks(body, x, p["layers"], ec)

    if fam == "moe":
        def body(h, lp):
            h, aux = moe_block(lp, h, cfg, ec, positions, dt)
            return h, aux
        return _scan_blocks(body, x, p["layers"], ec)

    if fam == "ssm":
        def body(h, lp):
            return mamba_block(lp, h, cfg, dt), jnp.zeros((), jnp.float32)
        return _scan_blocks(body, x, p["layers"], ec)

    if fam == "hybrid":
        shared = p["shared"]

        def group_body(h, gp):
            def inner(hh, lp):
                return mamba_block(lp, hh, cfg, dt), None
            h, _ = jax.lax.scan(inner, h, gp)
            h = dense_block(shared, h, cfg, ec, positions, dt)
            return h, jnp.zeros((), jnp.float32)

        x, aux = _scan_blocks(group_body, x, p["layers"], ec)
        if "tail" in p:
            def tail_body(h, lp):
                return mamba_block(lp, h, cfg, dt), jnp.zeros((), jnp.float32)
            x, aux2 = _scan_blocks(tail_body, x, p["tail"], ec)
            aux = aux + aux2
        return x, aux

    if fam == "encdec":
        assert enc_out is not None

        def body(h, lp):
            return encdec_block(lp, h, enc_out, cfg, ec, positions, dt), \
                jnp.zeros((), jnp.float32)
        return _scan_blocks(body, x, p["layers"], ec)

    raise ValueError(fam)


def encoder_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                    ec: ExecConfig, dt):
    """Bidirectional encoder for enc-dec archs. x: (B,S_enc,D)."""
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, lp):
        h2 = h + attn_mod.attention(
            lp["attn"], rmsnorm(lp["ln1"], h, cfg.norm_eps), cfg,
            positions=positions, causal=False, impl=ec.attn_impl,
            compute_dtype=dt)
        h2 = h2 + mlp(lp["mlp"], rmsnorm(lp["ln2"], h2, cfg.norm_eps), dt)
        return h2, jnp.zeros((), jnp.float32)

    out, _ = _scan_blocks(body, x, p["enc_layers"], ec)
    return out
