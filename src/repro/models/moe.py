"""Top-k routed Mixture-of-Experts MLP with expert-parallel dispatch.

Uses the grouped capacity-factor dispatch/combine einsum formulation
(GShard / Mesh-TF style): tokens are split into fixed-size groups; within a
group, routing produces dispatch (g, E, C) one-hot tensors that turn token
shuffling into dense einsums. GSPMD converts the expert contraction into an
all_to_all when the expert axis is mesh-sharded ("model" axis = EP in our
rules). This is the TPU-native adaptation — no scatter/gather, MXU-friendly,
and dispatch memory is O(k·cf·group²) per group instead of O(k·cf·T²).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.partition import shard_constraint

from .config import ModelConfig
from .layers import _init

Params = Any


def moe_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": _init(ks[0], (D, E), dtype),
        "wi_gate": _init(ks[1], (E, D, F), dtype),
        "wi_up": _init(ks[2], (E, D, F), dtype),
        "wo": _init(ks[3], (E, F, D), dtype),
    }


def _top_k_routing(logits: jnp.ndarray, k: int, capacity: int):
    """logits: (G, g, E) -> dispatch (G,g,E,C), combine (G,g,E,C), aux scalar.

    Position-based capacity assignment per group: tokens beyond an expert's
    per-group capacity are dropped (standard capacity-factor semantics).
    """
    G, g, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # one-hot per choice: (G, k, g, E), choice-major queue order
    choice_oh = jax.nn.one_hot(gate_idx.transpose(0, 2, 1), E,
                               dtype=jnp.float32)
    flat = choice_oh.reshape(G, k * g, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(G, k, g, E)
    keep = pos_in_expert < capacity
    slot = jnp.sum(pos_in_expert * choice_oh, axis=-1).astype(jnp.int32)
    cap_oh = jax.nn.one_hot(slot, capacity, dtype=jnp.float32)  # (G, k, g, C)
    kept_oh = choice_oh * keep
    dispatch = jnp.einsum("Gkte,Gktc->Gtec", kept_oh, cap_oh)
    combine = jnp.einsum("Gkte,Gktc,Gtk->Gtec", kept_oh, cap_oh,
                         gate_vals.astype(jnp.float32))
    aux = _load_balance_loss(probs, choice_oh)
    return dispatch, combine, aux


def _load_balance_loss(probs: jnp.ndarray, choice_oh: jnp.ndarray) -> jnp.ndarray:
    """Switch-style aux loss: E * dot(mean_prob, mean_top1_assignment)."""
    E = probs.shape[-1]
    density = jnp.mean(choice_oh[:, 0], axis=(0, 1))   # top-1 assignment share
    mean_prob = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(density * mean_prob)


def moe_mlp(p: Params, x: jnp.ndarray, cfg: ModelConfig,
            compute_dtype=jnp.bfloat16, group_size: int = 512):
    """x: (B, S, D) -> (y (B,S,D), aux_loss scalar)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.experts_per_token
    g = min(group_size, T)
    if T % g != 0:                       # tiny smoke shapes: one group
        g = T
    G = T // g
    capacity = max(int(cfg.capacity_factor * k * g / E), 1)
    capacity = max((capacity + 3) // 4 * 4, 4)   # pad to a lane-friendly size

    xt = x.reshape(G, g, D)
    xt = shard_constraint(xt, "batch", None, None)
    logits = xt.astype(compute_dtype) @ p["router"].astype(compute_dtype)
    dispatch, combine, aux = _top_k_routing(logits, k, capacity)

    # (G,g,E,C) x (G,g,D) -> (G,E,C,D); GSPMD turns the expert contraction
    # into an all_to_all when E is mesh-sharded and G is data-sharded.
    xe = jnp.einsum("Gtec,Gtd->Gecd", dispatch.astype(compute_dtype),
                    xt.astype(compute_dtype))
    xe = shard_constraint(xe, "batch", "expert", None, None)
    gt = jnp.einsum("Gecd,edf->Gecf", xe, p["wi_gate"].astype(compute_dtype))
    up = jnp.einsum("Gecd,edf->Gecf", xe, p["wi_up"].astype(compute_dtype))
    h = jax.nn.silu(gt) * up
    ye = jnp.einsum("Gecf,efd->Gecd", h, p["wo"].astype(compute_dtype))
    ye = shard_constraint(ye, "batch", "expert", None, None)
    y = jnp.einsum("Gtec,Gecd->Gtd", combine.astype(compute_dtype), ye)
    return y.reshape(B, S, D), aux.astype(jnp.float32)
