"""Unified Model API: config -> init / loss_fn / prefill / decode_step.

One class drives every assigned architecture family:

  dense | vlm    decoder-only transformer (vlm prepends patch embeddings)
  moe            decoder-only with routed-expert MLPs
  ssm            Mamba1 stack (attention-free)
  hybrid         Mamba2 stack + weight-shared attention block every K layers
  encdec         encoder-decoder (audio frontend stubbed as frame embeddings)

All step functions are pure (params, batch) -> outputs so they can be jitted
under any mesh. `input_specs` returns ShapeDtypeStruct stand-ins for every
input of the train/prefill/decode step of a given shape cell — the dry-run
lowers against these with zero allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import moe as moe_mod
from .config import ModelConfig, ShapeConfig
from .layers import (dense, dense_init, embed, embedding_init,
                     rmsnorm, rmsnorm_init, unembed)
from .transformer import (ExecConfig, encoder_forward,
                          stack_forward, stack_init)

Params = Any


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def masked_chunked_xent(table: jnp.ndarray, x: jnp.ndarray,
                        labels: jnp.ndarray, compute_dtype,
                        n_chunks: int = 8) -> jnp.ndarray:
    """Cross-entropy over sequence chunks; labels < 0 are ignored.

    Never materializes the full (B,S,V) logits — peak logit memory is
    (B, S/n_chunks, V) inside one scan iteration.
    """
    B, S, _ = x.shape
    if S % n_chunks != 0:
        n_chunks = 1
    tbl = table.astype(compute_dtype)
    xs = x.reshape(B, n_chunks, S // n_chunks, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def body(carry, xl):
        tot, cnt = carry
        xc, lc = xl
        valid = (lc >= 0)
        lc_safe = jnp.maximum(lc, 0)
        logits = xc.astype(compute_dtype) @ tbl.T
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, lc_safe[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * valid
        return (tot + jnp.sum(nll), cnt + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls))
    return tot / jnp.maximum(cnt, 1.0)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    ec: ExecConfig = ExecConfig()

    # ------------------------------------------------------------- params

    def init(self, key) -> Params:
        cfg = self.cfg
        dtype = _pdt(cfg)
        k_emb, k_stack, k_front = jax.random.split(key, 3)
        params = {
            "embedding": embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "stack": stack_init(k_stack, cfg, dtype),
            "ln_f": rmsnorm_init(cfg.d_model, dtype),
        }
        if cfg.frontend:
            params["frontend_proj"] = dense_init(
                k_front, cfg.d_model, cfg.d_model, dtype)
        if cfg.family == "encdec":
            params["ln_enc"] = rmsnorm_init(cfg.d_model, dtype)
        return params

    def abstract_params(self) -> Params:
        """ShapeDtypeStruct pytree of the parameters (no allocation)."""
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------ forward

    def _embed_inputs(self, params, batch, dt):
        """Token embedding (+frontend overwrite for vlm)."""
        cfg = self.cfg
        x = embed(params["embedding"], batch["tokens"], dt)
        if cfg.family == "vlm" and "frontend_emb" in batch:
            fe = dense(params["frontend_proj"], batch["frontend_emb"], dt)
            nf = fe.shape[1]
            x = jnp.concatenate([fe, x[:, nf:]], axis=1)
        return x

    def forward(self, params, batch):
        """Full-sequence forward -> (hidden (B,S,D), aux_loss)."""
        cfg, ec = self.cfg, self.ec
        dt = _dt(cfg)
        x = self._embed_inputs(params, batch, dt)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        enc_out = None
        if cfg.family == "encdec":
            fe = dense(params["frontend_proj"], batch["enc_emb"], dt)
            enc_out = encoder_forward(params["stack"], fe, cfg, ec, dt)
            enc_out = rmsnorm(params["ln_enc"], enc_out, cfg.norm_eps)
        h, aux = stack_forward(params["stack"], x, cfg, ec, positions, dt,
                               enc_out=enc_out)
        h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
        return h, aux

    def logits(self, params, batch):
        """(B,S,V) logits — small-model/test path only."""
        h, aux = self.forward(params, batch)
        return unembed(params["embedding"], h, _dt(self.cfg)), aux

    def loss_fn(self, params, batch):
        """Mean token cross-entropy + MoE aux. Returns (loss, metrics)."""
        cfg = self.cfg
        h, aux = self.forward(params, batch)
        xent = masked_chunked_xent(params["embedding"]["table"], h,
                                   batch["labels"], _dt(cfg),
                                   n_chunks=self.ec.xent_chunks)
        loss = xent + 0.01 * aux
        return loss, {"xent": xent, "aux": aux}

    # ------------------------------------------------------- decode state

    def init_decode_state(self, batch: int, max_len: int, *,
                          abstract: bool = False):
        cfg = self.cfg
        dt = _dt(cfg)

        def mk(shape, dtype):
            if abstract:
                return jax.ShapeDtypeStruct(shape, dtype)
            return jnp.zeros(shape, dtype)

        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            s = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            return {"k": mk(s, dt), "v": mk(s, dt)}
        if fam == "ssm":
            st = mamba_mod.mamba_init_state(cfg, batch, jnp.float32, abstract)
            return jax.tree.map(
                lambda a: (jax.ShapeDtypeStruct((cfg.n_layers,) + a.shape, a.dtype)
                           if abstract else
                           jnp.zeros((cfg.n_layers,) + a.shape, a.dtype)),
                st)
        if fam == "hybrid":
            G, tail = divmod(cfg.n_layers, cfg.attn_every)
            st = mamba_mod.mamba_init_state(cfg, batch, jnp.float32, abstract)

            def grouped(a, lead):
                shape = lead + a.shape
                return jax.ShapeDtypeStruct(shape, a.dtype) if abstract \
                    else jnp.zeros(shape, a.dtype)

            out = {"mamba": jax.tree.map(
                lambda a: grouped(a, (G, cfg.attn_every)), st)}
            if tail:
                out["tail"] = jax.tree.map(lambda a: grouped(a, (tail,)), st)
            s = (G, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            out["attn"] = {"k": mk(s, dt), "v": mk(s, dt)}
            return out
        if fam == "encdec":
            s = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            sx = (cfg.n_layers, batch, cfg.enc_seq_len, cfg.n_kv_heads,
                  cfg.head_dim)
            return {"k": mk(s, dt), "v": mk(s, dt),
                    "cross_k": mk(sx, dt), "cross_v": mk(sx, dt)}
        raise ValueError(fam)

    # ------------------------------------------------------------ prefill

    def prefill(self, params, batch, max_len: int):
        """Process a prompt; returns (last-position logits, decode state).

        The returned KV caches are padded to max_len so decode can continue
        in place.
        """
        cfg, ec = self.cfg, self.ec
        dt = _dt(cfg)
        fam = cfg.family
        x = self._embed_inputs(params, batch, dt)
        B, S, _ = x.shape
        positions = jnp.arange(S)[None, :]

        def pad_cache(c):   # (L,B,S,H,hd) -> (L,B,max_len,H,hd)
            pad = max_len - c.shape[2]
            if pad <= 0:
                return c
            return jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))

        if fam in ("dense", "moe", "vlm"):
            def body(h, lp):
                hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
                o, k, v = attn_mod.attention_with_kv(
                    lp["attn"], hn, cfg, positions=positions,
                    impl=ec.attn_impl, compute_dtype=dt)
                h = h + o
                hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
                if fam == "moe":
                    y, _ = moe_mod.moe_mlp(lp["moe"], hn, cfg, dt,
                                           group_size=self.ec.moe_group)
                else:
                    from .layers import mlp
                    y = mlp(lp["mlp"], hn, dt)
                return h + y, (k.astype(dt), v.astype(dt))

            x, (ks, vs) = jax.lax.scan(body, x, params["stack"]["layers"])
            state = {"k": pad_cache(ks), "v": pad_cache(vs)}

        elif fam == "ssm":
            def body(h, lp):
                hn = rmsnorm(lp["ln"], h, cfg.norm_eps)
                y, st = mamba_mod.mamba_forward_with_state(lp["mamba"], hn,
                                                           cfg, dt)
                return h + y, st

            x, states = jax.lax.scan(body, x, params["stack"]["layers"])
            state = states

        elif fam == "hybrid":
            shared = params["stack"]["shared"]

            def group_body(h, xs):
                gp, = xs

                def inner(hh, lp):
                    hn = rmsnorm(lp["ln"], hh, cfg.norm_eps)
                    y, st = mamba_mod.mamba_forward_with_state(
                        lp["mamba"], hn, cfg, dt)
                    return hh + y, st

                h, sts = jax.lax.scan(inner, h, gp)
                hn = rmsnorm(shared["ln1"], h, cfg.norm_eps)
                o, k, v = attn_mod.attention_with_kv(
                    shared["attn"], hn, cfg, positions=positions,
                    impl=ec.attn_impl, compute_dtype=dt)
                h = h + o
                from .layers import mlp
                h = h + mlp(shared["mlp"],
                            rmsnorm(shared["ln2"], h, cfg.norm_eps), dt)
                return h, (sts, k.astype(dt), v.astype(dt))

            x, (msts, ks, vs) = jax.lax.scan(
                group_body, x, (params["stack"]["layers"],))
            state = {"mamba": msts, "attn": {"k": pad_cache(ks),
                                             "v": pad_cache(vs)}}
            if "tail" in params["stack"]:
                def tail_body(h, lp):
                    hn = rmsnorm(lp["ln"], h, cfg.norm_eps)
                    y, st = mamba_mod.mamba_forward_with_state(
                        lp["mamba"], hn, cfg, dt)
                    return h + y, st
                x, tsts = jax.lax.scan(tail_body, x,
                                       params["stack"]["tail"])
                state["tail"] = tsts

        elif fam == "encdec":
            fe = dense(params["frontend_proj"], batch["enc_emb"], dt)
            enc_out = encoder_forward(params["stack"], fe, cfg, ec, dt)
            enc_out = rmsnorm(params["ln_enc"], enc_out, cfg.norm_eps)

            def body(h, lp):
                hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
                o, k, v = attn_mod.attention_with_kv(
                    lp["attn"], hn, cfg, positions=positions,
                    impl=ec.attn_impl, compute_dtype=dt)
                h = h + o
                hx = rmsnorm(lp["ln_x"], h, cfg.norm_eps)
                h = h + attn_mod.attention(lp["cross"], hx, cfg,
                                           kv_input=enc_out,
                                           impl=ec.attn_impl,
                                           compute_dtype=dt)
                ck, cv = attn_mod.project_cross_kv(lp["cross"], enc_out,
                                                   cfg, dt)
                from .layers import mlp
                h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps), dt)
                return h, (k.astype(dt), v.astype(dt),
                           ck.astype(dt), cv.astype(dt))

            x, (ks, vs, cks, cvs) = jax.lax.scan(body, x,
                                                 params["stack"]["layers"])
            state = {"k": pad_cache(ks), "v": pad_cache(vs),
                     "cross_k": cks, "cross_v": cvs}
        else:
            raise ValueError(fam)

        h = rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
        logits = unembed(params["embedding"], h, dt)
        return logits, state

    # -------------------------------------------------------- decode step

    def decode_step(self, params, token, state, pos):
        """One-token decode. token: (B,1) int32; pos: scalar int32, or a
        (B,) int32 vector of per-row positions (the serving engine's
        continuous batching — see models.attention.decode_attention).

        Returns (logits (B,1,V), new_state). The KV/SSM state threading is
        what the serve_step lowers for the decode_* roofline cells.
        """
        cfg = self.cfg
        dt = _dt(cfg)
        fam = cfg.family
        x = embed(params["embedding"], token, dt)

        if fam in ("dense", "moe", "vlm"):
            def body(h, xs):
                lp, ck, cv = xs
                hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
                o, ck, cv = attn_mod.decode_attention(
                    lp["attn"], hn, cfg, cache_k=ck, cache_v=cv, pos=pos,
                    compute_dtype=dt)
                h = h + o
                hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
                if fam == "moe":
                    y, _ = moe_mod.moe_mlp(lp["moe"], hn, cfg, dt,
                                           group_size=self.ec.moe_group)
                else:
                    from .layers import mlp
                    y = mlp(lp["mlp"], hn, dt)
                return h + y, (ck, cv)

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["stack"]["layers"], state["k"], state["v"]))
            new_state = {"k": ks, "v": vs}

        elif fam == "ssm":
            def body(h, xs):
                lp, st = xs
                hn = rmsnorm(lp["ln"], h, cfg.norm_eps)
                y, st = mamba_mod.mamba_step(lp["mamba"], hn, st, cfg, dt)
                return h + y, st

            x, new_state = jax.lax.scan(
                body, x, (params["stack"]["layers"], state))

        elif fam == "hybrid":
            shared = params["stack"]["shared"]

            def group_body(h, xs):
                gp, mst, ck, cv = xs

                def inner(hh, ys):
                    lp, st = ys
                    hn = rmsnorm(lp["ln"], hh, cfg.norm_eps)
                    y, st = mamba_mod.mamba_step(lp["mamba"], hn, st, cfg, dt)
                    return hh + y, st

                h, msts = jax.lax.scan(inner, h, (gp, mst))
                hn = rmsnorm(shared["ln1"], h, cfg.norm_eps)
                o, ck, cv = attn_mod.decode_attention(
                    shared["attn"], hn, cfg, cache_k=ck, cache_v=cv, pos=pos,
                    compute_dtype=dt)
                h = h + o
                from .layers import mlp
                h = h + mlp(shared["mlp"],
                            rmsnorm(shared["ln2"], h, cfg.norm_eps), dt)
                return h, (msts, ck, cv)

            x, (msts, ks, vs) = jax.lax.scan(
                group_body, x,
                (params["stack"]["layers"], state["mamba"],
                 state["attn"]["k"], state["attn"]["v"]))
            new_state = {"mamba": msts, "attn": {"k": ks, "v": vs}}
            if "tail" in state:
                def tail_body(h, xs):
                    lp, st = xs
                    hn = rmsnorm(lp["ln"], h, cfg.norm_eps)
                    y, st = mamba_mod.mamba_step(lp["mamba"], hn, st, cfg, dt)
                    return h + y, st
                x, tsts = jax.lax.scan(
                    tail_body, x, (params["stack"]["tail"], state["tail"]))
                new_state["tail"] = tsts

        elif fam == "encdec":
            def body(h, xs):
                lp, ck, cv, xk, xv = xs
                hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
                o, ck, cv = attn_mod.decode_attention(
                    lp["attn"], hn, cfg, cache_k=ck, cache_v=cv, pos=pos,
                    compute_dtype=dt)
                h = h + o
                hx = rmsnorm(lp["ln_x"], h, cfg.norm_eps)
                h = h + attn_mod.cross_decode_attention(
                    lp["cross"], hx, cfg, cross_k=xk, cross_v=xv,
                    compute_dtype=dt)
                from .layers import mlp
                h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps), dt)
                return h, (ck, cv)

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["stack"]["layers"], state["k"], state["v"],
                          state["cross_k"], state["cross_v"]))
            new_state = {"k": ks, "v": vs,
                         "cross_k": state["cross_k"],
                         "cross_v": state["cross_v"]}
        else:
            raise ValueError(fam)

        h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = unembed(params["embedding"], h, dt)
        return logits, new_state

    # ------------------------------------------------- decode state specs

    def decode_state_specs(self, rules):
        """PartitionSpec pytree matching init_decode_state's structure:
        batch over DP axes, heads/d_inner over the model axis."""
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        b, h = rules.batch, rules.kv_heads
        hh = rules.heads
        ks = getattr(rules, "kv_seq", None)
        fam = cfg.family

        def kv(lead=1):
            return {"k": P(*(None,) * lead, b, ks, h, None),
                    "v": P(*(None,) * lead, b, ks, h, None)}

        if fam in ("dense", "moe", "vlm"):
            return kv()
        if fam == "ssm":
            if cfg.ssm_version == 1:
                return {"h": P(None, b, hh, None),
                        "conv": P(None, b, None, hh)}
            return {"h": P(None, b, hh, None, None),
                    "conv": P(None, b, None, hh)}
        if fam == "hybrid":
            if cfg.ssm_version == 1:
                m = {"h": P(None, None, b, hh, None),
                     "conv": P(None, None, b, None, hh)}
                t = {"h": P(None, b, hh, None),
                     "conv": P(None, b, None, hh)}
            else:
                m = {"h": P(None, None, b, hh, None, None),
                     "conv": P(None, None, b, None, hh)}
                t = {"h": P(None, b, hh, None, None),
                     "conv": P(None, b, None, hh)}
            out = {"mamba": m, "attn": kv(lead=1)}
            if cfg.n_layers % cfg.attn_every:
                out["tail"] = t
            return out
        if fam == "encdec":
            d = kv()
            d["cross_k"] = P(None, b, None, h, None)
            d["cross_v"] = P(None, b, None, h, None)
            return d
        raise ValueError(fam)

    # --------------------------------------------------------- input specs

    def input_specs(self, shape: ShapeConfig, *, abstract: bool = True):
        """Inputs for the step function of a shape cell (SDS stand-ins).

        train  -> {"tokens","labels"} (+"enc_emb"/"frontend_emb")
        prefill-> {"tokens"} (+frontend inputs)
        decode -> {"token","pos","state"}
        """
        cfg = self.cfg
        dt = _dt(cfg)
        B, S = shape.global_batch, shape.seq_len

        def mk(shp, dtype):
            if abstract:
                return jax.ShapeDtypeStruct(shp, dtype)
            if dtype == jnp.int32:
                return jnp.zeros(shp, dtype)
            return jnp.zeros(shp, dtype)

        def frontend_inputs(d):
            if cfg.family == "encdec":
                d["enc_emb"] = mk((B, cfg.enc_seq_len, cfg.d_model), dt)
            elif cfg.family == "vlm":
                nf = min(cfg.n_frontend_tokens, S // 2)
                d["frontend_emb"] = mk((B, nf, cfg.d_model), dt)
            return d

        if shape.kind == "train":
            return frontend_inputs({
                "tokens": mk((B, S), jnp.int32),
                "labels": mk((B, S), jnp.int32),
            })
        if shape.kind == "prefill":
            return frontend_inputs({"tokens": mk((B, S), jnp.int32)})
        if shape.kind == "decode":
            return {
                "token": mk((B, 1), jnp.int32),
                "pos": mk((), jnp.int32),
                "state": self.init_decode_state(B, S, abstract=abstract),
            }
        raise ValueError(shape.kind)
