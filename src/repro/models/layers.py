"""Primitive layers shared by all architectures.

Everything is a pure function over an explicit parameter pytree; parameter
initializers return pytrees of arrays (or ShapeDtypeStructs in abstract mode)
so the same code paths drive real training, smoke tests and the dry-run.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


# ---------------------------------------------------------------- init utils

def _init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) > 1 else max(shape[-1], 1)
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, bias=False):
    p = {"w": _init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    y = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# ------------------------------------------------------------------ rmsnorm

def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- gated mlp

def mlp_init(key, d_model, d_ff, dtype, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi_up": _init(k2, (d_model, d_ff), dtype),
        "wo": _init(k3, (d_ff, d_model), dtype),
    }
    if gated:
        p["wi_gate"] = _init(k1, (d_model, d_ff), dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    xc = x.astype(compute_dtype)
    u = xc @ p["wi_up"].astype(compute_dtype)
    if "wi_gate" in p:
        g = xc @ p["wi_gate"].astype(compute_dtype)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(u)
    return h @ p["wo"].astype(compute_dtype)


# --------------------------------------------------------------- embeddings

def embedding_init(key, vocab, d_model, dtype):
    return {"table": _init(key, (vocab, d_model), dtype, scale=1.0)}


def embed(p: Params, tokens: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return p["table"].astype(compute_dtype)[tokens]


def unembed(p: Params, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return x.astype(compute_dtype) @ p["table"].astype(compute_dtype).T


# --------------------------------------------------- chunked cross-entropy

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token cross-entropy in fp32. logits: (B,S,V); labels: (B,S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_unembed_xent(emb_p: Params, x: jnp.ndarray, labels: jnp.ndarray,
                         compute_dtype, n_chunks: int = 4) -> jnp.ndarray:
    """Cross-entropy without materializing full (B,S,V) logits.

    Scans over sequence chunks; each chunk's logits live only inside one scan
    iteration, cutting peak activation memory by n_chunks.
    """
    B, S, _ = x.shape
    if S % n_chunks != 0:
        logits = unembed(emb_p, x, compute_dtype)
        return cross_entropy(logits, labels)
    xs = x.reshape(B, n_chunks, S // n_chunks, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)
    table = emb_p["table"].astype(compute_dtype)

    def body(carry, xl):
        xc, lc = xl
        logits = xc.astype(compute_dtype) @ table.T
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (B * S)
