from .manifest import Manifest, flatten_state, unflatten_state, tree_digest
from .file_ckpt import FileCheckpointer
from .memory_ckpt import BuddyStore, buddy_exchange, restore_from_buddy
from .policy import CheckpointPolicy, checkpoint_kind_for
