from . import serde
from .manifest import (Manifest, flatten_leaves, flatten_state, tree_digest,
                       unflatten_state)
from .file_ckpt import FileCheckpointer
from .memory_ckpt import BuddyStore, buddy_exchange, restore_from_buddy
from .policy import CheckpointPolicy, checkpoint_kind_for
