"""Zero-copy framed binary serialization for checkpoint payloads.

Replaces np.savez (zip framing, per-entry CRC, mandatory copies) on the
file path and the ad-hoc `step || raw bytes` payloads on the buddy/TCP
path with one self-describing frame:

    offset 0      magic       8 bytes   b"RPROCKP1"
    offset 8      header_len  u32 LE    byte length of the JSON header
    offset 12     reserved    u32 LE    0 (format flags, future use)
    offset 16     header      UTF-8 JSON, header_len bytes
    ...           zero pad to the next 64-byte boundary
    data          raw little-endian C-contiguous leaf bytes, each leaf
                  starting on a 64-byte boundary, in header order

    header JSON: {"version": 1,
                  "extra":  {...user metadata...},
                  "leaves": [{"path", "dtype", "shape",
                              "offset", "nbytes"}, ...]}

Design points:

  - *Writes are gather-free*: `write_file` streams each leaf's uint8 view
    straight into the file and `to_bytes` fills one preallocated buffer
    through memoryviews — no per-leaf `tobytes()`, no zip deflate/CRC.
  - *Reads are zero-copy*: `from_bytes`/`open_file` return ndarray views
    into the source buffer; `open_file(mmap=True)` backs them with
    np.memmap so `load_latest` maps shards instead of reading them, and
    pages fault in lazily as verification/restore touches them.
  - 64-byte alignment keeps every leaf cache-line- and SIMD-aligned and
    lets a future device DMA consume the mapping directly.
  - dtype names round-trip through ml_dtypes (bfloat16 & friends).

Integrity is *not* this layer's job — digests live in manifest.json
(file path) or the control message (buddy path), so corruption checks
can run per-shard in parallel against the mapped views.
"""
from __future__ import annotations

import json
import struct
from typing import Any, Dict, Tuple

import numpy as np

MAGIC = b"RPROCKP1"
ALIGN = 64
_FIXED = struct.Struct("<8sII")      # magic, header_len, reserved
VERSION = 1


def _dtype(name: str) -> np.dtype:
    """Resolve a dtype name, falling back to ml_dtypes for bf16 etc."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _leaf_bytes(arr) -> np.ndarray:
    """Flat uint8 view of a host array (copies only if non-contiguous)."""
    from repro.kernels.checksum.ref import byte_view
    return byte_view(np.asarray(arr))


def _align(n: int) -> int:
    return -(-n // ALIGN) * ALIGN


def _layout(flat: Dict[str, Any], extra: dict | None
            ) -> Tuple[bytes, list, int]:
    """Returns (prefix_bytes, [(path, uint8_view, offset)], frame_size).

    prefix = fixed header + JSON + pad; offsets are absolute in-frame.
    """
    views = {k: _leaf_bytes(v) for k, v in flat.items()}
    entries = [{"path": k,
                "dtype": str(getattr(flat[k], "dtype",
                                     np.asarray(flat[k]).dtype)),
                "shape": list(np.shape(flat[k])),
                "offset": 0, "nbytes": int(views[k].size)}
               for k in flat]
    # Offsets depend on the header's byte length, which depends on the
    # offsets' digit counts — iterate to a fixpoint. Offsets (and hence
    # the header length) only ever grow, so this converges in a couple
    # of rounds; the loop exits with `header` serialized from exactly
    # the offsets the data will be written at.
    while True:
        header = json.dumps({"version": VERSION, "extra": extra or {},
                             "leaves": entries},
                            separators=(",", ":")).encode()
        off = _align(_FIXED.size + len(header))
        changed = False
        for e in entries:
            if e["offset"] != off:
                e["offset"] = off
                changed = True
            off += _align(e["nbytes"])
        if not changed:
            break
    data_start = _align(_FIXED.size + len(header))
    prefix = _FIXED.pack(MAGIC, len(header), 0) + header
    prefix += b"\0" * (data_start - len(prefix))
    placed = [(e["path"], views[e["path"]], e["offset"]) for e in entries]
    return prefix, placed, off


def frame_size(flat: Dict[str, Any], extra: dict | None = None) -> int:
    return _layout(flat, extra)[2]


def to_bytes(flat: Dict[str, Any], extra: dict | None = None) -> bytes:
    """Serialize {path: array} into one frame (single preallocated buffer,
    leaves copied in via memoryview — no intermediate tobytes)."""
    prefix, placed, size = _layout(flat, extra)
    buf = bytearray(size)
    buf[:len(prefix)] = prefix
    mv = memoryview(buf)
    for _, view, off in placed:
        mv[off:off + view.size] = memoryview(view)
    return bytes(buf)


def write_file(path: str, flat: Dict[str, Any],
               extra: dict | None = None) -> int:
    """Stream a frame to `path`; returns bytes written. Leaf bytes go
    straight from the array's buffer to the file."""
    prefix, placed, size = _layout(flat, extra)
    with open(path, "wb") as f:
        f.write(prefix)
        pos = len(prefix)
        for _, view, off in placed:
            if off > pos:
                f.write(b"\0" * (off - pos))
            f.write(memoryview(view))
            pos = off + view.size
        if size > pos:
            f.write(b"\0" * (size - pos))
    return size


def _parse(buf) -> Tuple[dict, Dict[str, np.ndarray]]:
    """buf: bytes / bytearray / memmap. Returns (extra, {path: view})."""
    head = bytes(buf[:_FIXED.size])
    if len(head) < _FIXED.size:
        raise IOError("serde frame truncated (no fixed header)")
    magic, hlen, _ = _FIXED.unpack(head)
    if magic != MAGIC:
        raise IOError(f"bad serde magic {magic!r}")
    try:
        header = json.loads(bytes(buf[_FIXED.size:_FIXED.size + hlen]))
    except ValueError as e:
        raise IOError(f"serde header corrupt: {e}") from None
    is_arr = isinstance(buf, np.ndarray)
    mv = buf if is_arr else memoryview(buf)      # slices stay zero-copy
    flat: Dict[str, np.ndarray] = {}
    for e in header["leaves"]:
        off, n = e["offset"], e["nbytes"]
        raw = mv[off:off + n]
        if len(raw) != n:
            raise IOError(f"serde frame truncated at leaf {e['path']}")
        dt = _dtype(e["dtype"])
        if is_arr:                               # memmap slice: stay mapped
            arr = raw.view(dt)
        else:
            arr = np.frombuffer(raw, dtype=dt)
        flat[e["path"]] = arr.reshape(e["shape"])
    return header.get("extra", {}), flat


def from_bytes(buf: bytes) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Parse a frame into (extra, {path: ndarray view}). Views are
    read-only windows onto `buf` — np.array(view) to get writable."""
    return _parse(buf)


def open_file(path: str, *, mmap: bool = True
              ) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Map (default) or read a frame file. With mmap, leaves are memmap
    views — the OS pages them in on first touch, so restore cost is paid
    only for the bytes actually consumed."""
    if mmap:
        mm = np.memmap(path, dtype=np.uint8, mode="r")
        return _parse(mm)
    with open(path, "rb") as f:
        return _parse(f.read())
