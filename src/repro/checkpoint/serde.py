"""Zero-copy framed binary serialization for checkpoint payloads.

Replaces np.savez (zip framing, per-entry CRC, mandatory copies) on the
file path and the ad-hoc `step || raw bytes` payloads on the buddy/TCP
path with one self-describing frame:

    offset 0      magic       8 bytes   b"RPROCKP1"
    offset 8      header_len  u32 LE    byte length of the JSON header
    offset 12     reserved    u32 LE    0 (format flags, future use)
    offset 16     header      UTF-8 JSON, header_len bytes
    ...           zero pad to the next 64-byte boundary
    data          raw little-endian C-contiguous leaf bytes, each leaf
                  starting on a 64-byte boundary, in header order

    header JSON: {"version": 1,
                  "extra":  {...user metadata...},
                  "leaves": [{"path", "dtype", "shape",
                              "offset", "nbytes"}, ...]}

Design points:

  - *Writes are gather-free*: `write_file` streams each leaf's uint8 view
    straight into the file and `to_bytes` fills one preallocated buffer
    through memoryviews — no per-leaf `tobytes()`, no zip deflate/CRC.
  - *Reads are zero-copy*: `from_bytes`/`open_file` return ndarray views
    into the source buffer; `open_file(mmap=True)` backs them with
    np.memmap so `load_latest` maps shards instead of reading them, and
    pages fault in lazily as verification/restore touches them.
  - 64-byte alignment keeps every leaf cache-line- and SIMD-aligned and
    lets a future device DMA consume the mapping directly.
  - dtype names round-trip through ml_dtypes (bfloat16 & friends).

Integrity is *not* this layer's job — digests live in manifest.json
(file path) or the control message (buddy path), so corruption checks
can run per-shard in parallel against the mapped views.

Delta frames
------------

A *delta frame* records only the byte ranges of a state that changed
since a parent frame, at 4 KB tile granularity (the tile of
`kernels.checksum` — dirtiness is decided by comparing per-tile
(s0, s1, mix) digest rows, which on accelerators are computed on device
so only 12 bytes per tile ever cross to the host):

    offset 0      magic       8 bytes   b"RPROCKD1"
    offset 8      header_len  u32 LE    byte length of the JSON header
    offset 12     reserved    u32 LE    0 (format flags, future use)
    offset 16     header      UTF-8 JSON, header_len bytes
    ...           zero pad to the next 64-byte boundary
    data          dirty-range bytes, every range starting on a 64-byte
                  boundary, in header order

    header JSON: {"version": 1,
                  "kind":   "delta",
                  "base":   {"step": <int>},   # parent frame of the chain
                  "extra":  {...user metadata...},
                  "leaves": [{"path", "dtype", "shape", "full",
                              "ranges": [[leaf_off, nbytes, frame_off],
                                         ...]}, ...]}

Semantics:

  - `base.step` names the immediate parent (deltas chain; a restore
    walks down to the nearest full frame and re-applies upward).
  - A leaf with `full: true` carries its complete byte stream (new leaf,
    or shape/dtype changed) as a single range.
  - Leaves whose tiles all match the parent are omitted entirely — a
    clean snapshot's delta is just the header.
  - `ranges` entries are [offset-in-leaf-bytes, length, offset-in-frame];
    dirty tiles are merged into maximal runs and the final range is
    clipped to the leaf's byte length (partial trailing tile).
  - Composition (`apply_delta`/`compose`) is bit-exact: base + deltas
    reproduces the full snapshot byte-for-byte, enforced downstream by
    manifest digests over the composed state.
"""
from __future__ import annotations

import json
import struct
from typing import Any, Dict, NamedTuple, Tuple

import numpy as np

MAGIC = b"RPROCKP1"
DELTA_MAGIC = b"RPROCKD1"
ALIGN = 64
_FIXED = struct.Struct("<8sII")      # magic, header_len, reserved
VERSION = 1


def _dtype(name: str) -> np.dtype:
    """Resolve a dtype name, falling back to ml_dtypes for bf16 etc."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _leaf_bytes(arr) -> np.ndarray:
    """Flat uint8 view of a host array (copies only if non-contiguous)."""
    from repro.kernels.checksum.ref import byte_view
    return byte_view(np.asarray(arr))


def _align(n: int) -> int:
    return -(-n // ALIGN) * ALIGN


def _layout(flat: Dict[str, Any], extra: dict | None
            ) -> Tuple[bytes, list, int]:
    """Returns (prefix_bytes, [(path, uint8_view, offset)], frame_size).

    prefix = fixed header + JSON + pad; offsets are absolute in-frame.
    """
    views = {k: _leaf_bytes(v) for k, v in flat.items()}
    entries = [{"path": k,
                "dtype": str(getattr(flat[k], "dtype",
                                     np.asarray(flat[k]).dtype)),
                "shape": list(np.shape(flat[k])),
                "offset": 0, "nbytes": int(views[k].size)}
               for k in flat]
    # Offsets depend on the header's byte length, which depends on the
    # offsets' digit counts — iterate to a fixpoint. Offsets (and hence
    # the header length) only ever grow, so this converges in a couple
    # of rounds; the loop exits with `header` serialized from exactly
    # the offsets the data will be written at.
    while True:
        header = json.dumps({"version": VERSION, "extra": extra or {},
                             "leaves": entries},
                            separators=(",", ":")).encode()
        off = _align(_FIXED.size + len(header))
        changed = False
        for e in entries:
            if e["offset"] != off:
                e["offset"] = off
                changed = True
            off += _align(e["nbytes"])
        if not changed:
            break
    data_start = _align(_FIXED.size + len(header))
    prefix = _FIXED.pack(MAGIC, len(header), 0) + header
    prefix += b"\0" * (data_start - len(prefix))
    placed = [(e["path"], views[e["path"]], e["offset"]) for e in entries]
    return prefix, placed, off


def frame_size(flat: Dict[str, Any], extra: dict | None = None) -> int:
    return _layout(flat, extra)[2]


def to_bytes(flat: Dict[str, Any], extra: dict | None = None) -> bytes:
    """Serialize {path: array} into one frame (single preallocated buffer,
    leaves copied in via memoryview — no intermediate tobytes)."""
    prefix, placed, size = _layout(flat, extra)
    buf = bytearray(size)
    buf[:len(prefix)] = prefix
    mv = memoryview(buf)
    for _, view, off in placed:
        mv[off:off + view.size] = memoryview(view)
    return bytes(buf)


def write_file(path: str, flat: Dict[str, Any],
               extra: dict | None = None) -> int:
    """Stream a frame to `path`; returns bytes written. Leaf bytes go
    straight from the array's buffer to the file."""
    prefix, placed, size = _layout(flat, extra)
    with open(path, "wb") as f:
        f.write(prefix)
        pos = len(prefix)
        for _, view, off in placed:
            if off > pos:
                f.write(b"\0" * (off - pos))
            f.write(memoryview(view))
            pos = off + view.size
        if size > pos:
            f.write(b"\0" * (size - pos))
    return size


def _parse(buf) -> Tuple[dict, Dict[str, np.ndarray]]:
    """buf: bytes / bytearray / memmap. Returns (extra, {path: view})."""
    head = bytes(buf[:_FIXED.size])
    if len(head) < _FIXED.size:
        raise IOError("serde frame truncated (no fixed header)")
    magic, hlen, _ = _FIXED.unpack(head)
    if magic != MAGIC:
        raise IOError(f"bad serde magic {magic!r}")
    try:
        header = json.loads(bytes(buf[_FIXED.size:_FIXED.size + hlen]))
    except ValueError as e:
        raise IOError(f"serde header corrupt: {e}") from None
    is_arr = isinstance(buf, np.ndarray)
    mv = buf if is_arr else memoryview(buf)      # slices stay zero-copy
    flat: Dict[str, np.ndarray] = {}
    for e in header["leaves"]:
        off, n = e["offset"], e["nbytes"]
        raw = mv[off:off + n]
        if len(raw) != n:
            raise IOError(f"serde frame truncated at leaf {e['path']}")
        dt = _dtype(e["dtype"])
        if is_arr:                               # memmap slice: stay mapped
            arr = raw.view(dt)
        else:
            arr = np.frombuffer(raw, dtype=dt)
        flat[e["path"]] = arr.reshape(e["shape"])
    return header.get("extra", {}), flat


def from_bytes(buf: bytes) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Parse a frame into (extra, {path: ndarray view}). Views are
    read-only windows onto `buf` — np.array(view) to get writable."""
    return _parse(buf)


def open_file(path: str, *, mmap: bool = True
              ) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Map (default) or read a frame file. With mmap, leaves are memmap
    views — the OS pages them in on first touch, so restore cost is paid
    only for the bytes actually consumed."""
    if mmap:
        mm = np.memmap(path, dtype=np.uint8, mode="r")
        return _parse(mm)
    with open(path, "rb") as f:
        return _parse(f.read())


# --------------------------------------------------------------- deltas

def peek_kind(buf) -> str:
    """'full' | 'delta' for a serde frame, 'raw' for anything else."""
    head = bytes(buf[:8])
    if head == MAGIC:
        return "full"
    if head == DELTA_MAGIC:
        return "delta"
    return "raw"


class LeafTiles(NamedTuple):
    """Per-leaf tile digests plus the identity (byte length, dtype,
    shape) they were taken over — a leaf reshaped or reinterpreted to
    the same bytes must never be mistaken for a patchable one."""
    nbytes: int
    dtype: str
    shape: tuple
    rows: np.ndarray        # (n_tiles, 3) uint32


def _leaf_tiles(v, rows=None) -> LeafTiles:
    from repro.kernels.checksum.ops import tile_checksums
    meta = v if hasattr(v, "nbytes") else np.asarray(v)
    return LeafTiles(int(meta.nbytes), str(meta.dtype),
                     tuple(np.shape(v)),
                     tile_checksums(v) if rows is None else rows)


def tile_digests(flat: Dict[str, Any]) -> Dict[str, LeafTiles]:
    """Per-leaf LeafTiles — device arrays are digested on device, host
    arrays by the vectorized numpy reference."""
    return {k: _leaf_tiles(v) for k, v in flat.items()}


class DeltaPlan:
    """Outcome of diffing a snapshot against its parent's tile digests.

    entries: {path: None (full leaf) | [(leaf_off, nbytes), ...]}; clean
    leaves are absent. `feasible` is False when the leaf *set* changed in
    a way a delta cannot express (a leaf disappeared)."""

    def __init__(self, entries, new_tiles, dirty_bytes, total_bytes,
                 feasible):
        self.entries = entries
        self.new_tiles = new_tiles
        self.dirty_bytes = dirty_bytes
        self.total_bytes = total_bytes
        self.feasible = feasible

    @property
    def dirty_fraction(self) -> float:
        if not self.feasible:
            return 1.0
        return self.dirty_bytes / self.total_bytes if self.total_bytes \
            else 0.0


def delta_plan(flat: Dict[str, Any],
               prev_tiles: Dict[str, LeafTiles],
               new_tiles: Dict[str, LeafTiles] | None = None) -> DeltaPlan:
    """Diff `flat` against the parent snapshot's per-tile digests
    ({path: LeafTiles} as produced by `tile_digests`).

    `new_tiles` short-circuits digesting when the caller already computed
    (or enqueued on device) this snapshot's tiles."""
    from repro.kernels.checksum.ref import TILE_BYTES
    if new_tiles is None:
        new_tiles = tile_digests(flat)
    entries: Dict[str, Any] = {}
    dirty = total = 0
    for k, v in flat.items():
        cur = new_tiles[k]
        nbytes = int(cur.nbytes)
        nt = np.asarray(cur.rows)
        total += nbytes
        prev = prev_tiles.get(k)
        if (prev is None or prev[:3] != cur[:3]    # nbytes/dtype/shape
                or np.asarray(prev.rows).shape != nt.shape):
            entries[k] = None    # new / reshaped / recast: full leaf
            dirty += nbytes
            continue
        changed = np.any(np.asarray(prev.rows) != nt, axis=1)
        if not changed.any():
            continue                               # clean leaf: omitted
        idx = np.flatnonzero(changed)
        # merge consecutive dirty tiles into maximal runs
        splits = np.flatnonzero(np.diff(idx) > 1) + 1
        ranges = []
        for run in np.split(idx, splits):
            off = int(run[0]) * TILE_BYTES
            end = min((int(run[-1]) + 1) * TILE_BYTES, nbytes)
            ranges.append((off, end - off))
            dirty += end - off
        entries[k] = ranges
    feasible = all(k in flat for k in prev_tiles)
    return DeltaPlan(entries, new_tiles, dirty, total, feasible)


class ChainPlanner:
    """The base/delta cadence policy, shared by every delta producer
    (FileCheckpointer shards, worker buddy pushes): a full frame every
    `base_every`-th snapshot, tile-range deltas between, degrading to a
    full frame when the dirty fraction exceeds `max_dirty`, the leaf set
    changed, or the chain would not anchor (non-monotonic step; with
    `contiguous`, a parent other than step-1 — the BuddyStore retention
    walk assumes step-1 chains).

    `decide` is pure; call `commit` only after the frame is durably
    written so a failed write never corrupts the chain state."""

    def __init__(self, base_every: int, max_dirty: float = 0.5, *,
                 contiguous: bool = False):
        self.base_every = base_every
        self.max_dirty = max_dirty
        self.contiguous = contiguous
        self.prev: tuple | None = None        # (step, tiles)
        self.since_base = 0

    def predict_full(self, step: int) -> bool:
        """True when `decide(step)` cannot return "delta" regardless of
        how dirty the snapshot turns out to be (cadence says base, no
        parent, non-anchoring step). The async gather path uses this at
        submit time: only when the full bytes will certainly be needed
        does it kick the whole-state D2H drain early."""
        prev = self.prev
        return (self.base_every <= 1 or prev is None or prev[0] >= step
                or self.since_base >= self.base_every - 1
                or (self.contiguous and prev[0] != step - 1))

    def decide(self, flat: Dict[str, Any], step: int,
               new_tiles: Dict[str, tuple] | None = None):
        """-> (kind, plan-or-None, tiles, base_step-or-None)."""
        if new_tiles is None:
            new_tiles = tile_digests(flat)
        if self.predict_full(step):
            return "full", None, new_tiles, None
        plan = delta_plan(flat, self.prev[1], new_tiles)
        if not plan.feasible or plan.dirty_fraction > self.max_dirty:
            return "full", None, new_tiles, None
        return "delta", plan, new_tiles, self.prev[0]

    def commit(self, step: int, tiles: Dict[str, tuple], kind: str):
        self.prev = (step, tiles)
        self.since_base = self.since_base + 1 if kind == "delta" else 0


class GatherLeaf(NamedTuple):
    """One leaf of a *gathered* delta: its identity plus the dirty byte
    runs, each run carrying its own uint8 view of the bytes to emit.

    This is the representation every delta frame is built from. The
    views may point anywhere byte-identical to the leaf's dirty ranges:
    slices of the full host array (`gather_host`, the CPU path), or
    slices of a compact device-gathered tile buffer that is the *only*
    bulk payload ever copied D2H (FileCheckpointer's gather path) — the
    frame writer cannot tell the difference and the frame bytes are
    identical either way (tested)."""
    dtype: str
    shape: tuple
    full: bool
    runs: list              # [(leaf_off, nbytes, uint8_view)]


def range_tiles(ranges) -> np.ndarray:
    """Ascending tile indices covered by a plan entry's byte ranges
    (each range is a maximal run of dirty 4 KB tiles, possibly clipped
    at the leaf's end) — the index the device gather kernel consumes."""
    from repro.kernels.checksum.ref import TILE_BYTES
    idx = []
    for off, n in ranges:
        t0 = off // TILE_BYTES
        idx.extend(range(t0, t0 + (-(-(n) // TILE_BYTES))))
    return np.asarray(idx, np.int32)


def gather_host(flat: Dict[str, Any], plan: DeltaPlan
                ) -> Dict[str, GatherLeaf]:
    """Gathered representation of `plan` over host-resident leaves: the
    run views are zero-copy slices of the arrays themselves. The worker's
    buddy PUSH_CKPT frames and the CPU-backend file path both ride
    this."""
    out: Dict[str, GatherLeaf] = {}
    for k in flat:
        if k not in plan.entries:
            continue
        v = flat[k]
        bv = _leaf_bytes(v)
        dt = str(getattr(v, "dtype", np.asarray(v).dtype))
        rng = plan.entries[k]
        if rng is None:
            out[k] = GatherLeaf(dt, tuple(np.shape(v)), True,
                                [(0, int(bv.size), bv)])
        else:
            out[k] = GatherLeaf(dt, tuple(np.shape(v)), False,
                                [(o, n, bv[o:o + n]) for o, n in rng])
    return out


def _delta_layout_gathered(gathered: Dict[str, GatherLeaf],
                           base_step: int, extra: dict | None):
    """(prefix, [(uint8_view, frame_off)], frame_size) for a gathered
    delta. Each placed view is exactly one run's bytes."""
    entries = []
    for k, g in gathered.items():
        entries.append({"path": k, "dtype": g.dtype,
                        "shape": list(g.shape), "full": g.full,
                        "ranges": [[o, n, 0] for o, n, _ in g.runs]})
    while True:     # same offset/header fixpoint as _layout
        header = json.dumps({"version": VERSION, "kind": "delta",
                             "base": {"step": int(base_step)},
                             "extra": extra or {}, "leaves": entries},
                            separators=(",", ":")).encode()
        off = _align(_FIXED.size + len(header))
        changed = False
        for e in entries:
            for r in e["ranges"]:
                if r[2] != off:
                    r[2] = off
                    changed = True
                off += _align(r[1])
        if not changed:
            break
    data_start = _align(_FIXED.size + len(header))
    prefix = _FIXED.pack(DELTA_MAGIC, len(header), 0) + header
    prefix += b"\0" * (data_start - len(prefix))
    placed = [(run[2], r[2])
              for e, (_, g) in zip(entries, gathered.items())
              for run, r in zip(g.runs, e["ranges"])]
    return prefix, placed, off


def to_delta_bytes_gathered(gathered: Dict[str, GatherLeaf], *,
                            base_step: int,
                            extra: dict | None = None) -> bytes:
    prefix, placed, size = _delta_layout_gathered(gathered, base_step,
                                                  extra)
    buf = bytearray(size)
    buf[:len(prefix)] = prefix
    mv = memoryview(buf)
    for view, frame_off in placed:
        mv[frame_off:frame_off + view.size] = memoryview(view)
    return bytes(buf)


def write_delta_file_gathered(path: str, gathered: Dict[str, GatherLeaf],
                              *, base_step: int,
                              extra: dict | None = None) -> int:
    prefix, placed, size = _delta_layout_gathered(gathered, base_step,
                                                  extra)
    with open(path, "wb") as f:
        f.write(prefix)
        pos = len(prefix)
        for view, frame_off in placed:
            if frame_off > pos:
                f.write(b"\0" * (frame_off - pos))
            f.write(memoryview(view))
            pos = frame_off + view.size
        if size > pos:
            f.write(b"\0" * (size - pos))
    return size


def to_delta_bytes(flat: Dict[str, Any], plan: DeltaPlan, *,
                   base_step: int, extra: dict | None = None) -> bytes:
    """Delta frame from full host leaves — gathers (zero-copy slices)
    then serializes; kept as the convenience entry point."""
    return to_delta_bytes_gathered(gather_host(flat, plan),
                                   base_step=base_step, extra=extra)


def write_delta_file(path: str, flat: Dict[str, Any], plan: DeltaPlan, *,
                     base_step: int, extra: dict | None = None) -> int:
    return write_delta_file_gathered(path, gather_host(flat, plan),
                                     base_step=base_step, extra=extra)


class FramePublisher:
    """One-call publish side of a delta stream: decide base-vs-delta via
    a ChainPlanner, gather only the dirty byte runs, encode the frame,
    and advance the chain. The subscribe side is `composable_steps` +
    `compose`, unchanged.

    Shared by every in-memory delta producer — the runtime worker's
    buddy pushes and the serve replicator's state stream — so the frame
    format and cadence policy cannot drift between them. `last_kind`
    reports what the most recent publish emitted ("full"/"delta"),
    which is what O(dirt) tests and replication telemetry key on."""

    def __init__(self, base_every: int, max_dirty: float = 0.5, *,
                 contiguous: bool = False):
        self.chain = ChainPlanner(base_every, max_dirty,
                                  contiguous=contiguous)
        self.last_kind: str | None = None

    def publish(self, flat: Dict[str, Any], step: int,
                extra: dict | None = None) -> bytes:
        """Frame bytes for `flat` at `step` — a tile-range delta against
        the previous frame when the chain allows it and the state is
        sparse-dirty, a full frame otherwise. The chain is committed
        before returning; in-memory pushes have no partial-write failure
        mode (a crashed push loses the whole frame and the next decide
        sees a non-anchoring parent, degrading to a full frame)."""
        ex = dict(extra or {})
        ex.setdefault("step", step)
        kind, plan, tiles, base = self.chain.decide(flat, step)
        if kind == "delta":
            # gathered representation: the frame is assembled from
            # zero-copy slices of the dirty ranges only — same bytes as
            # the full-drain path, without re-touching clean pages
            payload = to_delta_bytes_gathered(gather_host(flat, plan),
                                              base_step=base, extra=ex)
        else:
            payload = to_bytes(flat, extra=ex)
        self.chain.commit(step, tiles, kind)
        self.last_kind = kind
        return payload

    def rebase(self):
        """Restart the chain: the next publish emits a full frame. Call
        when the consumer of the stream lost its history — e.g. the
        buddy holding the held frames died and respawned empty — so a
        delta against a frame nobody holds is never emitted."""
        self.chain.prev = None
        self.chain.since_base = 0


def _parse_delta(buf) -> Tuple[dict, Any]:
    head = bytes(buf[:_FIXED.size])
    if len(head) < _FIXED.size:
        raise IOError("delta frame truncated (no fixed header)")
    magic, hlen, _ = _FIXED.unpack(head)
    if magic != DELTA_MAGIC:
        raise IOError(f"bad delta magic {magic!r}")
    try:
        header = json.loads(bytes(buf[_FIXED.size:_FIXED.size + hlen]))
    except ValueError as e:
        raise IOError(f"delta header corrupt: {e}") from None
    mv = buf if isinstance(buf, np.ndarray) else memoryview(buf)
    return header, mv


def delta_base_step(buf) -> int:
    header, _ = _parse_delta(buf)
    return int(header["base"]["step"])


def apply_delta(flat: Dict[str, np.ndarray], buf,
                writable: set | None = None
                ) -> Tuple[dict, int, Dict[str, np.ndarray]]:
    """Patch one delta frame onto `flat` (a parsed parent snapshot).

    Returns (extra, base_step, new_flat). Untouched leaves pass through
    as-is (memmap views stay mapped); `full` leaves become views into
    `buf`; range-patched leaves are materialized copies. Bit-exact.

    `writable` (chain-compose optimization) names leaves the caller
    already owns as writable copies: those are patched in place instead
    of re-copied, so a K-link chain materializes each dirty leaf once,
    not K times. Paths this call materializes are added to the set."""
    header, mv = _parse_delta(buf)
    is_arr = isinstance(buf, np.ndarray)
    out = dict(flat)
    for e in header["leaves"]:
        dt = _dtype(e["dtype"])
        if e["full"]:
            [[_, n, off]] = e["ranges"]
            raw = mv[off:off + n]
            if len(raw) != n:
                raise IOError(f"delta truncated at leaf {e['path']}")
            arr = raw.view(dt) if is_arr else np.frombuffer(raw, dtype=dt)
            out[e["path"]] = arr.reshape(e["shape"])
            if writable is not None:
                writable.discard(e["path"])    # back to a read-only view
            continue
        cur = out.get(e["path"])
        if cur is None:
            raise IOError(f"delta patches unknown leaf {e['path']}")
        if str(cur.dtype) != e["dtype"]:
            raise IOError(f"delta dtype mismatch at leaf {e['path']}: "
                          f"{cur.dtype} vs {e['dtype']}")
        if writable is None or e["path"] not in writable:
            cur = np.array(cur)                # writable materialized
            if writable is not None:
                writable.add(e["path"])
        bv = cur.reshape(-1).view(np.uint8)
        for leaf_off, n, frame_off in e["ranges"]:
            raw = mv[frame_off:frame_off + n]
            if len(raw) != n:
                raise IOError(f"delta truncated at leaf {e['path']}")
            bv[leaf_off:leaf_off + n] = np.frombuffer(raw, np.uint8) \
                if not is_arr else raw
        out[e["path"]] = cur.reshape(e["shape"])
    return header.get("extra", {}), int(header["base"]["step"]), out


def chain_steps(frames: Dict[int, Any], step: int) -> list:
    """Frame steps [base, ..., step] needed to compose `step`; raises
    KeyError when the chain is broken."""
    chain = [step]
    while True:
        buf = frames.get(chain[-1])
        if buf is None:
            raise KeyError(f"missing frame for step {chain[-1]}")
        if peek_kind(buf) != "delta":
            return list(reversed(chain))
        chain.append(delta_base_step(buf))


def composable_steps(frames: Dict[int, Any]) -> list:
    """Steps whose full state is reconstructible from `frames` alone."""
    out = []
    for s in frames:
        try:
            chain_steps(frames, s)
            out.append(s)
        except (KeyError, IOError):
            pass
    return sorted(out)


def compose(frames: Dict[int, Any], step: int
            ) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Reconstruct the full snapshot at `step` from a {step: frame-bytes}
    map by walking the delta chain down to its base full frame and
    re-applying patches upward. Returns (extra of the target frame,
    flat). Raises KeyError on a broken chain."""
    chain = chain_steps(frames, step)
    extra, flat = from_bytes(frames[chain[0]])
    writable: set = set()
    for s in chain[1:]:
        extra, _, flat = apply_delta(flat, frames[s], writable)
    return extra, flat
