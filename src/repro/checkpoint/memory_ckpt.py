"""Buddy in-memory checkpointing.

Two faces of the same paper mechanism (local copy + copy on the cyclically
next rank):

1. `buddy_exchange` — the in-JAX SPMD form: every shard of the state pytree
   is `ppermute`d one step along the data axis, so each device's HBM holds
   its own shard *and* its left neighbour's. On a TPU torus this lowers to a
   single collective-permute over neighbour ICI links — the cheapest
   possible redundancy, and it shows up in the compiled HLO so the roofline
   accounts for it. Valid for single-shard failures (Table 2 of the paper):
   a lost device's state is recovered from its right neighbour.

2. `BuddyStore` — the process-runtime form: a rank stores checkpoint bytes
   locally and pushes a copy to rank (r+1) % world over TCP. Re-spawned
   ranks pull their state back from the buddy.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.rules import ShardingRules, tree_specs


def _fixed_specs(state, mesh: Mesh, rules: ShardingRules):
    from repro.sharding.partition import _divisible
    specs = tree_specs(state, rules)
    return jax.tree.map(
        lambda s, leaf: _divisible(s, getattr(leaf, "shape", ()), mesh),
        specs, state, is_leaf=lambda s: isinstance(s, P))


def buddy_exchange(state, mesh: Mesh, rules: ShardingRules,
                   axis: str = "data"):
    """Returns the buddy copy of `state`: each data-shard moved one step
    (cyclically) along `axis`. Leaves not sharded on `axis` come back
    unchanged (they are already replicated = already redundant)."""
    from jax.experimental.shard_map import shard_map

    n = mesh.shape[axis]
    if n == 1:
        return state
    specs = _fixed_specs(state, mesh, rules)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def fn(st):
        return jax.tree.map(
            lambda a: jax.lax.ppermute(a, axis, perm), st)

    return shard_map(fn, mesh=mesh, in_specs=(specs,), out_specs=specs,
                     check_rep=False)(state)


def restore_from_buddy(buddy_state, mesh: Mesh, rules: ShardingRules,
                       axis: str = "data"):
    """Inverse permute: rebuild the original state from buddy copies.

    After a shard loss, the survivor copies plus the buddy ring reconstruct
    every shard (single-failure guarantee, as in the paper)."""
    from jax.experimental.shard_map import shard_map

    n = mesh.shape[axis]
    if n == 1:
        return buddy_state
    specs = _fixed_specs(buddy_state, mesh, rules)
    perm = [((i + 1) % n, i) for i in range(n)]

    def fn(st):
        return jax.tree.map(
            lambda a: jax.lax.ppermute(a, axis, perm), st)

    return shard_map(fn, mesh=mesh, in_specs=(specs,), out_specs=specs,
                     check_rep=False)(buddy_state)


class _Spilled:
    """Marker for a payload tiered out to local disk. `owned` entries
    were written by the store (deleted on eviction); un-owned entries
    reference a file some other layer already persisted (e.g. the
    worker's rank checkpoint file) — the tier must neither rewrite nor
    delete those."""

    __slots__ = ("path", "nbytes", "kind", "owned")

    def __init__(self, path: str, nbytes: int, kind: str,
                 owned: bool = True):
        self.path = path
        self.nbytes = nbytes
        self.kind = kind
        self.owned = owned


class BuddyStore:
    """Rank-local in-memory checkpoint store with a remote buddy copy and
    an optional spill-to-file tier.

    `push_remote` is injected by the runtime (worker TCP send); the store
    itself is transport-agnostic so the trainer and tests can use it with a
    plain dict fabric.

    Tiering (the paper's memory/file dichotomy promoted to an LRU tier):
    with `spill_dir` set, only the `hot_steps` newest steps of each
    retention window stay resident; older retained payloads are written
    out as frame files on local disk and read back transparently on
    access. Spilled serde *base* frames are additionally kept alive past
    the retention window while a retained delta frame still chains to
    them, so every retained step stays composable.
    """

    def __init__(self, rank: int, world: int,
                 push_remote: Optional[Callable[[int, int, bytes], None]] = None,
                 *, retain: int = 2, spill_dir: Optional[str] = None,
                 hot_steps: Optional[int] = None):
        self.rank = rank
        self.world = world
        self.push_remote = push_remote
        # retention window: keep steps in [latest - retain, latest], both
        # locally and for held buddy copies — retain+1 checkpoints total,
        # enough for the BSP skew of one step plus the rejoin consensus
        self.retain = retain
        self.spill_dir = spill_dir
        self.hot_steps = retain + 1 if hot_steps is None else max(1,
                                                                  hot_steps)
        self.spilled_bytes = 0      # guarded-by: _lock (bytes spilled)
        self._lock = threading.Lock()
        self.local: Dict[int, Any] = {}       # guarded-by: _lock
        self._local_disk: Dict[int, str] = {}   # guarded-by: _lock
        self.held: Dict[int, Dict[int, Any]] = {}   # guarded-by: _lock
        # ring membership: None = the dense 0..world-1 ring; a shrinking
        # recovery re-forms it over the (possibly non-contiguous)
        # surviving rank ids
        self._members: Optional[list] = None    # guarded-by: _lock

    @property
    def buddy(self) -> int:
        with self._lock:
            if self._members is None:
                return (self.rank + 1) % self.world
            i = self._members.index(self.rank)
            return self._members[(i + 1) % len(self._members)]

    def reform_ring(self, members) -> None:
        """Re-form the buddy ring over `members` (sorted surviving rank
        ids) after an elastic shrink: the buddy becomes the next surviving
        rank. Held frames for dropped origins are no longer needed but
        are left to age out of the retention window."""
        ms = sorted(members)
        if self.rank not in ms:
            return      # stale broadcast to a rank outside the new world;
                        # its process is about to be reaped anyway
        with self._lock:
            self._members = ms
            self.world = len(ms)

    # ----------------------------------------------------------- tiering

    def _payload_kind(self, payload: bytes) -> str:
        from . import serde
        return serde.peek_kind(payload)

    def _spill_path(self, tag: str, step: int) -> str:
        return os.path.join(self.spill_dir, f"{tag}.s{step}.bin")

    def _prune(self, d: Dict[int, Any], latest: int, tag: str,
               disk_refs: Dict[int, str] | None = None) -> list:  # holds-lock: _lock
        """Window policy for one {step: payload} map (caller holds the
        lock). Keeps [latest - retain, latest]; when the window floor is
        a delta frame its chain is walked down to the full-frame anchor
        so every kept step stays composable. Cold entries with a known
        on-disk copy (`disk_refs`) become zero-I/O reference markers;
        the rest are returned as the spill worklist [(step, payload,
        path)] — those file writes happen *outside* the lock (see
        _spill) so concurrent hold()/held_map() never stall on disk
        I/O."""
        lo = latest - self.retain
        keep = {s for s in d if s >= lo}
        if keep:
            # delta frames chain to step-1: walk the window floor's chain
            # down to its full-frame anchor so every kept step composes
            kinds = {s: (e.kind if isinstance(e, _Spilled)
                         else self._payload_kind(e)) for s, e in d.items()}
            s = min(keep)
            while kinds.get(s) == "delta" and (s - 1) in d:
                s -= 1
                keep.add(s)
        for s in [s for s in d if s not in keep]:
            e = d.pop(s)
            if isinstance(e, _Spilled):
                if e.owned:
                    self.spilled_bytes -= e.nbytes
                    try:
                        os.unlink(e.path)
                    except OSError:
                        pass
        if self.spill_dir is None:
            return []
        hot_floor = latest - (self.hot_steps - 1)
        work = []
        for s, e in list(d.items()):
            if s >= hot_floor or isinstance(e, _Spilled):
                continue
            ref = (disk_refs or {}).get(s)
            if ref is not None:     # durable copy exists: just point at it
                d[s] = _Spilled(ref, len(e), self._payload_kind(e),
                                owned=False)
            else:
                work.append((s, e, self._spill_path(tag, s)))
        return work

    def _spill(self, d: Dict[int, Any], work: list):
        """Write the spill worklist to disk lock-free (payload bytes are
        immutable), then swap in the markers under the lock; an entry
        evicted meanwhile just has its fresh file deleted."""
        for s, payload, path in work:
            os.makedirs(self.spill_dir, exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(payload)
            os.replace(tmp, path)
            with self._lock:
                if d.get(s) is payload:
                    d[s] = _Spilled(path, len(payload),
                                    self._payload_kind(payload))
                    self.spilled_bytes += len(payload)
                    continue
            try:
                os.unlink(path)             # superseded while we wrote
            except OSError:
                pass

    def _fetch(self, e) -> bytes:
        if isinstance(e, _Spilled):
            with open(e.path, "rb") as f:
                return f.read()
        return e

    def resident_bytes(self) -> int:
        with self._lock:
            maps = [self.local] + list(self.held.values())
            return sum(len(e) for m in maps for e in m.values()
                       if not isinstance(e, _Spilled))

    # ------------------------------------------------------------- store

    def save(self, step: int, payload: bytes,
             on_disk: Optional[str] = None):
        """`on_disk`: path of a durable copy of `payload` some other
        layer already wrote (e.g. the rank's file checkpoint) — the
        spill tier then references it instead of writing a duplicate."""
        with self._lock:
            d = self.local
            d[step] = payload
            if on_disk is not None:
                self._local_disk[step] = on_disk
            work = self._prune(d, step, "local", self._local_disk)
            for s in [s for s in self._local_disk if s not in d]:
                del self._local_disk[s]
        self._spill(d, work)
        if self.push_remote is not None:
            self.push_remote(self.buddy, step, payload)

    def hold(self, origin_rank: int, step: int, payload: bytes):
        """Called when a buddy pushes its checkpoint to us."""
        with self._lock:
            d = self.held.setdefault(origin_rank, {})
            d[step] = payload
            work = self._prune(d, step, f"held_{origin_rank}")
        self._spill(d, work)

    def _fetch_map(self, snap: Dict[int, Any]) -> Dict[int, bytes]:
        """Materialize a snapshot of entries *outside* the lock (disk
        reads don't stall concurrent save/hold); an entry whose backing
        file was reaped underneath us is simply dropped — it was out of
        the window anyway."""
        out = {}
        for s, e in snap.items():
            try:
                out[s] = self._fetch(e)
            except OSError:
                pass
        return out

    def latest_local(self):
        m = self.local_map()
        if not m:
            return None, None
        s = max(m)
        return s, m[s]

    def latest_held(self, origin_rank: int):
        m = self.held_map(origin_rank)
        if not m:
            return None, None
        s = max(m)
        return s, m[s]

    def local_map(self) -> Dict[int, bytes]:
        with self._lock:
            snap = dict(self.local)
        return self._fetch_map(snap)

    def held_map(self, origin_rank: int) -> Dict[int, bytes]:
        with self._lock:
            snap = dict(self.held.get(origin_rank, {}))
        return self._fetch_map(snap)
