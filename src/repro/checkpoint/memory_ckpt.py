"""Buddy in-memory checkpointing.

Two faces of the same paper mechanism (local copy + copy on the cyclically
next rank):

1. `buddy_exchange` — the in-JAX SPMD form: every shard of the state pytree
   is `ppermute`d one step along the data axis, so each device's HBM holds
   its own shard *and* its left neighbour's. On a TPU torus this lowers to a
   single collective-permute over neighbour ICI links — the cheapest
   possible redundancy, and it shows up in the compiled HLO so the roofline
   accounts for it. Valid for single-shard failures (Table 2 of the paper):
   a lost device's state is recovered from its right neighbour.

2. `BuddyStore` — the process-runtime form: a rank stores checkpoint bytes
   locally and pushes a copy to rank (r+1) % world over TCP. Re-spawned
   ranks pull their state back from the buddy.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.rules import ShardingRules, tree_specs


def _fixed_specs(state, mesh: Mesh, rules: ShardingRules):
    from repro.sharding.partition import _divisible
    specs = tree_specs(state, rules)
    return jax.tree.map(
        lambda s, leaf: _divisible(s, getattr(leaf, "shape", ()), mesh),
        specs, state, is_leaf=lambda s: isinstance(s, P))


def buddy_exchange(state, mesh: Mesh, rules: ShardingRules,
                   axis: str = "data"):
    """Returns the buddy copy of `state`: each data-shard moved one step
    (cyclically) along `axis`. Leaves not sharded on `axis` come back
    unchanged (they are already replicated = already redundant)."""
    from jax.experimental.shard_map import shard_map

    n = mesh.shape[axis]
    if n == 1:
        return state
    specs = _fixed_specs(state, mesh, rules)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def fn(st):
        return jax.tree.map(
            lambda a: jax.lax.ppermute(a, axis, perm), st)

    return shard_map(fn, mesh=mesh, in_specs=(specs,), out_specs=specs,
                     check_rep=False)(state)


def restore_from_buddy(buddy_state, mesh: Mesh, rules: ShardingRules,
                       axis: str = "data"):
    """Inverse permute: rebuild the original state from buddy copies.

    After a shard loss, the survivor copies plus the buddy ring reconstruct
    every shard (single-failure guarantee, as in the paper)."""
    from jax.experimental.shard_map import shard_map

    n = mesh.shape[axis]
    if n == 1:
        return buddy_state
    specs = _fixed_specs(buddy_state, mesh, rules)
    perm = [((i + 1) % n, i) for i in range(n)]

    def fn(st):
        return jax.tree.map(
            lambda a: jax.lax.ppermute(a, axis, perm), st)

    return shard_map(fn, mesh=mesh, in_specs=(specs,), out_specs=specs,
                     check_rep=False)(buddy_state)


class BuddyStore:
    """Rank-local in-memory checkpoint store with a remote buddy copy.

    `push_remote` is injected by the runtime (worker TCP send); the store
    itself is transport-agnostic so the trainer and tests can use it with a
    plain dict fabric.
    """

    def __init__(self, rank: int, world: int,
                 push_remote: Optional[Callable[[int, int, bytes], None]] = None,
                 *, retain: int = 2):
        self.rank = rank
        self.world = world
        self.push_remote = push_remote
        # retention window: keep steps in [latest - retain, latest], both
        # locally and for held buddy copies — retain+1 checkpoints total,
        # enough for the BSP skew of one step plus the rejoin consensus
        self.retain = retain
        self._lock = threading.Lock()
        self.local: Dict[int, bytes] = {}      # step -> my own bytes
        self.held: Dict[int, Dict[int, bytes]] = {}   # origin rank -> step -> bytes

    @property
    def buddy(self) -> int:
        return (self.rank + 1) % self.world

    def save(self, step: int, payload: bytes):
        with self._lock:
            self.local[step] = payload
            self.local = {s: b for s, b in self.local.items()
                          if s >= step - self.retain}
        if self.push_remote is not None:
            self.push_remote(self.buddy, step, payload)

    def hold(self, origin_rank: int, step: int, payload: bytes):
        """Called when a buddy pushes its checkpoint to us."""
        with self._lock:
            d = self.held.setdefault(origin_rank, {})
            d[step] = payload
            for s in [s for s in d if s < step - self.retain]:
                del d[s]

    def latest_local(self):
        with self._lock:
            if not self.local:
                return None, None
            s = max(self.local)
            return s, self.local[s]

    def latest_held(self, origin_rank: int):
        with self._lock:
            d = self.held.get(origin_rank, {})
            if not d:
                return None, None
            s = max(d)
            return s, d[s]

    def local_map(self) -> Dict[int, bytes]:
        with self._lock:
            return dict(self.local)

    def held_map(self, origin_rank: int) -> Dict[int, bytes]:
        with self._lock:
            return dict(self.held.get(origin_rank, {}))
