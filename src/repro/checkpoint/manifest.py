"""Pytree flattening, shard naming and integrity hashes for checkpoints.

States are nested dicts of arrays; leaves are addressed by their
"/"-joined key path, which makes the on-disk format self-describing and
re-shardable (a restore may run under a different process count than the
save — global-restart is non-shrinking but elastic re-hosting is not).

Integrity digests come in two algorithms:

  "wordsum"  (default) — the tiled-reduction checksum from
             `repro.kernels.checksum`: device-resident leaves are digested
             *on device* (Pallas kernel on TPU, jnp reduction elsewhere)
             and host leaves through the vectorized numpy reference;
             neither path materializes a `tobytes()` copy. Only dtype,
             shape and two 4-byte word-sums feed the final (tiny) sha256.
  "sha256"   — the legacy full-content hash, kept for the np.savez
             comparison path and old manifests.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict

import numpy as np


def flatten_state(state) -> Dict[str, np.ndarray]:
    """Nested-dict pytree -> {path: np.ndarray}. Lists become index keys."""
    return {k: np.asarray(v) for k, v in flatten_leaves(state).items()}


def flatten_leaves(state) -> Dict[str, Any]:
    """Like flatten_state but leaves arrays untouched — device arrays stay
    on device (the fast checkpoint path digests and drains them itself)."""
    out: Dict[str, Any] = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}/{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}/{i}", v)
        else:
            out[prefix] = node

    rec("", state)
    return out


def unflatten_state(flat: Dict[str, np.ndarray]):
    """Inverse of flatten_state (all containers restored as dicts; integer
    keys are restored as list entries when contiguous from 0)."""
    root: dict = {}
    for path, arr in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr

    def fix(node):
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if keys and all(k.isdigit() for k in keys):
            idx = sorted(int(k) for k in keys)
            if idx == list(range(len(idx))):
                return [fix(node[str(i)]) for i in idx]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def digest_from_checksum(dtype, shape, s0: int, s1: int) -> str:
    """Combine word-sums with leaf metadata into the digest string —
    only these few bytes ever reach hashlib."""
    h = hashlib.sha256()
    h.update(f"{dtype}|{tuple(shape)}".encode())
    h.update(s0.to_bytes(4, "little"))
    h.update(s1.to_bytes(4, "little"))
    return h.hexdigest()[:16]


def leaf_digest(arr) -> str:
    """Wordsum digest: on-device reduction for jax arrays, vectorized
    numpy for host arrays."""
    from repro.kernels.checksum.ops import leaf_checksum   # lazy: jax init
    s0, s1 = leaf_checksum(arr)
    if not hasattr(arr, "dtype"):
        arr = np.asarray(arr)
    return digest_from_checksum(arr.dtype, arr.shape, s0, s1)


def leaf_digest_sha256(arr: np.ndarray) -> str:
    """Legacy full-content digest (hashes a tobytes copy on the host)."""
    arr = np.asarray(arr)
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()[:16]


DIGESTS = {"wordsum": leaf_digest, "sha256": leaf_digest_sha256}


def tree_digest(state) -> str:
    """Order-stable digest of a whole state pytree."""
    flat = flatten_leaves(state)
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(leaf_digest(flat[k]).encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class Manifest:
    step: int
    leaves: Dict[str, dict]          # path -> {shape, dtype, digest, shard}
    n_shards: int = 1
    extra: dict = dataclasses.field(default_factory=dict)
    algo: str = "wordsum"
    # delta checkpoints: "full" snapshots stand alone; a "delta" records
    # only dirty tile ranges against its parent step (chain walked at
    # load). Digests always describe the *composed* full state, so a
    # restore through any chain verifies end-to-end.
    kind: str = "full"
    base_step: int | None = None

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Manifest":
        d = json.loads(s)
        d.setdefault("algo", "sha256")   # pre-wordsum manifests
        d.setdefault("kind", "full")     # pre-delta manifests
        d.setdefault("base_step", None)
        return cls(**d)

    @classmethod
    def build(cls, step: int, flat: Dict[str, Any], shard_of,
              n_shards: int, extra: dict | None = None,
              algo: str = "wordsum",
              digests: Dict[str, str] | None = None,
              kind: str = "full",
              base_step: int | None = None) -> "Manifest":
        """`digests` short-circuits hashing when the caller already
        computed them (e.g. on device, or in a per-shard thread pool)."""
        fn = DIGESTS[algo]

        def meta(k, v):
            if not hasattr(v, "shape"):
                v = np.asarray(v)
            return {"shape": list(v.shape), "dtype": str(v.dtype),
                    "digest": (digests[k] if digests is not None else fn(v)),
                    "shard": shard_of(k)}

        leaves = {k: meta(k, v) for k, v in flat.items()}
        return cls(step=step, leaves=leaves, n_shards=n_shards,
                   extra=extra or {}, algo=algo, kind=kind,
                   base_step=base_step)

    def verify(self, flat: Dict[str, Any], paths=None) -> list[str]:
        """Returns corrupted/missing leaf paths (empty = OK). With
        `paths`, checks only that subset (per-shard parallel verify) and
        skips the global missing-leaf sweep."""
        fn = DIGESTS[self.algo]
        bad = []
        keys = self.leaves.keys() if paths is None else paths
        for k in keys:
            meta = self.leaves.get(k)
            if meta is None or k not in flat:
                bad.append(k)
                continue
            if fn(flat[k]) != meta["digest"]:
                bad.append(k)
        return bad
