"""Pytree flattening, shard naming and integrity hashes for checkpoints.

States are nested dicts of arrays; leaves are addressed by their
"/"-joined key path, which makes the on-disk format self-describing and
re-shardable (a restore may run under a different process count than the
save — global-restart is non-shrinking but elastic re-hosting is not).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict

import numpy as np


def flatten_state(state) -> Dict[str, np.ndarray]:
    """Nested-dict pytree -> {path: np.ndarray}. Lists become index keys."""
    out: Dict[str, np.ndarray] = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}/{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}/{i}", v)
        else:
            out[prefix] = np.asarray(node)

    rec("", state)
    return out


def unflatten_state(flat: Dict[str, np.ndarray]):
    """Inverse of flatten_state (all containers restored as dicts; integer
    keys are restored as list entries when contiguous from 0)."""
    root: dict = {}
    for path, arr in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr

    def fix(node):
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if keys and all(k.isdigit() for k in keys):
            idx = sorted(int(k) for k in keys)
            if idx == list(range(len(idx))):
                return [fix(node[str(i)]) for i in idx]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def leaf_digest(arr: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()[:16]


def tree_digest(state) -> str:
    """Order-stable digest of a whole state pytree."""
    flat = flatten_state(state)
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(leaf_digest(flat[k]).encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class Manifest:
    step: int
    leaves: Dict[str, dict]          # path -> {shape, dtype, digest, shard}
    n_shards: int = 1
    extra: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Manifest":
        return cls(**json.loads(s))

    @classmethod
    def build(cls, step: int, flat: Dict[str, np.ndarray], shard_of,
              n_shards: int, extra: dict | None = None) -> "Manifest":
        leaves = {
            k: {"shape": list(v.shape), "dtype": str(v.dtype),
                "digest": leaf_digest(v), "shard": shard_of(k)}
            for k, v in flat.items()
        }
        return cls(step=step, leaves=leaves, n_shards=n_shards,
                   extra=extra or {})

    def verify(self, flat: Dict[str, np.ndarray]) -> list[str]:
        """Returns the list of corrupted/missing leaf paths (empty = OK)."""
        bad = []
        for k, meta in self.leaves.items():
            if k not in flat:
                bad.append(k)
                continue
            if leaf_digest(flat[k]) != meta["digest"]:
                bad.append(k)
        return bad
