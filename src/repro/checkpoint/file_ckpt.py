"""Sharded, atomic, overlap-capable file checkpoints.

Layout:
    <dir>/step_<N>/shard_<i>.bin     one serde frame per writer shard
    <dir>/step_<N>/manifest.json     shapes/dtypes/digests per leaf
    <dir>/step_<N>/COMMITTED         written last — crash-consistency marker

A checkpoint without COMMITTED is garbage from a crashed writer and is
ignored (and garbage-collected) by load_latest. Writes go to a tmp dir that
is os.rename()d into place, so readers never observe partial shards.

Fast-path engine (the paper's argument made real — recovery speed is won
in the checkpoint substrate):

  write   leaves are digested while still on device (Pallas/jnp word-sum;
          only 8 bytes per leaf cross to the host for the manifest), then
          drained leaf-by-leaf via copy_to_host_async and streamed into
          serde frames by a thread pool, one worker per shard.
  async   save() snapshots the state with a cheap on-device copy (so the
          trainer may donate its buffers to step N+1 immediately), kicks
          the device→host DMA per leaf, and queues serialization + IO on
          a single ordered writer thread. A bounded queue of depth 2
          double-buffers snapshots: snapshot N drains while step N+1
          runs; save(N+2) blocks only if N hasn't committed yet.
  read    shards are memory-mapped (no read syscalls for the bulk data)
          and digest-verified per-shard in parallel before the views are
          stitched back into a pytree.

  delta   with delta_every=K > 1, a full (base) snapshot is written every
          K-th save and the saves between record only dirty 4 KB tile
          ranges against the previous save (chained): consecutive
          snapshots are diffed by per-tile word-sum digests computed on
          device (only 12 B/tile crosses PCIe), so a 5%-dirty state
          writes ~5% of the bytes. Restores walk the chain down to the
          base, apply patches upward from memmapped delta frames, and
          verify the *composed* state against the target manifest —
          bit-exact or it raises. GC never reaps a base a kept delta
          still needs. A save whose dirty fraction exceeds 50% degrades
          to a base automatically.

`fmt="npz"` preserves the legacy np.savez + sha256 path byte-for-byte so
benchmarks/checkpoint_bench.py can report old-vs-new on the same class.
"""
from __future__ import annotations

import os
import shutil
import threading
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.scenarios import hooks

from . import serde
from .manifest import (Manifest, digest_from_checksum, flatten_leaves,
                       flatten_state, leaf_digest, unflatten_state)


def _snapshot_device(leaf):
    """On-device copy + async D2H kick. The copy decouples the snapshot
    from donation: step N+1 may donate the original buffer while the copy
    drains. Returns an object np.asarray() can materialize later."""
    if isinstance(leaf, jax.Array):
        c = jax.numpy.copy(leaf)
        try:
            c.copy_to_host_async()
        except (AttributeError, RuntimeError):
            pass
        return c
    return np.asarray(leaf)


class FileCheckpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 n_shards: int = 1, fmt: str = "bin",
                 io_workers: Optional[int] = None,
                 delta_every: int = 0, delta_max_dirty: float = 0.5):
        if fmt not in ("bin", "npz"):
            raise ValueError(f"fmt must be 'bin' or 'npz', got {fmt!r}")
        self.dir = directory
        self.keep = keep
        self.n_shards = n_shards
        self.fmt = fmt
        # delta_every=K>1: base every K-th save, tile-range deltas between
        self.delta_every = delta_every
        self._chain = serde.ChainPlanner(delta_every, delta_max_dirty)
        self.last_write: dict = {}      # {"kind", "bytes"} of newest save
        self._io_workers = io_workers or min(8, max(2, n_shards))
        self._pool: Optional[ThreadPoolExecutor] = None      # shard fan-out
        self._writer: Optional[ThreadPoolExecutor] = None    # ordered jobs
        self._pending: deque[Future] = deque()
        self._error: Optional[BaseException] = None
        self._live_tmps: set[str] = set()
        self._lock = threading.Lock()
        os.makedirs(directory, exist_ok=True)

    @property
    def _delta_on(self) -> bool:
        return self.fmt == "bin" and self.delta_every > 1

    @property
    def delta_max_dirty(self) -> float:
        return self._chain.max_dirty

    @delta_max_dirty.setter
    def delta_max_dirty(self, v: float):
        self._chain.max_dirty = v

    # ----------------------------------------------------------- helpers

    def _shard_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._io_workers,
                thread_name_prefix="ckpt-io")
        return self._pool

    def _writer_pool(self) -> ThreadPoolExecutor:
        # one worker: writes stay ordered (step N commits before N+1)
        if self._writer is None:
            self._writer = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="ckpt-writer")
        return self._writer

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                p = os.path.join(self.dir, name)
                if os.path.exists(os.path.join(p, "COMMITTED")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def _manifest(self, step: int) -> Manifest:
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return Manifest.from_json(f.read())

    def _chain_closure(self, steps: list[int]) -> set[int]:
        """`steps` plus every base step their delta chains depend on."""
        need = set(steps)
        stack = list(steps)
        while stack:
            try:
                man = self._manifest(stack.pop())
            except (OSError, ValueError):
                continue
            b = man.base_step
            if man.kind == "delta" and b is not None and b not in need:
                need.add(b)
                stack.append(b)
        return need

    def _gc(self):
        steps = self.steps()
        if self.keep and len(steps) > self.keep:
            # a kept delta's chain anchor must outlive the keep window
            need = self._chain_closure(steps[-self.keep:])
            for s in steps[:-self.keep]:
                if s not in need:
                    shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # remove uncommitted junk from crashed writers — but never a live
        # tmp dir of *this* process's in-flight async writer (with zero
        # committed steps the old endswith(()) guard matched nothing and
        # a concurrent writer's tmp dir could be reaped mid-write)
        keep_names = {f"step_{s:010d}" for s in self.steps()}
        with self._lock:
            live = set(self._live_tmps)
        for name in os.listdir(self.dir):
            p = os.path.join(self.dir, name)
            if (name.startswith(("step_", "tmp_"))
                    and name not in keep_names
                    and name not in live
                    and not os.path.exists(os.path.join(p, "COMMITTED"))):
                shutil.rmtree(p, ignore_errors=True)

    def _raise_pending_error(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -------------------------------------------------------------- save

    def save(self, step: int, state: Any, *, async_: bool = False,
             extra: dict | None = None):
        """Checkpoint `state` at `step`.

        Sync: materialize on the caller thread and write (blocking).
        Async: on-device snapshot + async D2H now, serialization and IO
        on the writer thread; up to one snapshot queues behind the one
        draining (double buffering), further saves block on the oldest.
        """
        self._raise_pending_error()
        if not async_:
            self.wait()
            flat = flatten_state(state)      # blocking device_get
            self._write(step, flat, None, extra)
            return
        while len(self._pending) >= 2:       # double-buffer bound
            self._pending.popleft().result()
            self._raise_pending_error()
        dev_flat = flatten_leaves(state)
        snap = {k: _snapshot_device(v) for k, v in dev_flat.items()}
        dev_sums = dev_tiles = None
        if self.fmt == "bin" and jax.default_backend() != "cpu":
            # digest on device from the snapshot copies — the word-sum
            # reductions are *enqueued* here (they ride the same stream
            # as the D2H drain) but never awaited on this thread; the
            # writer int()s the 8B/leaf results later. (On the CPU
            # backend a jnp reduction is just a slower numpy, so there
            # the parallel shard writers digest instead.) With deltas on,
            # the *tiled* reduction is enqueued instead: its 12 B/tile
            # output both localizes dirty tiles (the on-device diff) and
            # folds into the scalar leaf digest, so one pass serves both.
            if self._delta_on:
                from repro.kernels.checksum.ops import tile_checksums_device
                dev_tiles = {}
                for k, v in snap.items():
                    if isinstance(v, jax.Array):
                        try:
                            dev_tiles[k] = (str(v.dtype), tuple(v.shape),
                                            int(v.nbytes),
                                            tile_checksums_device(v))
                        except TypeError:     # exotic itemsize: host path
                            pass
            else:
                from repro.kernels.checksum.ops import checksum_words_device
                dev_sums = {
                    k: (str(v.dtype), tuple(v.shape),
                        checksum_words_device(v))
                    for k, v in snap.items() if isinstance(v, jax.Array)}
        fut = self._writer_pool().submit(
            self._write_guarded, step, snap, dev_sums, dev_tiles, extra)
        self._pending.append(fut)

    def _write_guarded(self, step, snap, dev_sums, dev_tiles, extra):
        try:
            flat = {k: np.asarray(v) for k, v in snap.items()}
            digests = None
            tiles = None
            if dev_sums is not None:
                digests = {}
                for k, (dt, sh, s) in dev_sums.items():
                    s0, s1 = (0, 0) if s is None else (int(s[0]), int(s[1]))
                    digests[k] = digest_from_checksum(dt, sh, s0, s1)
            if dev_tiles is not None:
                from repro.kernels.checksum.ref import scalar_from_tiles
                digests, tiles = {}, {}
                for k, (dt, sh, nb, t) in dev_tiles.items():
                    rows = np.zeros((0, 3), np.uint32) if t is None \
                        else np.asarray(t)
                    tiles[k] = serde.LeafTiles(nb, dt, sh, rows)
                    digests[k] = digest_from_checksum(
                        dt, sh, *scalar_from_tiles(rows))
            self._write(step, flat, digests, extra, tiles=tiles)
        except BaseException as e:   # surfaced on next wait()/save()
            self._error = e

    def _delta_decision(self, step: int, flat, tiles):
        """Returns (kind, plan, tiles, base_step) from the shared chain
        planner. Tiles are computed here (host path) for any leaf the
        device didn't already digest."""
        if not self._delta_on:
            return "full", None, None, None
        if tiles is None or len(tiles) != len(flat):
            tiles = dict(tiles or {})
            for k in flat:
                if k not in tiles:
                    tiles[k] = serde._leaf_tiles(np.asarray(flat[k]))
        return self._chain.decide(flat, step, tiles)

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               digests: Optional[Dict[str, str]], extra,
               tiles: Optional[Dict[str, np.ndarray]] = None):
        keys = sorted(flat)
        shard_of = {k: i % self.n_shards for i, k in enumerate(keys)}
        kind, plan, tiles, base_step = self._delta_decision(step, flat,
                                                            tiles)
        if self._delta_on and digests is None:
            # one tiled pass already happened — fold it into the scalar
            # leaf digests instead of re-reading every byte
            from repro.kernels.checksum.ref import scalar_from_tiles
            digests = {
                k: digest_from_checksum(
                    np.asarray(flat[k]).dtype, np.shape(flat[k]),
                    *scalar_from_tiles(tiles[k].rows))
                for k in keys}
        tmp = os.path.join(self.dir, f"tmp_{step:010d}_{os.getpid()}")
        tmp_name = os.path.basename(tmp)
        with self._lock:
            self._live_tmps.add(tmp_name)
        try:
            os.makedirs(tmp, exist_ok=True)
            nbytes = [0] * self.n_shards
            if self.fmt == "npz":
                man = Manifest.build(step, flat, lambda k: shard_of[k],
                                     self.n_shards, extra, algo="sha256")
                for i in range(self.n_shards):
                    part = {k: flat[k] for k in keys if shard_of[k] == i}
                    np.savez(os.path.join(tmp, f"shard_{i:05d}.npz"),
                             **part)
            else:
                pool = self._shard_pool()

                def one_shard(i: int) -> Dict[str, str]:
                    part = {k: flat[k] for k in keys if shard_of[k] == i}
                    p = os.path.join(tmp, f"shard_{i:05d}.bin")
                    if kind == "delta":
                        nbytes[i] = serde.write_delta_file(
                            p, part, plan, base_step=base_step)
                    else:
                        nbytes[i] = serde.write_file(p, part)
                    # crash-injection point: this shard's bytes are down,
                    # the checkpoint is not yet COMMITTED
                    hooks.fire("ckpt.file.shard", step=step, shard=i)
                    pre = digests or {}
                    return {k: pre.get(k) or leaf_digest(v)
                            for k, v in part.items()}

                shard_digests: Dict[str, str] = {}
                for d in pool.map(one_shard, range(self.n_shards)):
                    shard_digests.update(d)
                man = Manifest.build(step, flat, lambda k: shard_of[k],
                                     self.n_shards, extra,
                                     digests=shard_digests,
                                     kind=kind, base_step=base_step)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                f.write(man.to_json())
            # crash-injection point: shards + manifest written, COMMITTED
            # absent — a kill here must leave this step invisible and the
            # orphaned tmp dir reapable by the next writer's GC
            hooks.fire("ckpt.file.pre_commit", step=step)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        finally:
            with self._lock:
                self._live_tmps.discard(tmp_name)
        if self._delta_on:
            self._chain.commit(step, tiles, kind)
        self.last_write = {"kind": kind, "bytes": sum(nbytes)}
        self._gc()

    def wait(self):
        """Drain the async writer queue; re-raise any background failure."""
        while self._pending:
            self._pending.popleft().result()
        self._raise_pending_error()

    def close(self):
        """Drain pending writes and release the IO thread pools. The
        checkpointer stays usable afterwards (pools respawn lazily)."""
        try:
            self.wait()
        finally:
            for pool in (self._writer, self._pool):
                if pool is not None:
                    pool.shutdown(wait=True)
            self._writer = None
            self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -------------------------------------------------------------- load

    def _read_shard(self, d: str, i: int, man: Manifest, verify: bool):
        """Map one shard and verify its leaves. Returns (views, bad)."""
        bin_path = os.path.join(d, f"shard_{i:05d}.bin")
        if os.path.exists(bin_path):
            _, part = serde.open_file(bin_path, mmap=True)
        else:
            part = {}
            with np.load(os.path.join(d, f"shard_{i:05d}.npz")) as z:
                for k in z.files:
                    part[k] = z[k]
        bad = man.verify(part, paths=list(part)) if verify else []
        return part, bad

    def load(self, step: int, *, verify: bool = True):
        man = self._manifest(step)
        chain = [man]
        while chain[-1].kind == "delta":
            if chain[-1].base_step is None:
                raise IOError(f"delta step {chain[-1].step} missing base")
            chain.append(self._manifest(chain[-1].base_step))
        chain.reverse()                  # [base, ..., target]
        base = chain[0]
        d = self._step_dir(base.step)
        pool = self._shard_pool()
        flat: Dict[str, np.ndarray] = {}
        bad: list[str] = []
        # verify per-shard only when the base IS the target; composed
        # loads are verified against the target manifest after patching
        base_verify = verify and len(chain) == 1
        for part, shard_bad in pool.map(
                lambda i: self._read_shard(d, i, base, base_verify),
                range(base.n_shards)):
            flat.update(part)
            bad.extend(shard_bad)
        writable: set = set()            # each dirty leaf copies once
        for dman in chain[1:]:           # apply memmapped delta frames
            # interruption point: mid delta-chain compose of a restore
            hooks.fire("ckpt.file.compose", step=dman.step)
            dd = self._step_dir(dman.step)
            for i in range(dman.n_shards):
                buf = np.memmap(os.path.join(dd, f"shard_{i:05d}.bin"),
                                dtype=np.uint8, mode="r")
                _, _, flat = serde.apply_delta(flat, buf, writable)
        if verify and len(chain) > 1:
            by_shard = {}
            for k, meta in man.leaves.items():
                by_shard.setdefault(meta["shard"], []).append(k)
            for shard_bad in pool.map(
                    lambda ks: man.verify(flat, paths=ks),
                    by_shard.values()):
                bad.extend(shard_bad)
        if verify:
            bad.extend(k for k in man.leaves if k not in flat)
            if bad:
                raise IOError(f"checkpoint step {step} corrupted: {bad[:5]}")
        return man, unflatten_state(flat)

    def load_latest(self, *, verify: bool = True):
        """Returns (step, state) of the newest committed checkpoint or
        (None, None) when none exists. Shards come back memory-mapped —
        restore pays page-in cost only for bytes actually touched."""
        steps = self.steps()
        if not steps:
            return None, None
        man, state = self.load(steps[-1], verify=verify)
        return man.step, state
