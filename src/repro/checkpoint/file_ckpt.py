"""Sharded, atomic, optionally-async file checkpoints.

Layout:
    <dir>/step_<N>/shard_<i>.npz     one npz per writer shard
    <dir>/step_<N>/manifest.json     shapes/dtypes/digests per leaf
    <dir>/step_<N>/COMMITTED         written last — crash-consistency marker

A checkpoint without COMMITTED is garbage from a crashed writer and is
ignored (and garbage-collected) by load_latest. Writes go to a tmp dir that
is os.rename()d into place, so readers never observe partial npz files.

The async mode snapshots the state synchronously (device_get — the step is
already finished) and performs serialization + IO on a writer thread; the
paper's CR baseline measures exactly this file path against buddy memory
checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from .manifest import Manifest, flatten_state, unflatten_state


class FileCheckpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 n_shards: int = 1):
        self.dir = directory
        self.keep = keep
        self.n_shards = n_shards
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------- helpers

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                p = os.path.join(self.dir, name)
                if os.path.exists(os.path.join(p, "COMMITTED")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # also remove uncommitted junk
        for name in os.listdir(self.dir):
            p = os.path.join(self.dir, name)
            if (name.startswith(("step_", "tmp_"))
                    and not os.path.exists(os.path.join(p, "COMMITTED"))
                    and not p.endswith(tuple(f"step_{s:010d}" for s in steps))):
                shutil.rmtree(p, ignore_errors=True)

    # -------------------------------------------------------------- save

    def save(self, step: int, state: Any, *, async_: bool = False,
             extra: dict | None = None):
        """Checkpoint `state` at `step`. With async_=True the device->host
        copy happens now, serialization/IO on a background thread."""
        self.wait()
        host_state = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                  state)
        if async_:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host_state, extra),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state, extra)

    def _write_guarded(self, step, host_state, extra):
        try:
            self._write(step, host_state, extra)
        except BaseException as e:   # surfaced on next wait()/save()
            self._error = e

    def _write(self, step: int, host_state, extra):
        flat = flatten_state(host_state)
        keys = sorted(flat)
        shard_of = {k: i % self.n_shards for i, k in enumerate(keys)}
        man = Manifest.build(step, flat, lambda k: shard_of[k],
                             self.n_shards, extra)
        tmp = os.path.join(self.dir, f"tmp_{step:010d}_{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        for i in range(self.n_shards):
            part = {k: flat[k] for k in keys if shard_of[k] == i}
            np.savez(os.path.join(tmp, f"shard_{i:05d}.npz"), **part)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            f.write(man.to_json())
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        """Join the async writer; re-raise any background failure."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -------------------------------------------------------------- load

    def load(self, step: int, *, verify: bool = True):
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            man = Manifest.from_json(f.read())
        flat: dict = {}
        for i in range(man.n_shards):
            with np.load(os.path.join(d, f"shard_{i:05d}.npz")) as z:
                for k in z.files:
                    flat[k] = z[k]
        if verify:
            bad = man.verify(flat)
            if bad:
                raise IOError(f"checkpoint step {step} corrupted: {bad[:5]}")
        return man, unflatten_state(flat)

    def load_latest(self, *, verify: bool = True):
        """Returns (step, state) of the newest committed checkpoint or
        (None, None) when none exists."""
        steps = self.steps()
        if not steps:
            return None, None
        man, state = self.load(steps[-1], verify=verify)
        return man.step, state
