"""Sharded, atomic, overlap-capable file checkpoints.

Layout:
    <dir>/step_<N>/shard_<i>.bin     one serde frame per writer shard
    <dir>/step_<N>/manifest.json     shapes/dtypes/digests per leaf
    <dir>/step_<N>/COMMITTED         written last — crash-consistency marker
    <dir>/step_<N>/rebase/           optional: the same step re-written as
                                     a self-contained full frame by the
                                     background re-base (own manifest +
                                     COMMITTED; preferred at load time)

A checkpoint without COMMITTED is garbage from a crashed writer and is
ignored (and garbage-collected) by load_latest. Writes go to a tmp dir that
is os.rename()d into place, so readers never observe partial shards.

Fast-path engine (the paper's argument made real — recovery speed is won
in the checkpoint substrate):

  write   leaves are digested while still on device (Pallas/jnp word-sum;
          only 8 bytes per leaf cross to the host for the manifest), then
          drained leaf-by-leaf via copy_to_host_async and streamed into
          serde frames by a thread pool, one worker per shard. Sync and
          async saves share the same on-device digest path — a sync save
          never host-hashes bytes the device already digested.
  async   save() snapshots the state with a cheap on-device copy (so the
          trainer may donate its buffers to step N+1 immediately), kicks
          the device→host DMA per leaf, and queues serialization + IO on
          a single ordered writer thread. A bounded queue of depth 2
          double-buffers snapshots: snapshot N drains while step N+1
          runs; save(N+2) blocks only if N hasn't committed yet.
  read    shards are memory-mapped (no read syscalls for the bulk data)
          and digest-verified per-shard in parallel before the views are
          stitched back into a pytree.

  delta   with delta_every=K > 1, a full (base) snapshot is written every
          K-th save and the saves between record only dirty 4 KB tile
          ranges against the previous save (chained): consecutive
          snapshots are diffed by per-tile word-sum digests computed on
          device (only 12 B/tile crosses PCIe), so a 5%-dirty state
          writes ~5% of the bytes. Restores walk the chain down to the
          base, apply patches upward from memmapped delta frames, and
          verify the *composed* state against the target manifest —
          bit-exact or it raises. GC never reaps a base a kept delta
          still needs. A save whose dirty fraction exceeds 50% degrades
          to a base automatically.

  gather  (delta saves on accelerators, or gather="on") the *transfer*
          is made proportional to dirt too: the per-tile digest rows
          decide which tiles changed, a Pallas/jnp gather compacts
          exactly those tiles into one contiguous device buffer, and
          only that buffer (plus 12 B/tile of digest rows) crosses
          device→host. Delta frames are then built directly from the
          gathered tiles — the full snapshot is never materialized on
          the host. The full-state drain survives only where it is
          needed: base-cadence saves (predicted at submit time so the
          DMA still overlaps), dirty-degraded saves, and the CPU-backend
          fallback. `last_write["d2h_bytes"]` accounts what crossed.

  rebase  (rebase_after=N / rebase_max_bytes=B) a background writer-pool
          thread rewrites a delta chain as a fresh self-contained base
          once its compose cost crosses the threshold (chain links,
          or cumulative delta bytes), so `delta_every` can be raised
          aggressively without unbounded restore cost. Crash-safe: the
          full frame is staged inside the step dir and committed by one
          atomic rename to `rebase/`; the old chain (and its base
          anchor) is never touched before that COMMITTED lands, and is
          GC'd only afterwards, via the normal chain-closure walk.
          `ckpt.file.rebase.{begin,pre_commit}` are scenario hook
          points.

`fmt="npz"` preserves the legacy np.savez + sha256 path byte-for-byte so
benchmarks/checkpoint_bench.py can report old-vs-new on the same class.
npz shards are always full archives, so delta_every is force-disabled
there — a "delta" decision over full npz bytes would corrupt the chain
bookkeeping.
"""
from __future__ import annotations

import os
import shutil
import threading
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.scenarios import hooks

from . import serde
from .manifest import (Manifest, digest_from_checksum, flatten_leaves,
                       flatten_state, leaf_digest, unflatten_state)


def _snapshot_device(leaf, *, kick: bool = True):
    """On-device copy + (optional) async D2H kick. The copy decouples the
    snapshot from donation: step N+1 may donate the original buffer while
    the copy drains. With kick=False the copy stays on device — the
    gather path moves only dirty tiles later, so kicking the full drain
    here would defeat it. Returns an object np.asarray() can materialize
    later."""
    if isinstance(leaf, jax.Array):
        c = jax.numpy.copy(leaf)
        if kick:
            try:
                c.copy_to_host_async()
            except (AttributeError, RuntimeError):
                pass
        return c
    return np.asarray(leaf)


class _LeafMeta:
    """Shape/dtype stand-in for a leaf whose bytes never reached the
    host (gathered delta saves build manifests from these)."""

    __slots__ = ("shape", "dtype")

    def __init__(self, shape, dtype):
        self.shape = shape
        self.dtype = dtype


class FileCheckpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 n_shards: int = 1, fmt: str = "bin",
                 io_workers: Optional[int] = None,
                 delta_every: int = 0, delta_max_dirty: float = 0.5,
                 gather: str = "auto", rebase_after: int = 0,
                 rebase_max_bytes: int = 0):
        if fmt not in ("bin", "npz"):
            raise ValueError(f"fmt must be 'bin' or 'npz', got {fmt!r}")
        if gather not in ("auto", "on", "off"):
            raise ValueError(f"gather must be auto/on/off, got {gather!r}")
        if fmt == "npz" and delta_every > 1:
            # npz shards are always full np.savez archives; honoring a
            # "delta" decision would write full bytes while the chain
            # planner records a delta kind — incoherent. Force full
            # frames and never engage the planner.
            delta_every = 0
        self.dir = directory
        self.keep = keep
        self.n_shards = n_shards
        self.fmt = fmt
        # delta_every=K>1: base every K-th save, tile-range deltas between
        self.delta_every = delta_every
        # gather: "auto" = device dirty-tile gather on accelerator
        # backends; "on" forces it (tests/benches on CPU); "off" keeps
        # the full-drain delta path
        self.gather = gather
        # background re-base thresholds (0 = off): chain links /
        # cumulative delta bytes under the newest step
        self.rebase_after = rebase_after
        self.rebase_max_bytes = rebase_max_bytes
        self._chain = serde.ChainPlanner(self.delta_every, delta_max_dirty)
        self.last_write: dict = {}   # {"kind", "bytes", "d2h_bytes"}
        self.last_rebase: dict = {}  # {"step", "ok"[, "error"]}
        self._io_workers = io_workers or min(8, max(2, n_shards))
        self._pool: Optional[ThreadPoolExecutor] = None      # shard fan-out
        self._writer: Optional[ThreadPoolExecutor] = None    # ordered jobs
        self._rebase_pool: Optional[ThreadPoolExecutor] = None
        self._pending: deque[Future] = deque()
        self._rebase_pending: deque[Future] = deque()
        self._rebase_busy = False           # guarded-by: _lock
        self._error: Optional[BaseException] = None
        self._live_tmps: set[str] = set()   # guarded-by: _lock
        self._lock = threading.Lock()
        os.makedirs(directory, exist_ok=True)

    @property
    def _delta_on(self) -> bool:
        return self.fmt == "bin" and self.delta_every > 1

    @property
    def _gather_on(self) -> bool:
        if not self._delta_on or self.gather == "off":
            return False
        return self.gather == "on" or jax.default_backend() != "cpu"

    @property
    def _device_digests_on(self) -> bool:
        # on the CPU backend a jnp reduction is just a slower numpy, so
        # there the parallel shard writers digest instead — unless the
        # gather path is forced on (its decisions need the tile rows)
        return self.fmt == "bin" and (jax.default_backend() != "cpu"
                                      or self.gather == "on")

    @property
    def delta_max_dirty(self) -> float:
        return self._chain.max_dirty

    @delta_max_dirty.setter
    def delta_max_dirty(self, v: float):
        self._chain.max_dirty = v

    # ----------------------------------------------------------- helpers

    def _shard_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._io_workers,
                thread_name_prefix="ckpt-io")
        return self._pool

    def _writer_pool(self) -> ThreadPoolExecutor:
        # one worker: writes stay ordered (step N commits before N+1)
        if self._writer is None:
            self._writer = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="ckpt-writer")
        return self._writer

    def _rebase_pool_get(self) -> ThreadPoolExecutor:
        # separate single thread: a slow compose must never stall the
        # ordered writer behind it
        if self._rebase_pool is None:
            self._rebase_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="ckpt-rebase")
        return self._rebase_pool

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def _frame_dir(self, step: int) -> str:
        """Where the step's authoritative frame lives: the committed
        `rebase/` subdir when the background re-base has landed, else
        the step dir itself."""
        d = self._step_dir(step)
        rb = os.path.join(d, "rebase")
        if os.path.exists(os.path.join(rb, "COMMITTED")):
            return rb
        return d

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                p = os.path.join(self.dir, name)
                if os.path.exists(os.path.join(p, "COMMITTED")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def _manifest(self, step: int) -> Manifest:
        with open(os.path.join(self._frame_dir(step),
                               "manifest.json")) as f:
            return Manifest.from_json(f.read())

    def _chain_closure(self, steps: list[int]) -> set[int]:
        """`steps` plus every base step their delta chains depend on.
        A committed re-base cuts the walk — its step reads back as a
        full frame, so the old anchor drops out of the closure (and
        becomes GC-able) exactly when the new base's COMMITTED lands."""
        need = set(steps)
        stack = list(steps)
        while stack:
            try:
                man = self._manifest(stack.pop())
            except (OSError, ValueError):
                continue
            b = man.base_step
            if man.kind == "delta" and b is not None and b not in need:
                need.add(b)
                stack.append(b)
        return need

    def _gc(self):
        steps = self.steps()
        if self.keep and len(steps) > self.keep:
            # a kept delta's chain anchor must outlive the keep window
            need = self._chain_closure(steps[-self.keep:])
            for s in steps[:-self.keep]:
                if s not in need:
                    shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # remove uncommitted junk from crashed writers — but never a live
        # tmp dir of *this* process's in-flight async writer (with zero
        # committed steps the old endswith(()) guard matched nothing and
        # a concurrent writer's tmp dir could be reaped mid-write)
        keep_names = {f"step_{s:010d}" for s in self.steps()}
        with self._lock:
            live = set(self._live_tmps)
        for name in os.listdir(self.dir):
            p = os.path.join(self.dir, name)
            if (name.startswith(("step_", "tmp_"))
                    and name not in keep_names
                    and name not in live
                    and not os.path.exists(os.path.join(p, "COMMITTED"))):
                shutil.rmtree(p, ignore_errors=True)

    def _raise_pending_error(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -------------------------------------------------------------- save

    def save(self, step: int, state: Any, *, async_: bool = False,
             extra: dict | None = None):
        """Checkpoint `state` at `step`.

        Sync: digest (on device, where there is one) and write on the
        caller thread (blocking). Async: on-device snapshot now, with
        the full D2H drain kicked only when the chain planner says the
        full bytes will be needed; serialization and IO run on the
        writer thread. Up to one snapshot queues behind the one draining
        (double buffering); further saves block on the oldest.
        """
        self._raise_pending_error()
        if self.fmt == "npz":
            # legacy comparison path: host materialize + sha256
            self._drain_writes()
            flat = flatten_state(state)
            self._write(step, flat, None, extra)
            return
        if async_:
            while len(self._pending) >= 2:   # double-buffer bound
                self._pending.popleft().result()
                self._raise_pending_error()
        else:
            # drain queued writes only — an in-flight background re-base
            # must never stall the save path
            self._drain_writes()
        dev_flat = flatten_leaves(state)
        # kick the full drain only when the planner is certain this save
        # is a base (or the gather path is off) — a delta save will move
        # just its gathered dirty tiles
        kick = not self._gather_on or self._chain.predict_full(step)
        if async_:
            snap = {k: _snapshot_device(v, kick=kick)
                    for k, v in dev_flat.items()}
        else:
            snap = dev_flat   # sync blocks: no donation hazard, no copy
        dev_sums = dev_tiles = None
        if self._device_digests_on:
            # digest on device from the snapshot — the word-sum
            # reductions are *enqueued* here (they ride the same stream
            # as any D2H drain) but never awaited on this thread; the
            # writer int()s the 8B/leaf results later. With deltas on,
            # the *tiled* reduction is enqueued instead: its 12 B/tile
            # output localizes dirty tiles (driving both the delta plan
            # and the device gather) and folds into the scalar leaf
            # digest, so one pass serves both.
            if self._delta_on:
                from repro.kernels.checksum.ops import tile_checksums_device
                dev_tiles = {}
                for k, v in snap.items():
                    if isinstance(v, jax.Array):
                        try:
                            dev_tiles[k] = (str(v.dtype), tuple(v.shape),
                                            int(v.nbytes),
                                            tile_checksums_device(v))
                        except TypeError:     # exotic itemsize: host path
                            pass
            else:
                from repro.kernels.checksum.ops import checksum_words_device
                dev_sums = {
                    k: (str(v.dtype), tuple(v.shape),
                        checksum_words_device(v))
                    for k, v in snap.items() if isinstance(v, jax.Array)}
        if async_:
            fut = self._writer_pool().submit(
                self._write_guarded, step, snap, dev_sums, dev_tiles,
                extra)
            self._pending.append(fut)
        else:
            self._write_prepared(step, snap, dev_sums, dev_tiles, extra)

    def _write_guarded(self, step, snap, dev_sums, dev_tiles, extra):
        try:
            self._write_prepared(step, snap, dev_sums, dev_tiles, extra)
        except BaseException as e:   # surfaced on next wait()/save()
            self._error = e

    def _drain(self, snap, counter: list) -> Dict[str, np.ndarray]:
        """Materialize every snapshot leaf on the host (the full-drain
        fallback), charging transferred device bytes to `counter[0]`."""
        flat = {}
        for k, v in snap.items():
            a = np.asarray(v)
            if isinstance(v, jax.Array):
                counter[0] += a.nbytes
            flat[k] = a
        return flat

    def _write_prepared(self, step, snap, dev_sums, dev_tiles, extra):
        """Shared sync/async write body: fold device digests, decide
        full-vs-delta, then either gather dirty tiles (transfer O(dirt))
        or drain the full snapshot (base / degraded / CPU fallback)."""
        d2h = [0]
        if dev_tiles is not None:
            from repro.kernels.checksum.ref import scalar_from_tiles
            tiles: Dict[str, serde.LeafTiles] = {}
            for k, (dt, sh, nb, t) in dev_tiles.items():
                rows = np.zeros((0, 3), np.uint32) if t is None \
                    else np.asarray(t)
                tiles[k] = serde.LeafTiles(nb, dt, sh, rows)
                d2h[0] += rows.nbytes            # 12 B/tile digest rows
            for k, v in snap.items():            # host / exotic leaves
                if k not in tiles:
                    a = np.asarray(v)
                    if isinstance(v, jax.Array):
                        d2h[0] += a.nbytes
                    tiles[k] = serde._leaf_tiles(a)
            digests = {k: digest_from_checksum(
                t.dtype, t.shape, *scalar_from_tiles(t.rows))
                for k, t in tiles.items()}
            kind, plan, tiles, base_step = self._chain.decide(
                snap, step, tiles)
            if kind == "delta" and self._gather_on:
                gathered = self._gather(snap, plan, d2h)
                meta = {k: _LeafMeta(t.shape, t.dtype)
                        for k, t in tiles.items()}
                self._write(step, meta, digests, extra, tiles=tiles,
                            decision=(kind, plan, base_step),
                            gathered=gathered, d2h_bytes=d2h[0])
                return
            flat = self._drain(snap, d2h)
            self._write(step, flat, digests, extra, tiles=tiles,
                        decision=(kind, plan, base_step),
                        d2h_bytes=d2h[0])
            return
        flat = self._drain(snap, d2h)
        digests = None
        if dev_sums is not None:
            digests = {}
            for k, (dt, sh, s) in dev_sums.items():
                s0, s1 = (0, 0) if s is None else (int(s[0]), int(s[1]))
                digests[k] = digest_from_checksum(dt, sh, s0, s1)
        self._write(step, flat, digests, extra, d2h_bytes=d2h[0])

    def _gather(self, snap, plan: serde.DeltaPlan,
                d2h: list) -> Dict[str, serde.GatherLeaf]:
        """Device-side dirty-tile gather: one compact gather kernel per
        range-dirty device leaf, D2H kicked for all of them before any
        is awaited, then materialized into the gathered representation
        the delta frame writers consume. Only gathered tiles (O(dirt))
        and plan-full leaves ever cross; clean bytes stay on device."""
        from repro.kernels.checksum.ref import TILE_BYTES
        from repro.kernels.checksum.ops import gather_tiles_device
        dev = {}
        for k, rng in plan.entries.items():
            v = snap[k]
            if rng is None or not isinstance(v, jax.Array):
                continue
            try:
                g = gather_tiles_device(v, serde.range_tiles(rng))
            except TypeError:        # exotic itemsize: host slices below
                continue
            try:
                g.copy_to_host_async()
            except (AttributeError, RuntimeError):
                pass
            dev[k] = g
        gathered: Dict[str, serde.GatherLeaf] = {}
        for k, rng in plan.entries.items():
            v = snap[k]
            dt = str(getattr(v, "dtype", np.asarray(v).dtype))
            sh = tuple(np.shape(v))
            if rng is None:          # new/reshaped leaf: full bytes
                a = np.asarray(v)
                if isinstance(v, jax.Array):
                    d2h[0] += a.nbytes
                bv = serde._leaf_bytes(a)
                gathered[k] = serde.GatherLeaf(
                    dt, sh, True, [(0, int(bv.size), bv)])
            elif k in dev:
                hb = np.asarray(dev[k])   # (n_dirty, TILE_WORDS): O(dirt)
                d2h[0] += hb.nbytes
                bv = hb.reshape(-1).view(np.uint8)
                runs, pos = [], 0
                for o, n in rng:
                    runs.append((o, n, bv[pos:pos + n]))
                    pos += (-(-n // TILE_BYTES)) * TILE_BYTES
                gathered[k] = serde.GatherLeaf(dt, sh, False, runs)
            else:                    # host leaf: zero-copy slices
                bv = serde._leaf_bytes(np.asarray(v))
                gathered[k] = serde.GatherLeaf(
                    dt, sh, False, [(o, n, bv[o:o + n]) for o, n in rng])
        return gathered

    def _delta_decision(self, step: int, flat, tiles):
        """Returns (kind, plan, tiles, base_step) from the shared chain
        planner. Tiles are computed here (host path) for any leaf the
        device didn't already digest."""
        if not self._delta_on:
            return "full", None, None, None
        if tiles is None or len(tiles) != len(flat):
            tiles = dict(tiles or {})
            for k in flat:
                if k not in tiles:
                    tiles[k] = serde._leaf_tiles(np.asarray(flat[k]))
        return self._chain.decide(flat, step, tiles)

    def _write(self, step: int, flat: Dict[str, Any],
               digests: Optional[Dict[str, str]], extra,
               tiles: Optional[Dict[str, Any]] = None,
               decision: Optional[tuple] = None,
               gathered: Optional[Dict[str, serde.GatherLeaf]] = None,
               d2h_bytes: Optional[int] = None):
        """Commit one checkpoint. `flat` maps every leaf path to either
        a host array or (gathered delta saves) a shape/dtype stand-in;
        `decision` short-circuits the chain planner when the caller
        already decided; `gathered` carries the dirty runs a delta's
        shards are written from."""
        keys = sorted(flat)
        shard_of = {k: i % self.n_shards for i, k in enumerate(keys)}
        if decision is None:
            kind, plan, tiles, base_step = self._delta_decision(step, flat,
                                                                tiles)
        else:
            kind, plan, base_step = decision
        if self._delta_on and digests is None:
            # one tiled pass already happened — fold it into the scalar
            # leaf digests instead of re-reading every byte
            from repro.kernels.checksum.ref import scalar_from_tiles
            digests = {
                k: digest_from_checksum(
                    np.asarray(flat[k]).dtype, np.shape(flat[k]),
                    *scalar_from_tiles(tiles[k].rows))
                for k in keys}
        tmp = os.path.join(self.dir, f"tmp_{step:010d}_{os.getpid()}")
        tmp_name = os.path.basename(tmp)
        with self._lock:
            self._live_tmps.add(tmp_name)
        try:
            os.makedirs(tmp, exist_ok=True)
            nbytes = [0] * self.n_shards
            if self.fmt == "npz":
                man = Manifest.build(step, flat, lambda k: shard_of[k],
                                     self.n_shards, extra, algo="sha256")
                for i in range(self.n_shards):
                    part = {k: flat[k] for k in keys if shard_of[k] == i}
                    np.savez(os.path.join(tmp, f"shard_{i:05d}.npz"),
                             **part)
            else:
                pool = self._shard_pool()

                def one_shard(i: int) -> Dict[str, str]:
                    part_keys = [k for k in keys if shard_of[k] == i]
                    p = os.path.join(tmp, f"shard_{i:05d}.bin")
                    if kind == "delta" and gathered is not None:
                        nbytes[i] = serde.write_delta_file_gathered(
                            p, {k: gathered[k] for k in part_keys
                                if k in gathered},
                            base_step=base_step)
                    elif kind == "delta":
                        nbytes[i] = serde.write_delta_file(
                            p, {k: flat[k] for k in part_keys}, plan,
                            base_step=base_step)
                    else:
                        nbytes[i] = serde.write_file(
                            p, {k: flat[k] for k in part_keys})
                    # crash-injection point: this shard's bytes are down,
                    # the checkpoint is not yet COMMITTED
                    hooks.fire("ckpt.file.shard", step=step, shard=i)
                    pre = digests or {}
                    if gathered is not None:
                        return {k: pre[k] for k in part_keys}
                    return {k: pre.get(k) or leaf_digest(flat[k])
                            for k in part_keys}

                shard_digests: Dict[str, str] = {}
                for d in pool.map(one_shard, range(self.n_shards)):
                    shard_digests.update(d)
                man = Manifest.build(step, flat, lambda k: shard_of[k],
                                     self.n_shards, extra,
                                     digests=shard_digests,
                                     kind=kind, base_step=base_step)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                f.write(man.to_json())
            # crash-injection point: shards + manifest written, COMMITTED
            # absent — a kill here must leave this step invisible and the
            # orphaned tmp dir reapable by the next writer's GC
            hooks.fire("ckpt.file.pre_commit", step=step)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        finally:
            with self._lock:
                self._live_tmps.discard(tmp_name)
        if self._delta_on:
            self._chain.commit(step, tiles, kind)
        self.last_write = {"kind": kind, "bytes": sum(nbytes),
                           "d2h_bytes": d2h_bytes}
        self._gc()
        self._maybe_rebase(step, kind)

    # ------------------------------------------------------------ rebase

    def _chain_cost(self, step: int) -> tuple[int, int]:
        """(links, delta_bytes) of the compose chain under `step`,
        walked through manifests — a committed re-base reads back as a
        full frame and zeroes the cost."""
        links = nbytes = 0
        man = self._manifest(step)
        while man.kind == "delta" and man.base_step is not None:
            links += 1
            d = self._frame_dir(man.step)
            for i in range(man.n_shards):
                try:
                    nbytes += os.path.getsize(
                        os.path.join(d, f"shard_{i:05d}.bin"))
                except OSError:
                    pass
            man = self._manifest(man.base_step)
        return links, nbytes

    def _maybe_rebase(self, step: int, kind: str):
        if kind != "delta" or (self.rebase_after <= 0
                               and self.rebase_max_bytes <= 0):
            return
        with self._lock:
            if self._rebase_busy:
                return          # one compaction in flight at a time
        try:
            links, nbytes = self._chain_cost(step)
        except (OSError, ValueError):
            return
        if ((self.rebase_after > 0 and links >= self.rebase_after)
                or (self.rebase_max_bytes > 0
                    and nbytes >= self.rebase_max_bytes)):
            with self._lock:
                self._rebase_busy = True
            self._rebase_pending.append(
                self._rebase_pool_get().submit(self._rebase_guarded,
                                               step))

    def _rebase_guarded(self, step: int):
        try:
            self._rebase(step)
            self.last_rebase = {"step": step, "ok": True}
        except BaseException as e:
            # re-base is an optimization: a failed/aborted attempt must
            # never take the writer down — the old chain is still whole
            self.last_rebase = {"step": step, "ok": False,
                                "error": repr(e)}
        finally:
            with self._lock:
                self._rebase_busy = False

    def _rebase(self, step: int):
        """Rewrite `step` (a delta-chain tip) as a self-contained full
        frame in `step_<N>/rebase/`. Later deltas keep chaining to this
        step by number; their compose walk now stops here. Crash-safe:
        everything is staged in a tmp subdir and committed by a single
        atomic rename *after* COMMITTED is inside — a kill at any point
        leaves the old chain authoritative and bit-exactly loadable."""
        hooks.fire("ckpt.file.rebase.begin", step=step)
        d = self._step_dir(step)
        if os.path.exists(os.path.join(d, "rebase", "COMMITTED")):
            return                           # already compacted
        man, state = self.load(step, verify=True)   # composed, verified
        flat = flatten_state(state)
        keys = sorted(flat)
        shard_of = {k: i % self.n_shards for i, k in enumerate(keys)}
        for name in os.listdir(d):           # crashed/aborted attempts
            if name.startswith("rebase.tmp"):
                shutil.rmtree(os.path.join(d, name), ignore_errors=True)
        tmp = os.path.join(d, f"rebase.tmp_{os.getpid()}")
        os.makedirs(tmp)
        for i in range(self.n_shards):
            part = {k: flat[k] for k in keys if shard_of[k] == i}
            serde.write_file(os.path.join(tmp, f"shard_{i:05d}.bin"),
                             part)
        # digests carry over verbatim: the old manifest already
        # describes exactly this composed state
        new_man = Manifest.build(
            step, flat, lambda k: shard_of[k], self.n_shards, man.extra,
            digests={k: man.leaves[k]["digest"] for k in keys},
            kind="full", base_step=None)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            f.write(new_man.to_json())
        # crash-injection point: full frame staged, not yet committed —
        # a kill here must leave the old chain authoritative and the
        # stale tmp reapable by the next attempt
        hooks.fire("ckpt.file.rebase.pre_commit", step=step)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        os.rename(tmp, os.path.join(d, "rebase"))
        # the old anchor may now age out of the keep window — reap it
        self._gc()

    def _drain_writes(self):
        while self._pending:
            self._pending.popleft().result()
        self._raise_pending_error()

    def wait(self):
        """Drain the async writer queue and any in-flight background
        re-base; re-raise any background write failure."""
        self._drain_writes()
        while self._rebase_pending:
            self._rebase_pending.popleft().result()
        self._raise_pending_error()

    def close(self):
        """Drain pending writes and release the IO thread pools. The
        checkpointer stays usable afterwards (pools respawn lazily)."""
        try:
            self.wait()
        finally:
            for pool in (self._writer, self._pool, self._rebase_pool):
                if pool is not None:
                    pool.shutdown(wait=True)
            self._writer = None
            self._pool = None
            self._rebase_pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -------------------------------------------------------------- load

    def _read_shard(self, d: str, i: int, man: Manifest, verify: bool):
        """Map one shard and verify its leaves. Returns (views, bad)."""
        bin_path = os.path.join(d, f"shard_{i:05d}.bin")
        if os.path.exists(bin_path):
            _, part = serde.open_file(bin_path, mmap=True)
        else:
            part = {}
            with np.load(os.path.join(d, f"shard_{i:05d}.npz")) as z:
                for k in z.files:
                    part[k] = z[k]
        bad = man.verify(part, paths=list(part)) if verify else []
        return part, bad

    def load(self, step: int, *, verify: bool = True):
        man = self._manifest(step)
        chain = [man]
        while chain[-1].kind == "delta":
            if chain[-1].base_step is None:
                raise IOError(f"delta step {chain[-1].step} missing base")
            chain.append(self._manifest(chain[-1].base_step))
        chain.reverse()                  # [base, ..., target]
        base = chain[0]
        # a re-based step reads from its rebase/ subdir (full frame)
        d = self._frame_dir(base.step)
        pool = self._shard_pool()
        flat: Dict[str, np.ndarray] = {}
        bad: list[str] = []
        # verify per-shard only when the base IS the target; composed
        # loads are verified against the target manifest after patching
        base_verify = verify and len(chain) == 1
        for part, shard_bad in pool.map(
                lambda i: self._read_shard(d, i, base, base_verify),
                range(base.n_shards)):
            flat.update(part)
            bad.extend(shard_bad)
        writable: set = set()            # each dirty leaf copies once
        for dman in chain[1:]:           # apply memmapped delta frames
            # interruption point: mid delta-chain compose of a restore
            hooks.fire("ckpt.file.compose", step=dman.step)
            dd = self._step_dir(dman.step)
            for i in range(dman.n_shards):
                buf = np.memmap(os.path.join(dd, f"shard_{i:05d}.bin"),
                                dtype=np.uint8, mode="r")
                _, _, flat = serde.apply_delta(flat, buf, writable)
        if verify and len(chain) > 1:
            by_shard = {}
            for k, meta in man.leaves.items():
                by_shard.setdefault(meta["shard"], []).append(k)
            for shard_bad in pool.map(
                    lambda ks: man.verify(flat, paths=ks),
                    by_shard.values()):
                bad.extend(shard_bad)
        if verify:
            bad.extend(k for k in man.leaves if k not in flat)
            if bad:
                raise IOError(f"checkpoint step {step} corrupted: {bad[:5]}")
        return man, unflatten_state(flat)

    def load_latest(self, *, verify: bool = True):
        """Returns (step, state) of the newest committed checkpoint or
        (None, None) when none exists. Shards come back memory-mapped —
        restore pays page-in cost only for bytes actually touched."""
        steps = self.steps()
        if not steps:
            return None, None
        man, state = self.load(steps[-1], verify=verify)
        return man.step, state
