"""Checkpoint cadence + Table-2 scheme selection (failure × strategy).

                 CR      ULFM     Reinit++
    process      file    memory   memory
    node         file    file     file

CR always re-deploys, so only permanent storage survives; memory (buddy)
checkpoints are valid only for single process failures — a node failure can
wipe both the local and the buddy copy, hence file.
"""
from __future__ import annotations

import dataclasses

TABLE2 = {
    ("process", "cr"): "file",
    ("process", "ulfm"): "memory",
    ("process", "reinit"): "memory",
    ("node", "cr"): "file",
    ("node", "ulfm"): "file",
    ("node", "reinit"): "file",
    # elastic shrinking recovery: like Reinit++ while spares absorb the
    # loss (a node loss takes the buddy copies with it -> file). Once the
    # pool is exhausted the recovery *shrinks* instead of respawning and
    # survivors restore from their own local memory — that branch is
    # modeled explicitly by the executors, not through this table.
    ("process", "shrink"): "memory",
    ("node", "shrink"): "file",
    # replica failover: the warm shadow *is* the memory tier, and it is
    # admitted off-node by construction, so even a node loss leaves it
    # intact — the promoted shadow composes its streamed frames without
    # ever touching the file tier. (When no warm shadow exists the root
    # falls back to Reinit++, which uses that row of this table.)
    ("process", "replica"): "memory",
    ("node", "replica"): "memory",
}


#: restore scheme of a grow-back (elastic re-admission): the rejoining
#: ranks were *out of the world* — nobody held buddy copies for them, so
#: their last durable state is the file tier (the checkpoints they
#: committed before being dropped, which survivors keep pinned as the
#: grow anchor). Survivors roll back from their own local copies.
GROW_RESTORE_KIND = "file"


def checkpoint_kind_for(failure: str, strategy: str) -> str:
    if failure == "grow":
        return GROW_RESTORE_KIND
    return TABLE2[(failure, strategy)]


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Every-N-steps cadence; the paper checkpoints after every iteration."""
    every_steps: int = 1
    async_file: bool = True
    keep: int = 3

    def should_checkpoint(self, step: int) -> bool:
        return step % self.every_steps == 0
