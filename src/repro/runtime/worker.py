"""Worker process: the resilient MPI-rank analogue.

Runs `reinit_main` around a BSP compute loop (numpy matmul + tree
allreduce through the daemon/root control plane — the world communicator).
Checkpoints after every iteration: a local in-memory copy plus a push to
the buddy rank's peer socket (memory scheme), and a file checkpoint (file
scheme) — exactly Table 2's matrix.

Fault injection (paper §4): at the pre-drawn (step, rank), the victim
SIGKILLs itself (process failure) or asks its daemon to take the whole node
down (node failure). Survivors receive SIGREINIT (SIGUSR1), roll back to
the reinit point, and rejoin the epoch barrier with re-spawned ranks.

Replica mode adds a shadow role (--shadow): the process registers,
receives the primary's per-step checkpoint stream on its peer listener,
and parks outside the BSP loop until the root PROMOTEs it — it then
composes the warm frame for the resume step and enters the loop in the
dead primary's place, with zero rollback and zero respawn.
"""
from __future__ import annotations

import argparse
import os
import shutil
import signal
import socket
import sys
import threading
import time
from typing import Optional

import numpy as np

from repro.core.events import RankState
from repro.core.membership import RankMembership
from repro.core.reinit import ROLLBACK, RollbackSignal, install_sigreinit, \
    reinit_main
from repro.checkpoint import serde
from repro.checkpoint.memory_ckpt import BuddyStore
from repro.scenarios import hooks
from repro.scenarios.schema import Fault, Scenario, gray_delay_s

from .transport import connect, install_lossy, listener, recv_msg, send_msg


class WorkerInjector:
    """Executes this rank's share of a Scenario at the named interruption
    points (installed as the process-global hook target; see
    repro.scenarios.hooks). Each fault fires exactly once per *run* — an
    O_EXCL sentinel in the shared checkpoint dir survives respawns, so a
    restarted incarnation never re-kills itself.

    Faults at point="step" die behind the FENCE kill barrier (the root
    releases it once every other rank has committed that step's
    checkpoint), making the post-recovery consistent cut deterministic;
    phase-point faults interrupt the checkpoint/recovery machinery at
    their natural program point and rely on the rollback consensus."""

    def __init__(self, worker, plan: list):
        self.w = worker
        self.plan = plan                      # [(fault_index, Fault)]

    def __call__(self, point: str, step=None, **ctx):
        for idx, f in self.plan:
            if f.point != point:
                continue
            if f.step is not None and step is not None and f.step != step:
                continue
            if self._claim(idx, point, step):
                self._execute(f, step)

    def _claim(self, idx: int, point: str, step) -> bool:
        sentinel = os.path.join(self.w.ckpt_dir, f"INJECTED_f{idx}")
        try:
            fd = os.open(sentinel, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False
        os.write(fd, f"rank={self.w.rank} point={point} "
                     f"step={step}".encode())
        os.close(fd)
        return True

    def _fence(self, step):
        if step is None:
            return
        w = self.w
        try:
            w._send_daemon({"type": "FENCE", "rank": w.rank,
                            "epoch": w.epoch, "step": step})
            w._wait_release(("fence", step), w.epoch, timeout=60.0)
        except (RollbackSignal, TimeoutError, OSError):
            pass          # recovery already racing us: die anyway

    def _execute(self, f: Fault, step):
        w = self.w
        if f.point == "step":
            self._fence(step)
        if f.target == "node":
            # the victim signals its parent daemon (paper §4): SIGKILL
            # takes the node down silently, a channel break partitions
            # it (the fail-stop node then fences itself), and a hang
            # mutes the whole node — daemon and children — while every
            # channel stays open (only daemon-ring observation sees it)
            msg = {"channel_break": {"type": "BREAK_CHANNEL"},
                   "hang": {"type": "HANG_NODE"}}.get(
                       f.how, {"type": "KILL_NODE"})
            try:
                w._send_daemon(msg)
            except OSError:
                pass
            time.sleep(10)
            os.kill(os.getpid(), signal.SIGKILL)
        if f.how == "hang":
            # silent forever: no SIGCHLD, control channel intact. Going
            # silent includes the peer fabric (heartbeat ACKs stop), so
            # the neighbour ring — when armed — can SUSPECT us; without
            # it only the stall watchdog sees this
            w._silent.set()
            threading.Event().wait()
            return
        if f.how == "channel_break":
            # shutdown (not just close): wakes the control loop blocked
            # in recv with an EOF — it then exits the fail-stop rank
            try:
                w.daemon_sock.shutdown(socket.SHUT_RDWR)
                w.daemon_sock.close()
            except OSError:
                pass
            threading.Event().wait()
            return
        os.kill(os.getpid(), signal.SIGKILL)


class Worker:
    # buddy frames chain deltas against the previous step's push; a full
    # base every 2nd step keeps chains shorter than the retention window
    PUSH_BASE_EVERY = 2

    def __init__(self, args):
        self.rank = args.rank
        # membership as rank ids, not a count (a shrinking recovery
        # leaves a non-contiguous surviving set), adopted only from the
        # root's broadcasts — centralized in core.membership
        self.member = RankMembership(rank=args.rank,
                                     world_ranks=list(range(args.world)),
                                     epoch=args.epoch,
                                     initial_world=args.world)
        # steps this rank keeps exempt from checkpoint retention while
        # the world is shrunk: the consistent cut a grow-back resumes
        # from (re-written as a composable full frame at pin time).
        # Released pins (world fully re-expanded) are reaped once they
        # age past the retention window — never before the post-grow
        # restore that reads them.
        self._pinned: set[int] = set()           # guarded-by: barrier_cv
        self._released_pins: set[int] = set()    # guarded-by: barrier_cv
        self.steps = args.steps
        self.dim = args.dim
        self.ckpt_dir = args.ckpt_dir
        # armed by a hang injection: the rank stops answering everything
        # (peer fabric included) while its channels stay open
        self._silent = threading.Event()
        self.injector = WorkerInjector(self, self._injection_plan(args))
        hooks.install(self.injector)
        self.initial_state = (RankState.RESTARTED if args.restarted
                              else RankState.NEW)

        # replica mode: shadow role + the primary side of the stream.
        # shadow_table maps rank -> its shadow's peer address (from the
        # root's RANK_TABLE broadcasts); a primary pushes every step's
        # frame there. _pending_sync is the in-flight root-bound message
        # (BARRIER/JOIN/DONE) replayed on RESYNC after a standby
        # takeover — the primary root may have died with it buffered but
        # unprocessed.
        self.is_shadow = getattr(args, "shadow", False)
        self.shadow_table: dict[int, tuple[str, int]] = {}
        self._shadow_addr_seen: Optional[tuple] = None
        self._pending_sync: Optional[dict] = None   # guarded-by: barrier_cv
        self._promote_ev = threading.Event()
        self._promote_resume = 0
        self._promoted = False
        self._shadow_plan = (
            Scenario.load(args.scenario).shadow_faults(self.rank)
            if (args.scenario and self.is_shadow) else [])

        # gray-failure plan: this rank's slow/lossy degradations. Only
        # the original incarnation degrades — a drained-and-respawned
        # rank (--restarted) comes back healthy, which is what lets the
        # mitigation path actually cure a persistent straggler.
        self._gray_plan = (
            Scenario.load(args.scenario).gray_faults_for_rank(self.rank)
            if (args.scenario and not args.restarted) else [])
        self._lossy_armed = False

        # retention window spills to local disk past the hot step — the
        # paper's memory/file dichotomy as an LRU tier, exercised by the
        # real-process runtime on every run. Prior incarnations of this
        # rank (pre-respawn) are dead by the time we start: reap their
        # orphaned spill dirs.
        spill_prefix = f".spill_r{self.rank}_"
        try:
            for name in os.listdir(self.ckpt_dir):
                if name.startswith(spill_prefix) \
                        and name != spill_prefix + str(os.getpid()):
                    shutil.rmtree(os.path.join(self.ckpt_dir, name),
                                  ignore_errors=True)
        except OSError:
            pass
        self.store = BuddyStore(
            self.rank, self.world, push_remote=self._push_remote,
            spill_dir=os.path.join(self.ckpt_dir,
                                   spill_prefix + str(os.getpid())),
            hot_steps=1)
        # buddy frame cadence shared with FileCheckpointer's policy;
        # contiguous: BuddyStore's retention walk assumes step-1 chains
        self._publisher = serde.FramePublisher(self.PUSH_BASE_EVERY,
                                               contiguous=True)
        self.rank_table: dict[int, tuple[str, int]] = {}
        self.table_event = threading.Event()
        self.barrier_release: dict[tuple[int, int], float] = {}  # guarded-by: barrier_cv
        self.barrier_cv = threading.Condition()

        # peer listener (buddy checkpoint fabric)
        self.peer_sock = listener()
        self.peer_port = self.peer_sock.getsockname()[1]
        threading.Thread(target=self._peer_loop, daemon=True).start()

        # control channel to parent daemon; the send lock serializes the
        # main loop's sends against the heartbeat observer thread's
        # SUSPECT reports (two concurrent sendall()s would interleave)
        self.daemon_sock = connect("127.0.0.1", args.daemon_port)
        self._daemon_send_lock = threading.Lock()
        self._send_daemon({
            "type": "REGISTER_WORKER", "rank": self.rank,
            "peer_port": self.peer_port, "pid": os.getpid(),
            "restarted": args.restarted, "shadow": self.is_shadow})
        threading.Thread(target=self._control_loop, daemon=True).start()

        # neighbour-heartbeat ring (ULFM/FTHP-MPI-style): observe the ring
        # successor every period; after `timeout` of consecutive silence
        # report SUSPECT to the root — hang detection without a watchdog
        self.hb_period = getattr(args, "hb_period", 0.0)
        self.hb_timeout = getattr(args, "hb_timeout", 0.0)
        if self.hb_period > 0 and self.hb_timeout > 0:
            threading.Thread(target=self._hb_loop, daemon=True).start()

    # ------------------------------------------------- membership facade

    @property
    def world_ranks(self) -> list:
        return self.member.world_ranks

    @world_ranks.setter
    def world_ranks(self, ranks):
        self.member.adopt(world=ranks)

    @property
    def world(self) -> int:
        return self.member.size

    @property
    def epoch(self) -> int:
        return self.member.epoch

    @epoch.setter
    def epoch(self, value: int):
        self.member.adopt(epoch=value)

    def _send_daemon(self, msg: dict):
        with self._daemon_send_lock:
            send_msg(self.daemon_sock, msg)

    def _injection_plan(self, args) -> list:
        """This rank's (index, Fault) pairs — from a scenario file when
        given, else synthesized from the legacy --fail-* flags (the
        original single-kill-point injection, now one schema entry)."""
        if args.scenario:
            return Scenario.load(args.scenario).faults_for_rank(self.rank)
        if args.fail_step >= 0 and args.fail_rank == self.rank:
            target = "node" if args.fail_kind == "node" else "rank"
            return [(0, Fault(target, self.rank, args.fail_step))]
        return []

    # ------------------------------------------------------------ fabric

    def _peer_loop(self):
        while True:
            try:
                conn, _ = self.peer_sock.accept()
            except OSError:
                return
            threading.Thread(target=self._peer_conn, args=(conn,),
                             daemon=True).start()

    def _peer_conn(self, conn):
        try:
            while True:
                msg = recv_msg(conn)
                if msg is None:
                    return
                if self._silent.is_set():
                    return          # hung rank: answers nothing, to anyone
                if msg["type"] == "HB_PING":
                    send_msg(conn, {"type": "HB_ACK", "rank": self.rank})
                elif msg["type"] == "PUSH_CKPT":
                    self.store.hold(msg["origin"], msg["step"],
                                    msg["_payload"])
                    send_msg(conn, {"type": "ACK"})
                    if self._shadow_plan and msg["origin"] == self.rank:
                        self._shadow_stream_fault(msg["step"])
                elif msg["type"] == "GET_CKPT":
                    held = self.store.held_map(msg["origin"])
                    # all retained frames concatenated on the raw payload
                    # channel; the index maps step -> (offset, length)
                    index, blobs, off = {}, [], 0
                    for s, b in held.items():
                        index[str(s)] = [off, len(b)]
                        blobs.append(b)
                        off += len(b)
                    send_msg(conn, {"type": "CKPT", "steps": index},
                             payload=b"".join(blobs))
        finally:
            conn.close()

    def _shadow_stream_fault(self, step: int):
        """Shadow-target faults fire off the replication stream: once the
        primary's push reaches the fault's step, the warm standby itself
        dies — exercising the root's shadow-loss bookkeeping (drop the
        entry, fall back to reinit if the primary dies later)."""
        for idx, f in self._shadow_plan:
            if f.step is not None and step < f.step:
                continue
            if self.injector._claim(idx, "shadow.stream", step):
                os.kill(os.getpid(), signal.SIGKILL)

    def _push_shadow(self, step: int, payload: bytes, x: np.ndarray):
        """Primary side of the replication stream: mirror every step's
        frame to this rank's shadow (when one exists). The first frame a
        newly-seen shadow receives must be self-contained — the shadow
        joined mid-chain, so a delta against a frame it never got would
        leave its whole stream uncomposable."""
        addr = self.shadow_table.get(self.rank)
        if addr is None:
            return
        if addr != self._shadow_addr_seen:
            payload = serde.to_bytes({"x": x}, extra={"step": step})
        try:
            s = connect(*addr, timeout=5)
            send_msg(s, {"type": "PUSH_CKPT", "origin": self.rank,
                         "step": step}, payload=payload)
            recv_msg(s)
            s.close()
            self._shadow_addr_seen = addr
        except OSError:
            pass      # shadow died; the root drops it from the table

    def _push_remote(self, buddy_rank: int, step: int, payload: bytes):
        addr = self.rank_table.get(buddy_rank)
        if addr is None:
            return
        try:
            s = connect(*addr, timeout=5)
            send_msg(s, {"type": "PUSH_CKPT", "origin": self.rank,
                         "step": step}, payload=payload)
            recv_msg(s)
            s.close()
        except OSError:
            pass      # buddy died; the failure path will handle it

    def _hb_loop(self):
        """Heartbeat observer: ping the ring successor's peer listener
        every period; `timeout` seconds of consecutive misses raise a
        SUSPECT to the root (via the daemon relay). Misses during an
        epoch transition are discarded — recovery re-forms the ring and
        the table rebroadcast resets the observation."""
        missed = 0.0
        while True:
            time.sleep(self.hb_period)
            if self._silent.is_set():
                return
            ring = list(self.world_ranks)
            if len(ring) < 2 or self.rank not in ring:
                continue
            succ = ring[(ring.index(self.rank) + 1) % len(ring)]
            addr = self.rank_table.get(succ)
            epoch0 = self.epoch
            if addr is None:
                missed = 0.0            # table in flux (deploy/recovery)
                continue
            ok = False
            try:
                s = connect(*addr, timeout=self.hb_period)
                s.settimeout(max(self.hb_period, 0.05))
                send_msg(s, {"type": "HB_PING", "from": self.rank})
                ok = recv_msg(s) is not None
                s.close()
            except OSError:
                ok = False
            if ok:
                missed = 0.0
            elif self.epoch == epoch0:
                missed += self.hb_period
                if missed >= self.hb_timeout:
                    try:
                        self._send_daemon({"type": "SUSPECT", "rank": succ,
                                           "by": self.rank,
                                           "epoch": epoch0})
                    except OSError:
                        pass
                    missed = 0.0
            else:
                missed = 0.0            # epoch moved: stale observation

    def _pull_from_buddy(self) -> dict[int, bytes]:
        """All retained checkpoints the buddy holds for this rank."""
        addr = self.rank_table.get(self.store.buddy)
        if addr is None:
            return {}
        try:
            s = connect(*addr, timeout=5)
            send_msg(s, {"type": "GET_CKPT", "origin": self.rank})
            msg = recv_msg(s)
            s.close()
            if msg:
                blob = msg.get("_payload", b"")
                return {int(k): blob[off:off + n]
                        for k, (off, n) in msg.get("steps", {}).items()}
        except OSError:
            pass
        return {}

    # ----------------------------------------------------------- control

    def _control_loop(self):
        while True:
            try:
                msg = recv_msg(self.daemon_sock)
            except OSError:       # channel broken (possibly by injection)
                msg = None
            if msg is None:
                os._exit(3)       # daemon died under us: node is gone
            t = msg["type"]
            if t == "RANK_TABLE":
                self.rank_table = {int(k): tuple(v)
                                   for k, v in msg["table"].items()}
                self.shadow_table = {int(k): tuple(v)
                                     for k, v in
                                     msg.get("shadows", {}).items()}
                with self.barrier_cv:     # epoch bump unblocks stale waits
                    # the table carries the authoritative membership: a
                    # rank spawned into a shrunk/grown world learns its
                    # actual world here, not from its static --world arg
                    self.member.adopt(world=msg.get("world"),
                                      epoch=msg["epoch"])
                    self.barrier_cv.notify_all()
                self.table_event.set()
            elif t == "BARRIER_RELEASE":
                with self.barrier_cv:
                    self.barrier_release[(msg["epoch"], msg["step"])] = \
                        msg["value"]
                    self.barrier_cv.notify_all()
            elif t == "JOIN_RELEASE":
                with self.barrier_cv:
                    self.barrier_release[("join", msg["epoch"])] = \
                        msg["resume"]
                    self.barrier_cv.notify_all()
            elif t == "FENCE_RELEASE":
                with self.barrier_cv:
                    self.barrier_release[("fence", msg["step"])] = 1
                    self.barrier_cv.notify_all()
            elif t == "SHRINK":
                # elastic shrinking recovery: adopt the contracted world
                # (membership + epoch), drop dead table entries, and
                # re-form the buddy ring over survivors. The SIGREINIT
                # the daemon delivered alongside unwinds the main loop;
                # it rejoins under the new epoch and re-balances (the
                # allreduce mean below runs over the shrunk world).
                with self.barrier_cv:
                    self.member.adopt(world=msg["world"],
                                      epoch=msg["epoch"])
                    for r in list(self.rank_table):
                        if r not in self.world_ranks:
                            self.rank_table.pop(r, None)
                    self.barrier_cv.notify_all()
                self.store.reform_ring(self.world_ranks)
            elif t == "GROW":
                # grow-back: a repaired node rejoined and the root
                # re-admitted the dropped ranks. Adopt the re-expanded
                # membership (bumped epoch + mesh epoch), release the
                # pinned grow anchors (the consensus about to run
                # supersedes them), and re-form the buddy ring over the
                # full world — the SIGREINIT alongside unwinds the main
                # loop back to the pinned pre-shrink cut.
                with self.barrier_cv:
                    self.member.adopt(world=msg["world"],
                                      epoch=msg["epoch"])
                    if not self.member.shrunk:
                        # fully re-expanded: the anchors are consumed
                        # (a partially-grown world keeps them — older
                        # drops still need their cuts durable). Reaped
                        # by retention once they age out, not here: the
                        # post-grow restore still reads them.
                        self._released_pins |= self._pinned
                        self._pinned.clear()
                    self.barrier_cv.notify_all()
                self.store.reform_ring(self.world_ranks)
            elif t == "PROMOTE":
                # replica failover: the root names this shadow the new
                # primary for its rank. Accept only if the warm stream
                # actually composes at the resume step; otherwise NACK so
                # the root can fall back (kill us + reinit respawn).
                resume = int(msg["resume"])
                have = serde.composable_steps(
                    self.store.held_map(self.rank))
                if resume in have:
                    self._promote_resume = resume
                    self._promote_ev.set()
                else:
                    try:
                        self._send_daemon({
                            "type": "PROMOTE_NACK", "rank": self.rank,
                            "epoch": msg.get("epoch", self.epoch),
                            "have": sorted(have)})
                    except OSError:
                        pass
            elif t == "RESYNC":
                # standby takeover: the dead primary root may have
                # swallowed our in-flight BARRIER/JOIN/DONE (the send
                # "succeeded" into a socket buffer nobody drained) —
                # replay it; root-side arrival recording is idempotent
                with self.barrier_cv:
                    pending = (dict(self._pending_sync)
                               if self._pending_sync else None)
                if pending is not None:
                    try:
                        self._send_daemon(pending)
                    except OSError:
                        pass
            elif t == "SHUTDOWN":
                os._exit(0)

    def _wait_release(self, key, epoch, timeout: float = 120.0):
        """Event-driven wait: woken by the condition variable (releases,
        epoch bumps) or unwound instantly by SIGREINIT via the
        interruptible safe-point — no polling period on the recovery
        critical path."""
        deadline = time.monotonic() + timeout
        try:
            with self.barrier_cv:
                while key not in self.barrier_release:
                    ROLLBACK.check()
                    if self.epoch != epoch:   # recovered: new epoch
                        raise RollbackSignal(self.epoch)
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(f"release {key}")
                    with ROLLBACK.interruptible():
                        self.barrier_cv.wait(min(remaining, 5.0))
                return self.barrier_release.pop(key)
        except RuntimeError as e:
            # SIGREINIT can land while Condition.wait is re-acquiring the
            # cv lock; the handler's RollbackSignal aborts the acquire
            # and the `with` exit then fails releasing an un-held lock,
            # surfacing as RuntimeError with the rollback swallowed.
            # The lock is un-held (future acquires are fine) — translate
            # exactly that case back into the rollback that caused it;
            # anything else is a real error and propagates.
            if "lock" not in str(e):
                raise
            ROLLBACK.clear()
            raise RollbackSignal(self.epoch)

    def _allreduce(self, step: int, value: float) -> float:
        """BSP collective: tree sum through daemon → root and back."""
        epoch = self.epoch
        msg = {"type": "BARRIER", "rank": self.rank, "epoch": epoch,
               "step": step, "value": value}
        with self.barrier_cv:
            self._pending_sync = msg
        self._send_daemon(msg)
        try:
            return self._wait_release((epoch, step), epoch)
        finally:
            with self.barrier_cv:
                self._pending_sync = None

    def _join(self, avail: int) -> int:
        """ORTE-style rejoin barrier (the MPI_Init-equivalent barrier of
        paper §3.2) extended with rollback consensus: every rank reports
        the newest checkpoint it can restore, the root answers with the
        minimum — the latest *consistent* global checkpoint."""
        epoch = self.epoch
        msg = {"type": "JOIN", "rank": self.rank, "epoch": epoch,
               "avail": avail}
        with self.barrier_cv:
            self._pending_sync = msg
        self._send_daemon(msg)
        try:
            return int(self._wait_release(("join", epoch), epoch))
        finally:
            with self.barrier_cv:
                self._pending_sync = None

    # --------------------------------------------------------------- app

    def _ckpt_payload(self, step: int, x: np.ndarray) -> bytes:
        """Serde frame for this step — a tile-range delta against the
        previous step's frame when the state is sparse-dirty (redistribu-
        tion then moves only dirty bytes), a full frame otherwise or on
        every PUSH_BASE_EVERY-th step (chain anchor)."""
        return self._publisher.publish({"x": x}, step)

    def _compose_state(self, frames: dict[int, bytes], step: int
                       ) -> tuple[int, np.ndarray]:
        extra, flat = serde.compose(frames, step)
        return int(extra["step"]), np.array(flat["x"])   # writable copy

    def _file_path(self, step: int) -> str:
        return os.path.join(self.ckpt_dir, f"rank_{self.rank}.s{step}.bin")

    def _save_file(self, step: int, payload: bytes):
        tmp = self._file_path(step) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(payload)
        # mid-checkpoint-write interruption point: the bytes are on disk
        # but invisible — a kill here must leave step-1 the newest
        # loadable checkpoint
        hooks.fire("worker.ckpt.mid_write", step=step)
        os.replace(tmp, self._file_path(step))
        # retention: drop the aged-out step — unless it is a pinned grow
        # anchor (the consistent cut a shrunk world must keep durable so
        # a grow-back can resume from it). Pin state is shared with the
        # control thread's GROW arm, so read and reap it under the cv;
        # the unlinks happen outside (no IO under the lock).
        old_step = step - 3
        old = self._file_path(old_step)
        with self.barrier_cv:
            unpin = old_step not in self._pinned
            # reap released anchors once they age out of the window
            # (they were consumed by the grow's restore; leaving them
            # would grow the dir and every later recovery's restore
            # scan unboundedly)
            reap = [p for p in sorted(self._released_pins)
                    if p <= step - 3]
            self._released_pins.difference_update(reap)
        if unpin and os.path.exists(old):
            os.unlink(old)
        for s in reap:
            stale = self._file_path(s)
            if os.path.exists(stale):
                os.unlink(stale)

    def _pin_anchor(self, step: int, x: np.ndarray):
        """While the world is shrunk, keep the consensus cut durable as
        the grow-back anchor: re-write it as a self-contained full frame
        (a delta frame's chain parents would age out of retention) and
        exempt it from the retention unlink until a GROW releases it."""
        with self.barrier_cv:
            if step in self._pinned:
                return
        tmp = self._file_path(step) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(serde.to_bytes({"x": x}, extra={"step": step}))
        os.replace(tmp, self._file_path(step))
        with self.barrier_cv:
            self._pinned.add(step)

    def _file_map(self) -> dict[int, bytes]:
        out = {}
        prefix = f"rank_{self.rank}.s"
        try:
            names = os.listdir(self.ckpt_dir)
        except FileNotFoundError:
            return out
        for name in names:
            if name.startswith(prefix) and name.endswith(".bin"):
                step = int(name[len(prefix):-4])
                with open(os.path.join(self.ckpt_dir, name), "rb") as f:
                    out[step] = f.read()
        return out

    def body(self, state: RankState) -> int:
        self.table_event.wait(30)     # need the rank table before buddy I/O
        # --- application recovery (Table 2): gather restorable checkpoints.
        # Maps merge file + memory tiers (identical frame bytes per step):
        # a rank that committed its file but died before the buddy push
        # still resumes from the committed step.
        if state is RankState.RESTARTED:
            avail_map = {**self._file_map(),      # file scheme (node)
                         **self._pull_from_buddy()}   # memory (process)
            if avail_map:
                hooks.fire("worker.recovery.pulled")
        elif state is RankState.REINITED:
            hooks.fire("worker.recovery.enter")   # survivor just rolled back
            avail_map = {**self._file_map(),
                         **self.store.local_map()}    # survivors: memory
        else:
            # NEW: resume from file if one exists — the CR re-deploy path
            avail_map = self._file_map()
            if avail_map:
                hooks.fire("worker.recovery.pulled")
        # --- consistent-cut consensus: resume at min over ranks; a step
        # counts as available only when its delta chain composes locally
        composable = serde.composable_steps(avail_map)
        resume = self._join(max(composable, default=0))
        if resume > 0:
            if resume not in composable:
                raise RuntimeError(
                    f"rank {self.rank}: no ckpt for agreed step {resume}; "
                    f"have {sorted(composable)}")
            hooks.fire("worker.recovery.compose", step=resume)
            start, x = self._compose_state(avail_map, resume)
        else:
            start = 0
            rng = np.random.default_rng(self.rank)
            x = rng.standard_normal(self.dim)
        # a shrunk world pins its cut: the dropped ranks' newest durable
        # checkpoints are at this step, so a future grow-back's consensus
        # lands exactly here — keep it composable and retention-proof
        if self.member.shrunk and resume > 0:
            self._pin_anchor(resume, x)
        return self._loop(start, x)

    def _gray_degrade(self, step: int):
        """Apply this rank's active gray faults for the step. `slow`
        sleeps the deceleration delay before compute — the rank still
        does all the work, just late, so state stays bit-identical.
        `lossy` arms the seeded transport degradation once, at the
        fault's onset step, scoped to the daemon uplink (one bad link):
        every control-plane send then pays a delay, a seeded fraction
        doubled. Both surface at the root as barrier lateness
        attributable to exactly this rank."""
        for idx, f in self._gray_plan:
            if step < f.step:
                continue
            if f.how == "slow":
                time.sleep(gray_delay_s(f))
            elif f.how == "lossy" and not self._lossy_armed:
                install_lossy(seed=1000 + 64 * idx + self.rank,
                              delay_s=gray_delay_s(f),
                              sock=self.daemon_sock)
                self._lossy_armed = True

    def _loop(self, start: int, x: np.ndarray) -> None:
        """The BSP step loop proper. Reached via `body` (normal join /
        rollback path) or directly by a promoted shadow, which skips the
        consensus entirely — its warm frame IS the resume state."""
        w = np.eye(self.dim) * 0.999        # fixed "model"

        for step in range(start, self.steps):
            ROLLBACK.check()
            # scenario injection — each fault fires exactly once per run
            # (the injector's O_EXCL sentinel stops re-spawned/restarted
            # processes from re-killing themselves). Step-point faults
            # wait behind the FENCE (deterministic kill barrier): the
            # root releases it once every other rank has arrived at this
            # step's barrier — i.e. has committed its checkpoint for this
            # step — so the post-recovery consistent cut is always
            # exactly `step`, independent of scheduling around SIGKILL.
            hooks.fire("step", step=step)
            self._gray_degrade(step)
            # BSP compute + collective
            x = w @ x + 1e-3
            total = self._allreduce(step, float(x.sum()))
            x[0] = total / self.world       # interlocked dependency
            # checkpoint: file first, then memory (local+buddy) — the
            # store's spill tier references the rank file already on
            # disk instead of writing the same bytes twice
            payload = self._ckpt_payload(step + 1, x)
            self._save_file(step + 1, payload)
            # mid-replication interruption point (ReStore): the file is
            # committed but the buddy never receives this step
            hooks.fire("worker.ckpt.pre_push", step=step + 1)
            self.store.save(step + 1, payload,
                            on_disk=self._file_path(step + 1))
            self._push_shadow(step + 1, payload, x)
        msg = {"type": "DONE", "rank": self.rank,
               "checksum": float(np.sum(x))}
        with self.barrier_cv:
            self._pending_sync = msg     # replayed if a standby takes over
        self._send_daemon(msg)
        # park until SHUTDOWN (control loop exits the process) — an event
        # wait, not a poll loop
        threading.Event().wait()

    def _shadow_body(self, state: RankState) -> None:
        """Entry for a promoted shadow. The first pass (promotion itself)
        composes the warm frame and enters the loop at the resume step —
        no join, no rollback. If a *later* recovery SIGREINITs us, we are
        an ordinary survivor by then and take the normal body path."""
        if self._promoted:
            return self.body(state)
        # cascade window: the promoted-but-not-yet-running shadow is the
        # same program point a respawned rank hits when it pulls buddy
        # state — a fault planted there kills the new primary mid-promote
        hooks.fire("worker.recovery.pulled")
        resume = self._promote_resume
        frames = self.store.held_map(self.rank)
        hooks.fire("worker.recovery.compose", step=resume)
        _, x = self._compose_state(frames, resume)
        self._promoted = True
        return self._loop(resume, x)

    def run(self):
        install_sigreinit()
        if self.is_shadow:
            # warm standby: the peer listener is absorbing the primary's
            # stream; stay out of the BSP world until the root PROMOTEs
            # us. SIGREINITs from unrelated recoveries only arm the
            # deferred flag here (the wait is not an interruptible
            # region) — cleared before we take over.
            self._promote_ev.wait()
            ROLLBACK.clear()
            reinit_main(self._shadow_body, initial_state=RankState.NEW)
            return
        try:
            reinit_main(self.body, initial_state=self.initial_state)
        except SystemExit:
            raise


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rank", type=int, required=True)
    ap.add_argument("--world", type=int, required=True)
    ap.add_argument("--daemon-port", type=int, required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--fail-step", type=int, default=-1)
    ap.add_argument("--fail-rank", type=int, default=-1)
    ap.add_argument("--fail-kind", default="process")
    ap.add_argument("--scenario", default="")
    ap.add_argument("--hb-period", type=float, default=0.0)
    ap.add_argument("--hb-timeout", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--restarted", action="store_true")
    ap.add_argument("--shadow", action="store_true")
    ap.add_argument("--epoch", type=int, default=0)
    Worker(ap.parse_args(argv)).run()


if __name__ == "__main__":
    main()
