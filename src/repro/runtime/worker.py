"""Worker process: the resilient MPI-rank analogue.

Runs `reinit_main` around a BSP compute loop (numpy matmul + tree
allreduce through the daemon/root control plane — the world communicator).
Checkpoints after every iteration: a local in-memory copy plus a push to
the buddy rank's peer socket (memory scheme), and a file checkpoint (file
scheme) — exactly Table 2's matrix.

Fault injection (paper §4): at the pre-drawn (step, rank), the victim
SIGKILLs itself (process failure) or asks its daemon to take the whole node
down (node failure). Survivors receive SIGREINIT (SIGUSR1), roll back to
the reinit point, and rejoin the epoch barrier with re-spawned ranks.
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import threading
import time
from typing import Optional

import numpy as np

from repro.core.events import RankState
from repro.core.reinit import ROLLBACK, RollbackSignal, install_sigreinit, \
    reinit_main
from repro.checkpoint import serde
from repro.checkpoint.memory_ckpt import BuddyStore

from .transport import connect, listener, recv_msg, send_msg


class Worker:
    def __init__(self, args):
        self.rank = args.rank
        self.world = args.world
        self.steps = args.steps
        self.dim = args.dim
        self.fail_step = args.fail_step
        self.fail_rank = args.fail_rank
        self.fail_kind = args.fail_kind
        self.ckpt_dir = args.ckpt_dir
        self.initial_state = (RankState.RESTARTED if args.restarted
                              else RankState.NEW)

        self.store = BuddyStore(self.rank, self.world,
                                push_remote=self._push_remote)
        self.rank_table: dict[int, tuple[str, int]] = {}
        self.table_event = threading.Event()
        self.barrier_release: dict[tuple[int, int], float] = {}
        self.barrier_cv = threading.Condition()
        self.epoch = args.epoch

        # peer listener (buddy checkpoint fabric)
        self.peer_sock = listener()
        self.peer_port = self.peer_sock.getsockname()[1]
        threading.Thread(target=self._peer_loop, daemon=True).start()

        # control channel to parent daemon
        self.daemon_sock = connect("127.0.0.1", args.daemon_port)
        send_msg(self.daemon_sock, {
            "type": "REGISTER_WORKER", "rank": self.rank,
            "peer_port": self.peer_port, "pid": os.getpid(),
            "restarted": args.restarted})
        threading.Thread(target=self._control_loop, daemon=True).start()

    # ------------------------------------------------------------ fabric

    def _peer_loop(self):
        while True:
            try:
                conn, _ = self.peer_sock.accept()
            except OSError:
                return
            threading.Thread(target=self._peer_conn, args=(conn,),
                             daemon=True).start()

    def _peer_conn(self, conn):
        try:
            while True:
                msg = recv_msg(conn)
                if msg is None:
                    return
                if msg["type"] == "PUSH_CKPT":
                    self.store.hold(msg["origin"], msg["step"],
                                    msg["_payload"])
                    send_msg(conn, {"type": "ACK"})
                elif msg["type"] == "GET_CKPT":
                    held = self.store.held_map(msg["origin"])
                    # all retained frames concatenated on the raw payload
                    # channel; the index maps step -> (offset, length)
                    index, blobs, off = {}, [], 0
                    for s, b in held.items():
                        index[str(s)] = [off, len(b)]
                        blobs.append(b)
                        off += len(b)
                    send_msg(conn, {"type": "CKPT", "steps": index},
                             payload=b"".join(blobs))
        finally:
            conn.close()

    def _push_remote(self, buddy_rank: int, step: int, payload: bytes):
        addr = self.rank_table.get(buddy_rank)
        if addr is None:
            return
        try:
            s = connect(*addr, timeout=5)
            send_msg(s, {"type": "PUSH_CKPT", "origin": self.rank,
                         "step": step}, payload=payload)
            recv_msg(s)
            s.close()
        except OSError:
            pass      # buddy died; the failure path will handle it

    def _pull_from_buddy(self) -> dict[int, bytes]:
        """All retained checkpoints the buddy holds for this rank."""
        addr = self.rank_table.get(self.store.buddy)
        if addr is None:
            return {}
        try:
            s = connect(*addr, timeout=5)
            send_msg(s, {"type": "GET_CKPT", "origin": self.rank})
            msg = recv_msg(s)
            s.close()
            if msg:
                blob = msg.get("_payload", b"")
                return {int(k): blob[off:off + n]
                        for k, (off, n) in msg.get("steps", {}).items()}
        except OSError:
            pass
        return {}

    # ----------------------------------------------------------- control

    def _control_loop(self):
        while True:
            msg = recv_msg(self.daemon_sock)
            if msg is None:
                os._exit(3)       # daemon died under us: node is gone
            t = msg["type"]
            if t == "RANK_TABLE":
                self.rank_table = {int(k): tuple(v)
                                   for k, v in msg["table"].items()}
                self.epoch = msg["epoch"]
                self.table_event.set()
            elif t == "BARRIER_RELEASE":
                with self.barrier_cv:
                    self.barrier_release[(msg["epoch"], msg["step"])] = \
                        msg["value"]
                    self.barrier_cv.notify_all()
            elif t == "JOIN_RELEASE":
                with self.barrier_cv:
                    self.barrier_release[("join", msg["epoch"])] = \
                        msg["resume"]
                    self.barrier_cv.notify_all()
            elif t == "SHUTDOWN":
                os._exit(0)

    def _wait_release(self, key, epoch):
        deadline = time.monotonic() + 120
        with self.barrier_cv:
            while key not in self.barrier_release:
                ROLLBACK.check()          # interruptible: SIGREINIT unblocks
                if self.epoch != epoch:   # recovered into a new epoch
                    raise RollbackSignal(self.epoch)
                self.barrier_cv.wait(0.05)
                if time.monotonic() > deadline:
                    raise TimeoutError(f"release {key}")
            return self.barrier_release.pop(key)

    def _allreduce(self, step: int, value: float) -> float:
        """BSP collective: tree sum through daemon → root and back."""
        epoch = self.epoch
        send_msg(self.daemon_sock, {
            "type": "BARRIER", "rank": self.rank, "epoch": epoch,
            "step": step, "value": value})
        return self._wait_release((epoch, step), epoch)

    def _join(self, avail: int) -> int:
        """ORTE-style rejoin barrier (the MPI_Init-equivalent barrier of
        paper §3.2) extended with rollback consensus: every rank reports
        the newest checkpoint it can restore, the root answers with the
        minimum — the latest *consistent* global checkpoint."""
        epoch = self.epoch
        send_msg(self.daemon_sock, {
            "type": "JOIN", "rank": self.rank, "epoch": epoch,
            "avail": avail})
        return int(self._wait_release(("join", epoch), epoch))

    # --------------------------------------------------------------- app

    def _ckpt_payload(self, step: int, x: np.ndarray) -> bytes:
        return serde.to_bytes({"x": x}, extra={"step": step})

    def _parse_payload(self, payload: bytes) -> tuple[int, np.ndarray]:
        extra, flat = serde.from_bytes(payload)
        return int(extra["step"]), np.array(flat["x"])   # writable copy

    def _file_path(self, step: int) -> str:
        return os.path.join(self.ckpt_dir, f"rank_{self.rank}.s{step}.bin")

    def _save_file(self, step: int, payload: bytes):
        tmp = self._file_path(step) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(payload)
        os.replace(tmp, self._file_path(step))
        old = self._file_path(step - 3)
        if os.path.exists(old):
            os.unlink(old)

    def _file_map(self) -> dict[int, bytes]:
        out = {}
        prefix = f"rank_{self.rank}.s"
        try:
            names = os.listdir(self.ckpt_dir)
        except FileNotFoundError:
            return out
        for name in names:
            if name.startswith(prefix) and name.endswith(".bin"):
                step = int(name[len(prefix):-4])
                with open(os.path.join(self.ckpt_dir, name), "rb") as f:
                    out[step] = f.read()
        return out

    def body(self, state: RankState) -> int:
        self.table_event.wait(30)     # need the rank table before buddy I/O
        # --- application recovery (Table 2): gather restorable checkpoints
        if state is RankState.RESTARTED:
            avail_map = self._pull_from_buddy()   # memory scheme (process)
            if not avail_map:
                avail_map = self._file_map()      # file scheme (node)
        elif state is RankState.REINITED:
            avail_map = self.store.local_map()    # survivors: local memory
            if not avail_map:
                avail_map = self._file_map()
        else:
            # NEW: resume from file if one exists — the CR re-deploy path
            avail_map = self._file_map()
        # --- consistent-cut consensus: resume at min over ranks
        resume = self._join(max(avail_map, default=0))
        if resume > 0:
            if resume not in avail_map:
                raise RuntimeError(
                    f"rank {self.rank}: no ckpt for agreed step {resume}; "
                    f"have {sorted(avail_map)}")
            start, x = self._parse_payload(avail_map[resume])
        else:
            start = 0
            rng = np.random.default_rng(self.rank)
            x = rng.standard_normal(self.dim)
        w = np.eye(self.dim) * 0.999        # fixed "model"

        sentinel = os.path.join(self.ckpt_dir, "INJECTED")
        for step in range(start, self.steps):
            ROLLBACK.check()
            # fault injection — exactly once per run (paper §4: single
            # failure); the sentinel stops re-spawned/restarted processes
            # from re-killing themselves at the same step
            if (step == self.fail_step and self.rank == self.fail_rank
                    and not os.path.exists(sentinel)):
                with open(sentinel, "w") as f:
                    f.write(f"step={step} rank={self.rank}")
                if self.fail_kind == "node":
                    send_msg(self.daemon_sock, {"type": "KILL_NODE"})
                    time.sleep(10)
                os.kill(os.getpid(), signal.SIGKILL)
            # BSP compute + collective
            x = w @ x + 1e-3
            total = self._allreduce(step, float(x.sum()))
            x[0] = total / self.world       # interlocked dependency
            # checkpoint: memory (local+buddy) and file
            payload = self._ckpt_payload(step + 1, x)
            self.store.save(step + 1, payload)
            self._save_file(step + 1, payload)
        send_msg(self.daemon_sock, {
            "type": "DONE", "rank": self.rank,
            "checksum": float(np.sum(x))})
        # wait for shutdown
        while True:
            time.sleep(0.2)

    def run(self):
        install_sigreinit()
        try:
            reinit_main(self.body, initial_state=self.initial_state)
        except SystemExit:
            raise


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rank", type=int, required=True)
    ap.add_argument("--world", type=int, required=True)
    ap.add_argument("--daemon-port", type=int, required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--fail-step", type=int, default=-1)
    ap.add_argument("--fail-rank", type=int, default=-1)
    ap.add_argument("--fail-kind", default="process")
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--restarted", action="store_true")
    ap.add_argument("--epoch", type=int, default=0)
    Worker(ap.parse_args(argv)).run()


if __name__ == "__main__":
    main()
