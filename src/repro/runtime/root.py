"""Root (HNP): deployment, liveness, Algorithm 1, recovery orchestration.

Two recovery modes, matching the paper's measured approaches:

  reinit  Algorithm 1 + REINIT broadcast: survivors roll back in place,
          only failed ranks are re-spawned (on the least-loaded node for
          node failures). Recovery cost is confined to the root↔daemon
          tree.
  cr      Checkpoint-Restart: tear the whole job down (SIGKILL every
          daemon) and re-deploy it from scratch; every rank restarts from
          the file checkpoint.

The root measures, with wall clocks, the same phases the paper reports:
detection→REINIT-broadcast, re-registration (MPI recovery), and the first
post-recovery barrier (rejoin). Results land in a JSON report consumed by
benchmarks/runtime_bench.py.
"""
from __future__ import annotations

import argparse
import json
import os
import queue
import signal
import subprocess
import sys
import threading
import time

from repro.core.events import FailureEvent, FailureType
from repro.core.protocol import ClusterView, root_handle_failure

from .transport import listener, recv_msg, send_msg


class Root:
    def __init__(self, args):
        self.args = args
        self.world = args.nodes * args.ranks_per_node
        self.view = ClusterView.build(args.nodes, args.ranks_per_node,
                                      args.spares)
        self.sock = listener()
        self.port = self.sock.getsockname()[1]
        self.events: "queue.Queue[tuple]" = queue.Queue()
        self.daemon_socks: dict[str, object] = {}
        self.daemon_pids: dict[str, int] = {}
        self.daemon_procs: dict[str, subprocess.Popen] = {}
        self.rank_table: dict[int, tuple[str, int]] = {}
        self.barrier: dict[tuple[int, int], dict[int, float]] = {}
        self.fences: dict[tuple[int, int], int] = {}  # kill-barrier victims
        self.joins: dict[int, dict[int, int]] = {}   # epoch -> rank -> avail
        self.epoch = 0
        self.done: set[int] = set()
        self.recovering = False
        self.shutting_down = False
        self.timeline: list[dict] = []
        self.report: dict = {"mode": args.mode, "world": self.world,
                             "events": []}
        threading.Thread(target=self._accept_loop, daemon=True).start()

    # ------------------------------------------------------------ fabric

    def _accept_loop(self):
        while True:
            try:
                conn, _ = self.sock.accept()
            except OSError:
                return
            threading.Thread(target=self._daemon_conn, args=(conn,),
                             daemon=True).start()

    def _daemon_conn(self, conn):
        node = None
        try:
            while True:
                msg = recv_msg(conn)
                if msg is None:
                    break
                if msg["type"] == "REGISTER_DAEMON":
                    node = msg["node"]
                    self.daemon_socks[node] = conn
                    self.daemon_pids[node] = msg["pid"]
                self.events.put(("msg", msg))
        except OSError:
            pass
        if node is not None:
            # carry the socket identity: a channel that was already
            # replaced (CR teardown + re-deploy) must not be mistaken
            # for a failure of the *new* daemon on the same node
            self.events.put(("channel_broken", (node, conn)))

    def _broadcast(self, msg: dict, nodes=None):
        for node, s in list(self.daemon_socks.items()):
            if nodes is not None and node not in nodes:
                continue
            try:
                send_msg(s, msg)
            except OSError:
                pass

    # -------------------------------------------------------- deployment

    def _spawn_daemon(self, node: str):
        a = self.args
        cmd = [sys.executable, "-m", "repro.runtime.daemon",
               "--node", node, "--root-port", str(self.port),
               "--world", str(self.world), "--steps", str(a.steps),
               "--dim", str(a.dim), "--fail-step", str(a.fail_step),
               "--fail-rank", str(a.fail_rank), "--fail-kind", a.fail_kind,
               "--ckpt-dir", a.ckpt_dir, "--pythonpath", a.pythonpath]
        env = dict(os.environ, PYTHONPATH=a.pythonpath)
        self.daemon_procs[node] = subprocess.Popen(cmd, env=env)

    def deploy(self):
        t0 = time.monotonic()
        for node in self.view.daemons():
            self._spawn_daemon(node)
        # wait for all daemons to register, then hand them their ranks
        need = set(self.view.daemons())
        while need:
            kind, msg = self.events.get(timeout=30)
            if kind == "msg" and msg["type"] == "REGISTER_DAEMON":
                need.discard(msg["node"])
        for node in self.view.daemons():
            ranks = sorted(self.view.children[node])
            if ranks:
                send_msg(self.daemon_socks[node],
                         {"type": "SPAWN", "ranks": ranks,
                          "restarted": False, "epoch": self.epoch})
        self.report["deploy_start_s"] = t0

    # ----------------------------------------------------------- barrier

    def _barrier_arrive(self, msg):
        key = (msg["epoch"], msg["step"])
        if msg["epoch"] != self.epoch:
            return                          # stale pre-recovery arrival
        d = self.barrier.setdefault(key, {})
        d[msg["rank"]] = msg["value"]
        if len(d) == self.world:
            # reduce in rank order: float addition is order-sensitive, and
            # a deterministic reduction is what makes a recovered run
            # land on the bit-identical state of the fault-free run
            total = sum(d[r] for r in sorted(d))
            self._broadcast({"type": "BARRIER_RELEASE",
                             "epoch": key[0], "step": key[1],
                             "value": total})
            del self.barrier[key]
            if getattr(self, "_first_barrier_after_recovery", None) is not None:
                t0 = self._first_barrier_after_recovery
                self.report["events"][-1]["rejoin_barrier_s"] = \
                    time.monotonic() - t0
                self._first_barrier_after_recovery = None
        else:
            self._maybe_release_fence(key)

    def _fence_arrive(self, msg):
        """Deterministic kill barrier: a fault-injecting victim FENCEs at
        its kill step instead of dying immediately. The fence releases —
        and only then does the victim die — once every *other* rank has
        arrived at that step's barrier, i.e. has completed the previous
        iteration and committed its checkpoint for this step. The
        consistent cut after recovery is then always exactly the fence
        step, killing the timing dependence SIGKILL injection used to
        have."""
        key = (msg["epoch"], msg["step"])
        if msg["epoch"] != self.epoch:
            return
        self.fences[key] = msg["rank"]
        self._maybe_release_fence(key)

    def _maybe_release_fence(self, key):
        victim = self.fences.get(key)
        if victim is None:
            return
        arrived = self.barrier.get(key, {})
        if len(arrived) >= self.world - 1:
            self._broadcast({"type": "FENCE_RELEASE",
                             "epoch": key[0], "step": key[1]})
            del self.fences[key]

    def _join_arrive(self, msg):
        """ORTE-style rejoin barrier + consistent-rollback consensus: the
        resume step is the minimum checkpoint available across all ranks
        (ranks can be one step apart when a failure lands mid-save)."""
        if msg["epoch"] != self.epoch:
            return
        d = self.joins.setdefault(msg["epoch"], {})
        d[msg["rank"]] = msg["avail"]
        if len(d) == self.world:
            resume = min(d.values())
            self._broadcast({"type": "JOIN_RELEASE", "epoch": msg["epoch"],
                             "resume": resume})
            del self.joins[msg["epoch"]]
            if self.report["events"]:
                ev = self.report["events"][-1]
                if "resume_step" not in ev and ev.get("t_recover_start"):
                    ev["resume_step"] = resume
                    ev["join_release_s"] = \
                        time.monotonic() - ev["t_recover_start"]

    # ---------------------------------------------------------- recovery

    def _handle_failure(self, failure: FailureEvent):
        if self.shutting_down:
            return
        if self.recovering:
            # A node failure can supersede an in-flight process recovery:
            # the dying daemon may have relayed its children's deaths just
            # before its channel broke. Process recovery targeting a dead
            # node would stall, so the node failure takes over; duplicate
            # process failures during recovery are stale and dropped.
            if failure.kind is not FailureType.NODE:
                return
        self.recovering = True
        t_detect = time.monotonic()
        ev = {"failure": str(failure), "kind": failure.kind.value,
              "detect_at_s": t_detect}
        if self.args.mode == "cr":
            self._recover_cr(ev, failure)
        else:
            self._recover_reinit(ev, failure)
        self.report["events"].append(ev)

    def _recover_reinit(self, ev, failure: FailureEvent):
        t0 = time.monotonic()
        cmd = root_handle_failure(self.view, failure)
        self.epoch = cmd.epoch
        self.barrier.clear()
        self.fences.clear()
        self.joins.clear()
        # forget lost workers' addresses (and a lost node's daemon channel)
        if failure.kind is FailureType.NODE:
            lost = [r.rank for r in cmd.respawns]
            self.daemon_socks.pop(failure.node, None)
            self.daemon_pids.pop(failure.node, None)
        else:
            lost = [failure.rank]
        for r in lost:
            self.rank_table.pop(r, None)
        self._pending_respawn = set(lost)
        self._broadcast({"type": "REINIT", "epoch": self.epoch,
                         "respawns": [[r.daemon, r.rank]
                                      for r in cmd.respawns]})
        # pipeline the restore with the respawn: push the survivors'
        # addresses (and the new epoch) out immediately so survivors roll
        # back and re-spawned ranks begin their buddy pulls while the
        # rest of the world is still re-registering — the full table
        # rebroadcast happens when all lost ranks are back
        self._broadcast({"type": "RANK_TABLE", "epoch": self.epoch,
                         "partial": True,
                         "table": {str(k): list(v) for k, v in
                                   self.rank_table.items()}})
        ev["reinit_broadcast_s"] = time.monotonic() - t0
        ev["t_recover_start"] = t0

    def _recover_cr(self, ev, failure: FailureEvent):
        t0 = time.monotonic()
        # teardown: SIGKILL every daemon (daemons take children with them
        # on channel loss; be thorough and kill workers via daemons' procs)
        for node, pid in list(self.daemon_pids.items()):
            try:
                os.kill(pid, signal.SIGKILL)
            except ProcessLookupError:
                pass
        for p in self.daemon_procs.values():
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                pass
        self.daemon_socks.clear()
        self.daemon_pids.clear()
        self.daemon_procs.clear()
        self.rank_table.clear()
        self.barrier.clear()
        self.fences.clear()
        self.joins.clear()
        self.done.clear()
        ev["teardown_s"] = time.monotonic() - t0
        # re-deploy the whole application
        self.epoch += 1
        self.view = ClusterView.build(self.args.nodes,
                                      self.args.ranks_per_node,
                                      self.args.spares)
        self._pending_respawn = set(range(self.world))
        self.deploy()
        ev["t_recover_start"] = t0

    # --------------------------------------------------------------- run

    def _maybe_broadcast_table(self):
        if len(self.rank_table) == self.world:
            self._broadcast({"type": "RANK_TABLE", "epoch": self.epoch,
                             "table": {str(k): list(v) for k, v in
                                       self.rank_table.items()}})
            if self.recovering:
                ev = self.report["events"][-1] if self.report["events"] \
                    else None
                t0 = self._last_recover_start()
                if ev is not None and t0 is not None:
                    ev["mpi_recovery_s"] = time.monotonic() - t0
                self.recovering = False
                self._first_barrier_after_recovery = time.monotonic()
            elif "deploy_s" not in self.report:
                self.report["deploy_s"] = \
                    time.monotonic() - self.report.pop("deploy_start_s")

    def _last_recover_start(self):
        ev = self.report["events"][-1] if self.report["events"] else None
        return ev.get("t_recover_start") if ev else None

    def run(self) -> dict:
        self.deploy()
        t_start = time.monotonic()
        self._first_barrier_after_recovery = None
        self._pending_respawn = set()
        while len(self.done) < self.world:
            try:
                kind, payload = self.events.get(timeout=120)
            except queue.Empty:
                raise TimeoutError("cluster stalled")
            if kind == "channel_broken":
                node, conn = payload
                if (not self.shutting_down
                        and node in self.view.children
                        and self.daemon_socks.get(node) is conn):
                    self._handle_failure(FailureEvent(
                        kind=FailureType.NODE, node=node))
                continue
            msg = payload
            t = msg["type"]
            if t == "REGISTER_WORKER":
                self.rank_table[msg["rank"]] = ("127.0.0.1",
                                                msg["peer_port"])
                self._maybe_broadcast_table()
            elif t == "CHILD_DEAD":
                if not self.recovering and not self.shutting_down:
                    # re-registered ranks also produce CHILD_DEAD for their
                    # old pid; only treat live cluster members as failures
                    self._handle_failure(FailureEvent(
                        kind=FailureType.PROCESS, rank=msg["rank"]))
            elif t == "BARRIER":
                self._barrier_arrive(msg)
            elif t == "FENCE":
                self._fence_arrive(msg)
            elif t == "REINIT_DONE":
                ev = self.report["events"][-1] if self.report["events"] \
                    else None
                t0 = self._last_recover_start()
                if ev is not None and t0 is not None:
                    ev["respawn_done_s"] = time.monotonic() - t0
            elif t == "JOIN":
                self._join_arrive(msg)
            elif t == "DONE":
                self.done.add(msg["rank"])
                self.report.setdefault("checksums", {})[str(msg["rank"])] \
                    = msg["checksum"]
        self.shutting_down = True
        self.report["total_s"] = time.monotonic() - t_start
        self._broadcast({"type": "SHUTDOWN"})
        # join on the daemons' exits instead of a fixed drain sleep: each
        # daemon exits once its workers are gone, so a clean shutdown
        # costs exactly the teardown latency, not a worst-case timer
        for p in self.daemon_procs.values():
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.terminate()
                try:
                    p.wait(timeout=2)
                except subprocess.TimeoutExpired:
                    p.kill()
        if self.args.report:
            with open(self.args.report, "w") as f:
                json.dump(self.report, f, indent=2)
        return self.report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--ranks-per-node", type=int, default=4)
    ap.add_argument("--spares", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--fail-step", type=int, default=-1)
    ap.add_argument("--fail-rank", type=int, default=-1)
    ap.add_argument("--fail-kind", default="process",
                    choices=["process", "node"])
    ap.add_argument("--mode", default="reinit", choices=["reinit", "cr"])
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--report", default="")
    ap.add_argument("--pythonpath", default=os.environ.get("PYTHONPATH", ""))
    args = ap.parse_args(argv)
    os.makedirs(args.ckpt_dir, exist_ok=True)
    rep = Root(args).run()
    ok = len(set(rep.get("checksums", {}).values())) >= 1
    print(json.dumps(rep, indent=2))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
