"""Root (HNP): deployment, liveness, Algorithm 1, recovery orchestration.

Four recovery modes — the paper's two measured approaches plus the
elastic extension it defers as future work and the zero-rollback
replica extension:

  reinit  Algorithm 1 + REINIT broadcast: survivors roll back in place,
          only failed ranks are re-spawned (on the least-loaded node for
          node failures). Recovery cost is confined to the root↔daemon
          tree.
  cr      Checkpoint-Restart: tear the whole job down (SIGKILL every
          daemon) and re-deploy it from scratch; every rank restarts from
          the file checkpoint.
  shrink  Elastic: failures consult the spare pool (Algorithm 1's
          least-loaded choice re-hosts onto a spare while one exists);
          once the pool is exhausted, a SHRINK broadcast drops the lost
          ranks (a node's whole group, or a single rank — leaving uneven
          groups) down to the --min-data-parallel world floor — survivors
          re-balance over the contracted world and resume from the
          consistent cut instead of aborting. The membership machine
          (repro.core.membership) makes every decision and bumps the mesh
          epoch. Bidirectional: a repaired node's daemon re-registers
          (REJOIN) and the admission policy either re-admits the dropped
          ranks at the next checkpoint boundary (GROW broadcast: expanded
          world, bumped mesh epoch, re-admitted ranks restore from the
          pinned pre-shrink cut) or adds the node to the spare pool.
  replica Zero-rollback failover: every rank gets a warm shadow on
          another node (spare nodes first) that applies the primary's
          per-step checkpoint stream. A fenced failure is recovered by
          PROMOTE — the shadow composes its newest warm frame and joins
          the stalled barrier in the victim's place. No SIGREINIT, no
          epoch bump, no respawn: survivors never leave their barrier
          wait, so recovery is promote-and-reform and the resume step IS
          the failure step. Faults the stream cannot cover (mid-write
          kills, a cold or dead shadow, a NACKing shadow) fall back to
          the reinit path. A warm-standby root mirrors the rank/daemon
          tables over a replication channel and takes over on HNP loss
          (daemons re-home to it) — root failure no longer needs an
          external job restart.

The root measures, with wall clocks, the same phases the paper reports:
detection→REINIT-broadcast, re-registration (MPI recovery), and the first
post-recovery barrier (rejoin). Results land in a JSON report consumed by
benchmarks/runtime_bench.py.
"""
from __future__ import annotations

import argparse
import json
import os
import queue
import signal
import subprocess
import sys
import threading
import time

from repro.core.elastic import ElasticManager, MeshEpoch
from repro.core.events import FailureEvent, FailureType
from repro.core.protocol import ClusterView, root_handle_failure, \
    root_handle_failure_promote
from repro.core.recovery import STRATEGIES
from repro.scenarios.schema import GRAY_DRAIN_PERSIST, GRAY_HOWS, \
    ROOT_INJECTED_EXIT, Scenario, gray_delay_s

from .transport import connect, listener, recv_msg, send_msg

# every registered strategy the live process tree can execute; ulfm is
# sim-only by design (its revoke/shrink/agree collectives are modeled,
# not implemented). Derived from the strategy registry so the CLI can
# never drift from it.
MODES = tuple(k for k in STRATEGIES if k != "ulfm")


class Root:
    def __init__(self, args):
        self.args = args
        self.world = args.nodes * args.ranks_per_node
        self.view = ClusterView.build(args.nodes, args.ranks_per_node,
                                      args.spares)
        # live membership — a set, not a count: a shrinking recovery
        # leaves non-contiguous rank ids behind
        self.world_ranks: set[int] = set(self.view.ranks())
        # elastic mode: one node = one data-parallel group; the
        # membership machine owns the spare pool, the shrink/grow
        # decisions, the dropped-rank ledger and the mesh epochs that
        # key the survivors' compiled-step caches
        self.elastic = ElasticManager(
            self.view, MeshEpoch(epoch=0, data_parallel=args.nodes,
                                 model_parallel=args.ranks_per_node),
            min_data_parallel=getattr(args, "min_data_parallel", 1)) \
            if args.mode == "shrink" else None
        self.sock = listener()
        self.port = self.sock.getsockname()[1]
        self.events: "queue.Queue[tuple]" = queue.Queue()
        self.daemon_socks: dict[str, object] = {}
        self.daemon_pids: dict[str, int] = {}
        self.daemon_procs: dict[str, subprocess.Popen] = {}
        self.rank_table: dict[int, tuple[str, int]] = {}
        self._rank_pids: dict[int, int] = {}   # rank -> live incarnation
        self.barrier: dict[tuple[int, int], dict[int, float]] = {}
        self.fences: dict[tuple[int, int], int] = {}  # kill-barrier victims
        self.joins: dict[int, dict[int, int]] = {}   # epoch -> rank -> avail
        # True while the current epoch's rejoin consensus has not yet
        # released: a rank dying inside this window is a cascade of the
        # recovery in flight (it must merge — survivors are still blocked
        # on its vote), never a fresh failure, even when the rank table
        # already rebroadcast (recovering == False)
        self._join_open = True              # initial deploy consensus
        self.epoch = 0
        self.done: set[int] = set()
        self.recovering = False
        self.shutting_down = False
        self.timeline: list[dict] = []
        self.report: dict = {"mode": args.mode, "world": self.world,
                             "events": []}
        # stall watchdog (armed by --stall-timeout > 0): first-arrival
        # clocks per open barrier, and the set of ranks already ordered
        # killed so a slow SIGCHLD doesn't double-fire
        self.stall_timeout = getattr(args, "stall_timeout", 0.0)
        self._barrier_seen: dict[tuple, float] = {}
        self._stall_killed: set[int] = set()
        self._detect_mark: tuple | None = None  # (detector, latency, rank)
        self._detect_mark_node: tuple | None = None  # (by, latency, node)
        # daemon-level heartbeat ring: wport of each live daemon's
        # listener, broadcast as DAEMON_TABLE so daemons observe their
        # ring successor (hung-*daemon* detection)
        self.daemon_ports: dict[str, int] = {}
        # grow-back: initial rank->node map (repairs name the node that
        # originally hosted a rank), repairs due per step, nodes whose
        # next REGISTER_DAEMON is a REJOIN, and admitted nodes queued for
        # the GROW at the next checkpoint boundary
        self._initial_parent = {r: self.view.parent(r)
                                for r in range(self.world)}
        self._repairs: dict[int, list[str]] = {}
        self._rejoining: set[str] = set()
        self._pending_grow: list[str] = []
        self._held_release: tuple | None = None   # barrier paused for a
                                                  # rejoin in flight
        # replica mode: warm shadows (rank -> peer addr / hosting daemon /
        # pid) and the in-flight promote ledger (rank -> hosting daemon,
        # consulted when a PROMOTE_NACK or a mid-promote death arrives)
        self.shadow_table: dict[int, tuple[str, int]] = {}
        self._shadow_parent: dict[int, str] = {}
        self._shadow_pids: dict[int, int] = {}
        self._promote_inflight: dict[int, str] = {}
        self._await_shadows: set[int] = set()   # gate the initial table
                                                # broadcast on warm cover
        # warm-standby root: spawned before deploy in replica mode; the
        # registration carries the standby's listener port, which daemons
        # get on their spawn command line so they can re-home on HNP loss
        self.standby_proc: subprocess.Popen | None = None
        # the replication channel is installed by the accept thread
        # (STANDBY_REGISTER) while the serve loop reads it per event
        self._standby_lock = threading.Lock()
        self.standby_sock = None        # guarded-by: _standby_lock
        self._standby_port = 0
        self._standby_ready = threading.Event()
        self._standby_active = False
        # root-target scenario faults: {step: fault_index}
        self._root_faults: dict[int, int] = {}
        # gray-failure mitigation, armed by the scenario's mitigate knob:
        # a per-rank tracker over barrier lateness (arrival minus the
        # step's first arrival). A rank on a GRAY_DRAIN_PERSIST flag
        # streak is drained at the next completed barrier — see
        # _maybe_drain_stragglers. min_flag_s at half the smallest
        # injected delay keeps scheduler jitter below the trigger.
        self._straggler = None
        if getattr(args, "scenario", ""):
            sc = Scenario.load(args.scenario)
            self._root_faults = {f.step: i for i, f in sc.root_faults()}
            for r in sc.repairs:
                node = self._initial_parent[r.rank]
                self._repairs.setdefault(r.step, []).append(node)
            gray = [f for f in sc.faults if f.how in GRAY_HOWS]
            if sc.mitigate and gray:
                from repro.train.straggler import StragglerTracker
                self._straggler = StragglerTracker(
                    window=32, threshold_mads=4.0, min_samples=2,
                    min_flag_s=0.5 * min(gray_delay_s(f) for f in gray))
        threading.Thread(target=self._accept_loop, daemon=True).start()

    # ------------------------------------------------------------ fabric

    def _accept_loop(self):
        while True:
            try:
                conn, _ = self.sock.accept()
            except OSError:
                return
            threading.Thread(target=self._daemon_conn, args=(conn,),
                             daemon=True).start()

    def _daemon_conn(self, conn):
        node = None
        try:
            while True:
                msg = recv_msg(conn)
                if msg is None:
                    break
                if msg["type"] == "STANDBY_REGISTER":
                    # the warm standby announcing itself: keep the channel
                    # as the replication stream, never queue it as a
                    # cluster event
                    self._standby_port = msg["port"]
                    with self._standby_lock:
                        self.standby_sock = conn
                    self._standby_ready.set()
                    continue
                if msg["type"] == "REGISTER_DAEMON":
                    node = msg["node"]
                    self.daemon_socks[node] = conn
                    self.daemon_pids[node] = msg["pid"]
                    self.daemon_ports[node] = msg.get("port", 0)
                self.events.put(("msg", msg))
        except OSError:
            pass
        if node is not None:
            # carry the socket identity: a channel that was already
            # replaced (CR teardown + re-deploy) must not be mistaken
            # for a failure of the *new* daemon on the same node
            self.events.put(("channel_broken", (node, conn)))

    def _broadcast(self, msg: dict, nodes=None):
        for node, s in list(self.daemon_socks.items()):
            if nodes is not None and node not in nodes:
                continue
            try:
                send_msg(s, msg)
            except OSError:
                pass

    # -------------------------------------------------------- deployment

    def _spawn_daemon(self, node: str):
        a = self.args
        cmd = [sys.executable, "-m", "repro.runtime.daemon",
               "--node", node, "--root-port", str(self.port),
               "--world", str(self.world), "--steps", str(a.steps),
               "--dim", str(a.dim), "--fail-step", str(a.fail_step),
               "--fail-rank", str(a.fail_rank), "--fail-kind", a.fail_kind,
               "--scenario", getattr(a, "scenario", ""),
               "--hb-period", str(getattr(a, "hb_period", 0.0)),
               "--hb-timeout", str(getattr(a, "hb_timeout", 0.0)),
               "--standby-port", str(self._standby_port),
               "--ckpt-dir", a.ckpt_dir, "--pythonpath", a.pythonpath]
        env = dict(os.environ, PYTHONPATH=a.pythonpath)
        self.daemon_procs[node] = subprocess.Popen(cmd, env=env)

    def deploy(self):
        t0 = time.monotonic()
        for node in self.view.daemons():
            self._spawn_daemon(node)
        # wait for all daemons to register, then hand them their ranks
        need = set(self.view.daemons())
        while need:
            kind, msg = self.events.get(timeout=30)
            if kind == "msg" and msg["type"] == "REGISTER_DAEMON":
                need.discard(msg["node"])
        for node in self.view.daemons():
            ranks = sorted(self.view.children[node])
            if ranks:
                send_msg(self.daemon_socks[node],
                         {"type": "SPAWN", "ranks": ranks,
                          "restarted": False, "epoch": self.epoch})
        self.report["deploy_start_s"] = t0

    # ---------------------------------------------------- replica fabric

    def _spawn_standby(self):
        """Spawn the warm-standby root and wait for it to register: its
        listener port goes on every daemon's command line (the re-home
        target), so it must exist before the first daemon spawns."""
        a = self.args
        cmd = [sys.executable, "-m", "repro.runtime.root",
               "--nodes", str(a.nodes),
               "--ranks-per-node", str(a.ranks_per_node),
               "--spares", str(a.spares), "--steps", str(a.steps),
               "--dim", str(a.dim), "--mode", a.mode,
               "--min-data-parallel", str(getattr(a, "min_data_parallel", 1)),
               "--scenario", getattr(a, "scenario", ""),
               "--ckpt-dir", a.ckpt_dir, "--report", a.report,
               "--pythonpath", a.pythonpath,
               "--as-standby", "--primary-port", str(self.port)]
        env = dict(os.environ, PYTHONPATH=a.pythonpath)
        self.standby_proc = subprocess.Popen(cmd, env=env)
        if not self._standby_ready.wait(timeout=30):
            raise TimeoutError("standby root never registered")

    def _deploy_shadows(self):
        """One warm shadow per rank, hosted off the rank's own node —
        spare nodes first (the paper's over-provisioning absorbs the
        shadow load), other compute nodes otherwise. Shadows are
        pre-admitted members with warm state: they apply the primary's
        per-step checkpoint stream and only enter the BSP loop on
        PROMOTE."""
        spares = self.view.spares()
        computes = [d for d in self.view.daemons()
                    if self.view.children.get(d)]
        pool = spares or computes
        by_daemon: dict[str, list[int]] = {}
        i = 0
        for r in sorted(self.view.ranks()):
            home = self.view.parent(r)
            cands = [d for d in pool if d != home] \
                or [d for d in computes if d != home]
            if not cands:
                continue            # single-node world: nowhere to shadow
            host = cands[i % len(cands)]
            i += 1
            self._shadow_parent[r] = host
            by_daemon.setdefault(host, []).append(r)
        # hold the initial table broadcast until every shadow registered:
        # the zero-rollback guarantee needs the stream warm from step 1 —
        # otherwise a slow-deploying shadow joins mid-chain and the first
        # failure races its warm-up
        self._await_shadows = {r for rs in by_daemon.values() for r in rs}
        for host, ranks in by_daemon.items():
            send_msg(self.daemon_socks[host],
                     {"type": "SPAWN", "ranks": sorted(ranks),
                      "restarted": False, "epoch": self.epoch,
                      "shadow": True})

    def _table_msg(self, partial: bool = False) -> dict:
        msg = {"type": "RANK_TABLE", "epoch": self.epoch,
               "world": sorted(self.world_ranks),
               "table": {str(k): list(v) for k, v in
                         self.rank_table.items()}}
        if partial:
            msg["partial"] = True
        if self.shadow_table:
            # primaries stream their per-step frames to their own shadow
            msg["shadows"] = {str(k): list(v) for k, v in
                              self.shadow_table.items()}
        return msg

    def _sync_standby(self):
        """Replicate the root's authoritative tables to the warm standby.
        Called once per processed event — the stream is tiny (rank/daemon
        tables + report), and a takeover needs nothing newer than the
        last completed event."""
        with self._standby_lock:
            standby = self.standby_sock
        if standby is None:
            return
        try:
            send_msg(standby, {
                "type": "SYNC", "epoch": self.epoch,
                "world": sorted(self.world_ranks),
                "table": {str(k): list(v) for k, v in
                          self.rank_table.items()},
                "pids": {str(k): v for k, v in self._rank_pids.items()},
                "shadows": {str(k): list(v) for k, v in
                            self.shadow_table.items()},
                "shadow_parent": {str(k): v for k, v in
                                  self._shadow_parent.items()},
                "shadow_pids": {str(k): v for k, v in
                                self._shadow_pids.items()},
                "children": {d: sorted(rs) for d, rs in
                             self.view.children.items()},
                "view_epoch": self.view.epoch,
                "done": sorted(self.done),
                "report": self.report})
        except OSError:
            with self._standby_lock:      # standby died: run uncovered
                self.standby_sock = None

    # ----------------------------------------------------------- barrier

    def _barrier_arrive(self, msg):
        key = (msg["epoch"], msg["step"])
        if msg["epoch"] != self.epoch:
            return                          # stale pre-recovery arrival
        d = self.barrier.setdefault(key, {})
        t_first = self._barrier_seen.setdefault(key, time.monotonic())
        if self._straggler is not None and msg["rank"] not in d:
            # per-rank lateness relative to the step's first arrival:
            # the signal a slow or lossy rank cannot hide — it does all
            # the work, just late, and every other rank is already here
            self._straggler.observe(key[1], time.monotonic() - t_first,
                                    rank=msg["rank"])
        d[msg["rank"]] = msg["value"]
        if len(d) == len(self.world_ranks):
            # a completed barrier is a checkpoint boundary: every rank
            # has committed this step's checkpoint, which makes it the
            # one safe place to drain a persistent straggler — the
            # consistent cut is exactly this step
            if self._maybe_drain_stragglers(key):
                return
            # A due node repair restarts the repaired node's daemon here
            # and HOLDS this release until its REJOIN is admitted: the
            # world is paused at the boundary, so the grow (or spare
            # grant) lands deterministically between steps, never racing
            # the run to completion
            if self._check_repairs(key[1]):
                self._held_release = (key, d)
                del self.barrier[key]
                self._barrier_seen.pop(key, None)
                return
            # reduce in rank order: float addition is order-sensitive, and
            # a deterministic reduction is what makes a recovered run
            # land on the bit-identical state of the fault-free run
            total = sum(d[r] for r in sorted(d))
            self._broadcast({"type": "BARRIER_RELEASE",
                             "epoch": key[0], "step": key[1],
                             "value": total})
            del self.barrier[key]
            self._barrier_seen.pop(key, None)
            if self.report["events"]:
                ev = self.report["events"][-1]
                if ev.get("promote") and "promote_complete_s" not in ev \
                        and ev.get("t_recover_start"):
                    # the promoted shadow's arrival completed the stalled
                    # barrier: the whole world is computing again — the
                    # replica failover's true end-to-end recovery time.
                    # The promotion window is over: later deaths of these
                    # ranks are ordinary new failures, not window deaths.
                    ev["promote_complete_s"] = \
                        time.monotonic() - ev["t_recover_start"]
                    self._promote_inflight.clear()
            self._maybe_die_as_root(key[1])
            if getattr(self, "_first_barrier_after_recovery", None) is not None:
                t0 = self._first_barrier_after_recovery
                self.report["events"][-1]["rejoin_barrier_s"] = \
                    time.monotonic() - t0
                self._first_barrier_after_recovery = None
        else:
            self._maybe_release_fence(key)

    def _fence_arrive(self, msg):
        """Deterministic kill barrier: a fault-injecting victim FENCEs at
        its kill step instead of dying immediately. The fence releases —
        and only then does the victim die — once every *other* rank has
        arrived at that step's barrier, i.e. has completed the previous
        iteration and committed its checkpoint for this step. The
        consistent cut after recovery is then always exactly the fence
        step, killing the timing dependence SIGKILL injection used to
        have."""
        key = (msg["epoch"], msg["step"])
        if msg["epoch"] != self.epoch:
            return
        self.fences[key] = msg["rank"]
        self._maybe_release_fence(key)

    def _maybe_release_fence(self, key):
        victim = self.fences.get(key)
        if victim is None:
            return
        arrived = self.barrier.get(key, {})
        if len(arrived) >= len(self.world_ranks) - 1:
            self._broadcast({"type": "FENCE_RELEASE",
                             "epoch": key[0], "step": key[1]})
            del self.fences[key]

    def _join_arrive(self, msg):
        """ORTE-style rejoin barrier + consistent-rollback consensus: the
        resume step is the minimum checkpoint available across all ranks
        (ranks can be one step apart when a failure lands mid-save)."""
        if msg["epoch"] != self.epoch:
            return
        d = self.joins.setdefault(msg["epoch"], {})
        d[msg["rank"]] = msg["avail"]
        if len(d) == len(self.world_ranks):
            resume = min(d.values())
            self._broadcast({"type": "JOIN_RELEASE", "epoch": msg["epoch"],
                             "resume": resume})
            del self.joins[msg["epoch"]]
            self._join_open = False
            if self.report["events"]:
                ev = self.report["events"][-1]
                if "resume_step" not in ev and ev.get("t_recover_start"):
                    ev["resume_step"] = resume
                    ev["join_release_s"] = \
                        time.monotonic() - ev["t_recover_start"]

    def _maybe_drain_stragglers(self, key) -> bool:
        """Gray-failure mitigation: called with a COMPLETED barrier,
        before its release. A rank on a GRAY_DRAIN_PERSIST consecutive
        flag streak is persistently degraded — withhold the release and
        order it killed (its whole node, when the flagged set covers the
        node's live ranks). Every rank committed step `key[1]`'s
        checkpoint before arriving, so the ensuing SIGCHLD/EOF-driven
        shrink resumes from exactly this boundary; the drained rank's
        eventual grow-back incarnation spawns healthy (--restarted
        drops the gray plan) and is re-admitted on merit. Returns True
        when a drain was ordered (the caller then skips the release)."""
        if (self._straggler is None or self.recovering
                or self.shutting_down):
            return False
        flagged = self._straggler.stragglers(
            persist=GRAY_DRAIN_PERSIST) & self.world_ranks
        if not flagged:
            return False
        now = time.monotonic()
        t0 = self._barrier_seen.get(key)
        lat = None if t0 is None else now - t0
        # node drain when a whole node's live ranks are on a streak —
        # the degradation is the node's, not any one process's
        for node in sorted(self.view.children):
            live = set(self.view.children[node]) & self.world_ranks
            if not live or not live <= flagged:
                continue
            sock = self.daemon_socks.get(node)
            if sock is None:
                continue
            try:
                send_msg(sock, {"type": "KILL_NODE"})
            except OSError:
                continue
            self._detect_mark_node = ("straggler", lat, node)
            del self.barrier[key]
            self._barrier_seen.pop(key, None)
            return True
        rank = min(flagged)
        try:
            daemon = self.view.parent(rank)
        except KeyError:
            return False
        sock = self.daemon_socks.get(daemon)
        if sock is None:
            return False
        try:
            send_msg(sock, {"type": "KILL_RANK", "rank": rank})
        except OSError:
            return False
        self._stall_killed.add(rank)
        self._detect_mark = ("straggler", lat, rank)
        del self.barrier[key]
        self._barrier_seen.pop(key, None)
        return True

    # ------------------------------------------------- injection/watchdog

    def _maybe_die_as_root(self, step: int):
        """Root-target fault: die right after releasing this step's
        barrier. The HNP is Reinit++'s single point of failure — only an
        external job restart (the engine relaunching this command, the
        sentinel stopping a re-fire) recovers from it."""
        idx = self._root_faults.get(step)
        if idx is None:
            return
        sentinel = os.path.join(self.args.ckpt_dir, f"INJECTED_root_f{idx}")
        try:
            fd = os.open(sentinel, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return
        os.write(fd, f"root step={step}".encode())
        os.close(fd)
        os._exit(ROOT_INJECTED_EXIT)

    def _order_kill(self, rank: int, by: str):
        """Order a silent rank's daemon to SIGKILL it (stall watchdog or a
        neighbour-heartbeat SUSPECT); the resulting SIGCHLD drives the
        ordinary failure path. Records which detector fired and how long
        after the stuck barrier's first arrival — the measured detection
        latency the benchmark compares across detectors."""
        if rank in self._stall_killed:
            return
        self._stall_killed.add(rank)
        try:
            daemon = self.view.parent(rank)
        except KeyError:
            return
        sock = self.daemon_socks.get(daemon)
        if sock is None:
            return
        now = time.monotonic()
        t0 = min((t for k, t in self._barrier_seen.items()
                  if k[0] == self.epoch), default=None)
        try:
            send_msg(sock, {"type": "KILL_RANK", "rank": rank})
        except OSError:
            return      # kill never delivered: claim no detection credit
        self._detect_mark = (by, None if t0 is None else now - t0, rank)

    def _check_stalls(self):
        """Stall watchdog: a barrier stuck past --stall-timeout with a
        subset of the world arrived means the missing ranks are silent
        (hung or partitioned but undead) — order their daemons to SIGKILL
        them."""
        if (self.stall_timeout <= 0 or self.recovering
                or self.shutting_down):
            return
        now = time.monotonic()
        for key, t0 in list(self._barrier_seen.items()):
            if key[0] != self.epoch or now - t0 < self.stall_timeout:
                continue
            arrived = set(self.barrier.get(key, {}))
            missing = self.world_ranks - arrived - self.done
            for rank in sorted(missing - self._stall_killed):
                self._order_kill(rank, "watchdog")

    def _handle_suspect(self, msg):
        """A worker's heartbeat observer timed out on its ring successor
        and reported SUSPECT: kill the silent rank so SIGCHLD recovery
        runs — detection without any watchdog timeout on the path."""
        rank = msg["rank"]
        if (self.recovering or self.shutting_down
                or rank not in self.world_ranks or rank in self.done
                or msg.get("epoch", self.epoch) != self.epoch):
            return
        self._order_kill(rank, "heartbeat")

    def _handle_suspect_node(self, msg):
        """A daemon's ring observer timed out on its successor *daemon*:
        the whole node is silent (a hung daemon relays nothing — its
        children's barrier traffic, CHILD_DEADs and heartbeat ACKs all
        stop). SIGKILL the hung daemon: the channel EOF then drives the
        ordinary node-failure path, credited to the heartbeat ring."""
        node = msg["node"]
        if (self.recovering or self.shutting_down
                or node not in self.view.children):
            return
        pid = self.daemon_pids.get(node)
        if pid is None:
            return
        now = time.monotonic()
        t0 = min((t for k, t in self._barrier_seen.items()
                  if k[0] == self.epoch), default=None)
        try:
            os.kill(pid, signal.SIGKILL)
        except ProcessLookupError:
            return
        self._detect_mark_node = \
            ("heartbeat", None if t0 is None else now - t0, node)

    # --------------------------------------------------------- grow-back

    def _check_repairs(self, step: int) -> bool:
        """Scenario-driven node repair: at the step's checkpoint boundary
        the repaired node's daemon restarts and re-registers. Returns
        True when a daemon was (re)started — the caller then holds the
        boundary's barrier release until the REJOIN is admitted. Only the
        elastic mode acts on repairs; the other modes never shrank, so a
        repair is meaningless there (and CR resurrects dead nodes
        wholesale on its own)."""
        if self.elastic is None or self.shutting_down:
            self._repairs.pop(step, None)
            return False
        started = False
        for node in self._repairs.pop(step, []):
            if node in self.daemon_socks or node in self.view.children:
                continue            # never left / already back
            self._rejoining.add(node)
            self._spawn_daemon(node)
            started = True
        return started

    def _release_held(self):
        """Release the barrier held for a rejoin that did not re-shape
        the world (spare admission): the paused boundary resumes exactly
        where it stopped. A grow never gets here — its epoch bump voids
        the held barrier and the rollback consensus takes over."""
        held, self._held_release = self._held_release, None
        if held is None:
            return
        key, d = held
        if key[0] != self.epoch:
            return
        total = sum(d[r] for r in sorted(d))
        self._broadcast({"type": "BARRIER_RELEASE", "epoch": key[0],
                         "step": key[1], "value": total})
        self._maybe_die_as_root(key[1])

    def _handle_rejoin(self, node: str):
        """REJOIN: a repaired node's daemon re-registered while the world
        is paused at the repair step's boundary. Root-side admission
        policy (the membership machine): re-admit the dropped ranks
        (GROW) when the world is shrunk, else grant the node into the
        spare pool and resume the paused boundary."""
        if self.elastic.admit(node) == "spare":
            self.elastic.grant_spare(node)
            self.report["events"].append(
                {"rejoin": node, "admitted": "spare",
                 "spares": self.elastic.spares()})
            self._release_held()
            return
        if self.recovering:
            self._pending_grow.append(node)    # folded in after recovery
            return
        self._execute_grow(node)

    def _execute_grow(self, node: str):
        """GROW broadcast at a checkpoint boundary: re-admit the most
        recently dropped rank group onto the rejoined node. Survivors get
        SIGREINIT + the expanded membership (bumped epoch and mesh
        epoch); the rejoined daemon spawns the re-admitted ranks, which
        restore from the durable checkpoints they committed before being
        dropped — the consensus therefore lands exactly on the pinned
        pre-shrink cut, and the re-expanded world replays from it."""
        if node not in self.daemon_socks:
            return                  # the repaired node died again already
        t0 = time.monotonic()
        cmd = self.elastic.grow(node)
        self.epoch = cmd.epoch
        self.recovering = True
        self._reset_sync_state()
        for r in cmd.added:
            self.rank_table.pop(r, None)
            self._rank_pids.pop(r, None)
        self.world_ranks = set(cmd.world)
        self._pending_respawn = set(cmd.added)
        ev = {"grow": True, "node": node, "added": sorted(cmd.added),
              "world_after": len(cmd.world),
              "mesh_epoch": cmd.mesh_epoch,
              "detect_at_s": t0, "detected_by": "rejoin"}
        self.report["events"].append(ev)
        self._broadcast({"type": "GROW", "epoch": self.epoch,
                         "world": sorted(cmd.world),
                         "mesh_epoch": cmd.mesh_epoch,
                         "respawns": [[node, r] for r in cmd.added]})
        # pipeline the restore with the spawn, like REINIT: survivors'
        # addresses go out immediately so the re-admitted ranks can try
        # buddy pulls while the rest of the world re-registers
        self._broadcast(self._table_msg(partial=True))
        ev["reinit_broadcast_s"] = time.monotonic() - t0
        ev["t_recover_start"] = t0

    # ---------------------------------------------------------- recovery

    def _respawn_during_recovery(self, rank: int):
        """Cascading failure: a rank died while a recovery is already in
        flight (a replacement dying mid-restore, a survivor dying right
        after rollback). Merge it into the current recovery — forget its
        address and any stale consensus vote, re-spawn it at its current
        daemon, and let it join the in-flight rejoin barrier."""
        self.rank_table.pop(rank, None)
        self.joins.get(self.epoch, {}).pop(rank, None)
        self._pending_respawn.add(rank)
        try:
            daemon = self.view.parent(rank)
        except KeyError:
            return
        sock = self.daemon_socks.get(daemon)
        if sock is None:
            return      # node recovery in flight; its respawn covers this
        if self.report["events"]:
            ev = self.report["events"][-1]
            ev["cascades"] = ev.get("cascades", 0) + 1
        try:
            send_msg(sock, {"type": "SPAWN", "ranks": [rank],
                            "restarted": True, "epoch": self.epoch})
        except OSError:
            pass

    def _handle_failure(self, failure: FailureEvent):
        if self.shutting_down:
            return
        if self.recovering:
            # A node failure can supersede an in-flight process recovery:
            # the dying daemon may have relayed its children's deaths just
            # before its channel broke. Process recovery targeting a dead
            # node would stall, so the node failure takes over; duplicate
            # process failures during recovery are stale and dropped.
            if failure.kind is not FailureType.NODE:
                return
        self.recovering = True
        t_detect = time.monotonic()
        ev = {"failure": str(failure), "kind": failure.kind.value,
              "detect_at_s": t_detect}
        mark, self._detect_mark = self._detect_mark, None
        nmark, self._detect_mark_node = self._detect_mark_node, None
        if mark is not None and failure.kind is FailureType.PROCESS \
                and failure.rank == mark[2]:
            # this failure is the SIGCHLD of the kill we ordered: credit
            # the detector that ordered it (watchdog vs heartbeat ring).
            # A mismatched failure (e.g. the whole node died under the
            # ordered kill) drops the mark — no misattributed credit.
            by, latency, _ = mark
            ev["detected_by"] = by
            if latency is not None:
                ev["detect_latency_s"] = latency
        elif nmark is not None and failure.kind is FailureType.NODE \
                and failure.node == nmark[2]:
            # the channel EOF of the daemon we SIGKILLed on the daemon
            # ring's SUSPECT_NODE: the heartbeat detected a hung *node*
            by, latency, _ = nmark
            ev["detected_by"] = by
            if latency is not None:
                ev["detect_latency_s"] = latency
        else:
            ev["detected_by"] = "channel" \
                if failure.kind is FailureType.NODE else "sigchld"
        # append before dispatch: recovery helpers (and the table
        # rebroadcast a shrink triggers synchronously) annotate
        # report["events"][-1]
        self.report["events"].append(ev)
        if self.args.mode == "cr":
            self._recover_cr(ev, failure)
        elif self.args.mode == "replica":
            self._recover_replica(ev, failure)
        elif self.elastic is not None \
                and self.elastic.decide(failure) == "shrink":
            self._recover_shrink(ev, failure)
        else:
            if self.elastic is not None:
                self.elastic.nonshrink_plan(failure)   # mesh bookkeeping
            self._recover_reinit(ev, failure)

    def _reset_sync_state(self):
        """Drop every pre-recovery synchronization artifact (open
        barriers, watchdog clocks, ordered kills, fences, consensus
        votes) — stale entries under a new epoch fire spurious
        releases/kills. Every recovery path starts with this."""
        self.barrier.clear()
        self._barrier_seen.clear()
        self._stall_killed.clear()
        self.fences.clear()
        self.joins.clear()
        self._held_release = None
        self._join_open = True     # every recovery re-runs the consensus
        if self._straggler is not None:
            # streaks describe pre-recovery incarnations; the drained
            # rank's healthy replacement starts with a clean slate
            self._straggler.reset_streaks()

    def _recover_reinit(self, ev, failure: FailureEvent):
        t0 = time.monotonic()
        cmd = root_handle_failure(self.view, failure)
        self.epoch = cmd.epoch
        self._reset_sync_state()
        # forget lost workers' addresses (and a lost node's daemon channel)
        if failure.kind is FailureType.NODE:
            lost = [r.rank for r in cmd.respawns]
            self.daemon_socks.pop(failure.node, None)
            self.daemon_pids.pop(failure.node, None)
            self.daemon_ports.pop(failure.node, None)
        else:
            lost = [failure.rank]
        for r in lost:
            self.rank_table.pop(r, None)
        self._pending_respawn = set(lost)
        self._broadcast({"type": "REINIT", "epoch": self.epoch,
                         "respawns": [[r.daemon, r.rank]
                                      for r in cmd.respawns]})
        # pipeline the restore with the respawn: push the survivors'
        # addresses (and the new epoch) out immediately so survivors roll
        # back and re-spawned ranks begin their buddy pulls while the
        # rest of the world is still re-registering — the full table
        # rebroadcast happens when all lost ranks are back
        self._broadcast(self._table_msg(partial=True))
        ev["reinit_broadcast_s"] = time.monotonic() - t0
        ev["t_recover_start"] = t0

    def _recover_shrink(self, ev, failure: FailureEvent):
        """Elastic shrinking recovery (spare pool exhausted): drop the
        lost ranks from the world instead of respawning — a whole node's
        group on a node loss, or a single rank on a process loss (the
        surviving groups then being uneven). Survivors get SIGREINIT +
        the SHRINK broadcast (shrunk rank membership, bumped epoch and
        mesh epoch), re-balance the batch over the contracted world, and
        resume from the consistent cut — which they keep pinned on disk
        as the grow-back anchor until a repaired node re-expands the
        world."""
        t0 = time.monotonic()
        cmd = self.elastic.shrink(failure)     # view+mesh+dropped ledger
        mesh_epoch = self.elastic.mesh.epoch
        self.epoch = cmd.epoch
        self._reset_sync_state()
        if failure.kind is FailureType.NODE:
            self.daemon_socks.pop(failure.node, None)
            self.daemon_pids.pop(failure.node, None)
            self.daemon_procs.pop(failure.node, None)
            self.daemon_ports.pop(failure.node, None)
        for r in cmd.dropped:
            self.rank_table.pop(r, None)
            self._rank_pids.pop(r, None)
            self.done.discard(r)
        self.world_ranks = set(cmd.world)
        self._pending_respawn = set()
        self._broadcast({"type": "SHRINK", "epoch": self.epoch,
                         "world": sorted(cmd.world),
                         "mesh_epoch": mesh_epoch})
        ev["shrink"] = True
        ev["dropped"] = sorted(cmd.dropped)
        ev["world_after"] = len(cmd.world)
        ev["mesh_epoch"] = mesh_epoch
        ev["reinit_broadcast_s"] = time.monotonic() - t0
        ev["t_recover_start"] = t0
        # no respawns: every survivor's address is already known, so the
        # full-table rebroadcast — and with it the recovery — completes
        # immediately; the remaining cost is the survivors' rollback
        self._maybe_broadcast_table()

    # ----------------------------------------------- replica (promote)

    def _drop_shadow(self, rank: int):
        self.shadow_table.pop(rank, None)
        self._shadow_parent.pop(rank, None)
        self._shadow_pids.pop(rank, None)

    def _handle_shadow_death(self, rank: int):
        """A warm shadow died (its own injected fault, or collateral).
        The rank's primary is untouched, so this is not a recovery — the
        rank just lost its zero-rollback cover and the next failure falls
        back to reinit."""
        self._drop_shadow(rank)
        if not self.shutting_down:
            self.report["events"].append({"shadow_lost": rank})

    def _can_promote(self, failure: FailureEvent):
        """Returns the zero-rollback resume step, or None when the
        failure is not promotable. Promotable means: every lost rank has
        a registered shadow hosted off the failed node, AND every
        survivor is already parked at one stalled barrier — the fenced
        consistent cut, which is exactly the step the warm frame holds.
        An unfenced failure (mid-write kill, hang) leaves survivors
        scattered and the stream behind the cut: fall back to reinit."""
        if failure.kind is FailureType.NODE:
            lost = sorted(self.view.children.get(failure.node, ()))
            if not lost:
                return None
        else:
            if failure.rank not in self.world_ranks:
                return None
            lost = [failure.rank]
        for r in lost:
            home = self._shadow_parent.get(r)
            if r not in self.shadow_table or home is None \
                    or home not in self.daemon_socks:
                return None
            if failure.kind is FailureType.NODE and home == failure.node:
                return None
        survivors = self.world_ranks - set(lost)
        for (ep, step), d in self.barrier.items():
            if ep == self.epoch and survivors <= set(d) \
                    and len(d) < len(self.world_ranks):
                return step
        return None

    def _recover_replica(self, ev, failure: FailureEvent):
        """Zero-rollback failover: promote the lost ranks' warm shadows
        in place, or fall back to Algorithm-1 reinit when the stream
        cannot cover this failure."""
        if failure.kind is FailureType.NODE:
            # the dead node takes the shadows it hosted with it
            doomed = sorted(r for r, h in self._shadow_parent.items()
                            if h == failure.node)
            for r in doomed:
                self._drop_shadow(r)
            if doomed:
                ev["shadows_lost"] = doomed
        resume = self._can_promote(failure)
        if resume is None:
            ev["promote"] = False
            self._recover_reinit(ev, failure)
            return
        self._recover_promote(ev, failure, resume)

    def _recover_promote(self, ev, failure: FailureEvent, resume: int):
        """PROMOTE: move each lost rank to its shadow's daemon, point the
        rank table at the shadow's peer listener, and tell the shadow to
        compose its warm frame and enter the BSP loop at `resume`.

        Deliberately NO epoch bump, NO SIGREINIT, NO _reset_sync_state():
        survivors stay parked at the stalled barrier — the promoted
        shadows' arrivals are what complete it. The rank-ordered
        reduction then sums the identical values a fault-free run would
        have, so the recovered run stays bit-identical."""
        t0 = time.monotonic()
        cmd = root_handle_failure_promote(self.view, failure,
                                          dict(self._shadow_parent))
        if failure.kind is FailureType.NODE:
            self.daemon_socks.pop(failure.node, None)
            self.daemon_pids.pop(failure.node, None)
            self.daemon_procs.pop(failure.node, None)
            self.daemon_ports.pop(failure.node, None)
        ev["promote"] = True
        ev["promoted"] = [p.rank for p in cmd.promotions]
        ev["resume_step"] = resume
        ev["t_recover_start"] = t0
        self._pending_respawn = set()
        for p in cmd.promotions:
            addr = self.shadow_table.pop(p.rank)
            home = self._shadow_parent.pop(p.rank)
            self._promote_inflight[p.rank] = home
            self.rank_table[p.rank] = addr
            self._rank_pids[p.rank] = self._shadow_pids.pop(p.rank, None)
            sock = self.daemon_socks.get(home)
            if sock is not None:
                try:
                    send_msg(sock, {"type": "PROMOTE", "rank": p.rank,
                                    "resume": resume,
                                    "epoch": self.epoch})
                except OSError:
                    pass
        ev["reinit_broadcast_s"] = time.monotonic() - t0
        self._maybe_broadcast_table()

    def _promote_window_death(self, rank: int):
        """A freshly-promoted shadow died inside the promotion window
        (after PROMOTE, before its barrier arrival completed the stalled
        cut). Merge into the recovery in flight: fall back to a reinit
        respawn annotated on the SAME consensus entry — never a second
        event, never a double promote, never a deadlocked barrier."""
        self._promote_inflight.pop(rank, None)
        ev = self.report["events"][-1]
        ev.setdefault("promote_window_death", []).append(rank)
        ev["promote"] = False
        self.recovering = True
        self._recover_reinit(ev, FailureEvent(kind=FailureType.PROCESS,
                                              rank=rank))

    def _promote_nack(self, msg):
        """The shadow cannot compose the agreed resume step (its stream
        lagged): kill it so the ordinary failure path re-runs — with the
        shadow gone, _recover_replica falls back to reinit."""
        r = msg["rank"]
        home = self._promote_inflight.pop(r, None)
        if home is None:
            return
        if self.report["events"]:
            ev = self.report["events"][-1]
            ev.setdefault("promote_nack", []).append(r)
        sock = self.daemon_socks.get(home)
        if sock is not None:
            try:
                send_msg(sock, {"type": "KILL_RANK", "rank": r})
            except OSError:
                pass

    def _recover_cr(self, ev, failure: FailureEvent):
        t0 = time.monotonic()
        # teardown: SIGKILL every daemon (daemons take children with them
        # on channel loss; be thorough and kill workers via daemons' procs)
        for node, pid in list(self.daemon_pids.items()):
            try:
                os.kill(pid, signal.SIGKILL)
            except ProcessLookupError:
                pass
        for p in self.daemon_procs.values():
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                pass
        self.daemon_socks.clear()
        self.daemon_pids.clear()
        self.daemon_procs.clear()
        self.daemon_ports.clear()
        self._rejoining.clear()
        self._pending_grow.clear()
        self.rank_table.clear()
        self._rank_pids.clear()     # every old incarnation died with the
                                    # teardown; their reports are stale
        self._reset_sync_state()
        self.done.clear()
        ev["teardown_s"] = time.monotonic() - t0
        # re-deploy the whole application
        self.epoch += 1
        self.view = ClusterView.build(self.args.nodes,
                                      self.args.ranks_per_node,
                                      self.args.spares)
        self.world_ranks = set(self.view.ranks())
        self._pending_respawn = set(range(self.world))
        self.deploy()
        ev["t_recover_start"] = t0

    # --------------------------------------------------------------- run

    def _maybe_broadcast_table(self):
        if self._await_shadows:
            return      # replica deploy: shadows still coming up
        if len(self.rank_table) == len(self.world_ranks):
            self._broadcast(self._table_msg())
            # daemon ring membership for hung-daemon observation: every
            # live daemon (spares included) observes its ring successor
            self._broadcast({"type": "DAEMON_TABLE", "epoch": self.epoch,
                             "table": {d: self.daemon_ports[d]
                                       for d in self.view.daemons()
                                       if d in self.daemon_ports}})
            if self.recovering:
                ev = self.report["events"][-1] if self.report["events"] \
                    else None
                t0 = self._last_recover_start()
                if ev is not None and t0 is not None:
                    ev["mpi_recovery_s"] = time.monotonic() - t0
                self.recovering = False
                self._first_barrier_after_recovery = time.monotonic()
                if self._pending_grow and not self.shutting_down:
                    # a rejoin admitted while the recovery was in flight:
                    # the world is consistent again, grow now
                    self._execute_grow(self._pending_grow.pop(0))
            elif "deploy_s" not in self.report:
                self.report["deploy_s"] = \
                    time.monotonic() - self.report.pop("deploy_start_s")

    def _last_recover_start(self):
        ev = self.report["events"][-1] if self.report["events"] else None
        return ev.get("t_recover_start") if ev else None

    def run(self) -> dict:
        if self.args.mode == "replica":
            self._spawn_standby()
        self.deploy()
        if self.args.mode == "replica":
            self._deploy_shadows()
        t_start = time.monotonic()
        self._first_barrier_after_recovery = None
        self._pending_respawn = set()
        self._serve()
        return self._finish(t_start)

    def _serve(self):
        # with the stall watchdog armed the event wait ticks so silent
        # ranks are noticed; either way 120 s without any event at all is
        # a dead cluster
        tick = 0.5 if self.stall_timeout > 0 else 120.0
        last_event = time.monotonic()
        while len(self.done) < len(self.world_ranks):
            try:
                kind, payload = self.events.get(timeout=tick)
            except queue.Empty:
                if time.monotonic() - last_event > 120:
                    raise TimeoutError("cluster stalled")
                self._check_stalls()
                continue
            last_event = time.monotonic()
            if kind == "channel_broken":
                node, conn = payload
                if (not self.shutting_down
                        and node in self.view.children
                        and self.daemon_socks.get(node) is conn):
                    self._handle_failure(FailureEvent(
                        kind=FailureType.NODE, node=node))
                continue
            msg = payload
            t = msg["type"]
            if t == "REGISTER_DAEMON":
                # post-deployment registration = REJOIN of a repaired
                # node (the initial deployment consumes its
                # registrations inside deploy()) — or a daemon re-homing
                # to this standby after the primary root died: ask its
                # workers to re-send any in-flight sync message the dead
                # root swallowed
                node = msg["node"]
                if self._standby_active and msg.get("rehome"):
                    sock = self.daemon_socks.get(node)
                    if sock is not None:
                        try:
                            send_msg(sock, {"type": "RESYNC"})
                        except OSError:
                            pass
                    for e in reversed(self.report["events"]):
                        if e.get("standby_takeover"):
                            # takeover latency: primary loss -> first
                            # daemon re-homed to this standby
                            e.setdefault("takeover_s", time.monotonic()
                                         - e["detect_at_s"])
                            break
                elif self.elastic is not None and node in self._rejoining:
                    self._rejoining.discard(node)
                    self._handle_rejoin(node)
            elif t == "REGISTER_WORKER":
                if msg.get("shadow"):
                    # a warm shadow came up: record its peer listener and
                    # rebroadcast the table so its primary starts
                    # streaming frames to it
                    self.shadow_table[msg["rank"]] = ("127.0.0.1",
                                                      msg["peer_port"])
                    self._shadow_pids[msg["rank"]] = msg.get("pid")
                    self._shadow_parent[msg["rank"]] = msg["node"]
                    self._await_shadows.discard(msg["rank"])
                    self._maybe_broadcast_table()
                else:
                    self.rank_table[msg["rank"]] = ("127.0.0.1",
                                                    msg["peer_port"])
                    self._rank_pids[msg["rank"]] = msg.get("pid")
                    self._pending_respawn.discard(msg["rank"])
                    self._maybe_broadcast_table()
            elif t == "CHILD_DEAD":
                # a death report for a pid that is not the rank's current
                # incarnation is stale (old pid of a re-registered rank,
                # or a straggler from a torn-down deployment) — drop it
                pid, known = msg.get("pid"), self._rank_pids.get(msg["rank"])
                stale = None not in (pid, known) and pid != known
                if pid is not None \
                        and pid == self._shadow_pids.get(msg["rank"]):
                    # an un-promoted shadow died, not the rank itself
                    self._handle_shadow_death(msg["rank"])
                elif self.shutting_down or stale:
                    pass
                elif not self.recovering:
                    if msg["rank"] in self._promote_inflight:
                        self._promote_window_death(msg["rank"])
                    elif self._join_open and known is not None \
                            and msg["rank"] in self.world_ranks:
                        # died inside the open rejoin window (after the
                        # table rebroadcast, before the consensus
                        # released): a cascade of the recovery still in
                        # flight — merge it, don't open a new recovery
                        # (the elastic path would otherwise drop a
                        # replacement that survivors are blocked waiting
                        # on)
                        self._respawn_during_recovery(msg["rank"])
                    else:
                        self._handle_failure(FailureEvent(
                            kind=FailureType.PROCESS, rank=msg["rank"]))
                elif known is not None:
                    # cascading failure mid-recovery: fold into the
                    # in-flight recovery instead of dropping it (a
                    # dropped death would stall the rejoin forever).
                    # known=None means the rank never registered in this
                    # world — a straggler report from a torn-down
                    # deployment, not a cascade.
                    self._respawn_during_recovery(msg["rank"])
            elif t == "BARRIER":
                self._barrier_arrive(msg)
            elif t == "FENCE":
                self._fence_arrive(msg)
            elif t == "REINIT_DONE":
                ev = self.report["events"][-1] if self.report["events"] \
                    else None
                t0 = self._last_recover_start()
                if ev is not None and t0 is not None:
                    ev["respawn_done_s"] = time.monotonic() - t0
            elif t == "JOIN":
                self._join_arrive(msg)
            elif t == "PROMOTE_NACK":
                self._promote_nack(msg)
            elif t == "SUSPECT":
                self._handle_suspect(msg)
            elif t == "SUSPECT_NODE":
                self._handle_suspect_node(msg)
            elif t == "DONE":
                self.done.add(msg["rank"])
                self.report.setdefault("checksums", {})[str(msg["rank"])] \
                    = msg["checksum"]
            self._sync_standby()

    def _finish(self, t_start: float) -> dict:
        self.shutting_down = True
        self.report["total_s"] = time.monotonic() - t_start
        self._broadcast({"type": "SHUTDOWN"})
        # join on the daemons' exits instead of a fixed drain sleep: each
        # daemon exits once its workers are gone, so a clean shutdown
        # costs exactly the teardown latency, not a worst-case timer
        for p in self.daemon_procs.values():
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.terminate()
                try:
                    p.wait(timeout=2)
                except subprocess.TimeoutExpired:
                    p.kill()
        if self.args.report:
            # tmp + atomic rename: the scenario engine (and any external
            # watcher) takes the file's existence as completion — a
            # standby takeover hands off through exactly this commit
            tmp = self.args.report + ".tmp"
            with open(tmp, "w") as f:
                json.dump(self.report, f, indent=2)
            os.replace(tmp, self.args.report)
        with self._standby_lock:
            standby = self.standby_sock
        if standby is not None:
            try:
                send_msg(standby, {"type": "SHUTDOWN_STANDBY"})
            except OSError:
                pass
        if self.standby_proc is not None:
            try:
                self.standby_proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.standby_proc.kill()
        return self.report

    # ----------------------------------------------------- standby root

    def _apply_sync(self, msg: dict):
        self.epoch = msg["epoch"]
        self.world_ranks = set(msg["world"])
        self.rank_table = {int(k): tuple(v)
                           for k, v in msg["table"].items()}
        self._rank_pids = {int(k): v for k, v in msg["pids"].items()}
        self.shadow_table = {int(k): tuple(v)
                             for k, v in msg["shadows"].items()}
        self._shadow_parent = {int(k): v
                               for k, v in msg["shadow_parent"].items()}
        self._shadow_pids = {int(k): v
                             for k, v in msg["shadow_pids"].items()}
        self.view.children = {d: set(rs)
                              for d, rs in msg["children"].items()}
        self.view.epoch = msg["view_epoch"]
        self.done = set(msg["done"])
        self.report = msg["report"]

    def run_standby(self) -> dict:
        """Warm-standby protocol: register with the primary, mirror its
        table/membership/report stream, and on primary loss take over —
        daemons re-home here, in-flight sync messages are re-requested
        (RESYNC), and this process finishes the job and commits the
        report the dead primary never could. A clean SHUTDOWN_STANDBY
        from the primary exits quietly instead. Returns {} when no
        takeover happened."""
        s = connect("127.0.0.1", self.args.primary_port)
        send_msg(s, {"type": "STANDBY_REGISTER", "port": self.port,
                     "pid": os.getpid()})
        synced = False
        while True:
            try:
                msg = recv_msg(s)
            except OSError:
                msg = None
            if msg is None:
                break                        # primary died mid-job
            if msg["type"] == "SHUTDOWN_STANDBY":
                return {}
            if msg["type"] == "SYNC":
                self._apply_sync(msg)
                synced = True
        if not synced or self.shutting_down:
            return {}
        # --- takeover
        self._standby_active = True
        t0 = time.monotonic()
        self.report.setdefault("events", []).append(
            {"failure": "root", "kind": "root", "detected_by": "standby",
             "standby_takeover": True, "detect_at_s": t0})
        self._first_barrier_after_recovery = None
        self._pending_respawn = set()
        self._serve()
        return self._finish(t0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--ranks-per-node", type=int, default=4)
    ap.add_argument("--spares", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--fail-step", type=int, default=-1)
    ap.add_argument("--fail-rank", type=int, default=-1)
    ap.add_argument("--fail-kind", default="process",
                    choices=["process", "node"])
    ap.add_argument("--mode", default="reinit", choices=list(MODES))
    ap.add_argument("--min-data-parallel", type=int, default=1,
                    help="elastic world floor, in whole node groups: "
                         "shrink refuses to drop below "
                         "min_data_parallel * ranks_per_node ranks")
    ap.add_argument("--scenario", default="",
                    help="declarative Scenario JSON driving fault "
                         "injection (supersedes the --fail-* flags)")
    ap.add_argument("--stall-timeout", type=float, default=0.0,
                    help="arm the stall watchdog: a barrier stuck this "
                         "many seconds gets its missing ranks killed")
    ap.add_argument("--hb-period", type=float, default=0.0,
                    help="arm the worker neighbour-heartbeat ring: each "
                         "rank observes its ring successor this often")
    ap.add_argument("--hb-timeout", type=float, default=0.0,
                    help="consecutive heartbeat silence before the "
                         "observer reports SUSPECT to the root")
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--report", default="")
    ap.add_argument("--pythonpath", default=os.environ.get("PYTHONPATH", ""))
    ap.add_argument("--as-standby", action="store_true",
                    help="run as the warm-standby root: mirror the "
                         "primary's tables and take over on its loss")
    ap.add_argument("--primary-port", type=int, default=0,
                    help="primary root's listener (standby mode only)")
    args = ap.parse_args(argv)
    os.makedirs(args.ckpt_dir, exist_ok=True)
    if args.as_standby:
        rep = Root(args).run_standby()
        if not rep:
            return 0            # clean primary finish: nothing to do
        print(json.dumps(rep, indent=2))
        return 0
    rep = Root(args).run()
    ok = len(set(rep.get("checksums", {}).values())) >= 1
    print(json.dumps(rep, indent=2))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
