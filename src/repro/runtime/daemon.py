"""Per-node daemon: spawn/monitor workers, relay faults, run Algorithm 2.

The daemon is the ORTE-daemon analogue: it spawns its children worker
processes, watches them with a waitpid loop (SIGCHLD semantics), relays
death notifications to the root, and on REINIT signals survivors with
SIGREINIT (SIGUSR1) and re-spawns the ranks assigned to it.

A KILL_NODE message (node-failure injection) SIGKILLs every child and then
the daemon itself — from the root's perspective the control channel breaks,
exactly like a node loss.

Replica mode extends the daemon with shadow hosting (a SPAWN carrying
shadow=True starts warm-shadow workers, PROMOTE is relayed to the named
one) and root fail-over: when the control channel to the root breaks and a
warm-standby address was configured (--standby-port), the daemon re-homes —
re-registers with the standby and continues relaying — instead of tearing
the node down.
"""
from __future__ import annotations

import argparse
import os
import signal
import socket
import subprocess
import sys
import threading
import time

from repro.core.failure import ChildMonitor

from .transport import connect, listener, recv_msg, send_msg


class Daemon:
    def __init__(self, args):
        self.node = args.node
        self.args = args
        self.workers: dict[int, subprocess.Popen] = {}       # guarded-by: lock
        self.worker_socks: dict[int, object] = {}            # guarded-by: lock
        self.last_table: dict | None = None   # guarded-by: lock
        # guards the three shared maps above: mutated by per-connection
        # threads and the spawn fan-out, read by the run loop
        self.lock = threading.Lock()
        # serializes writes to worker sockets: the run loop broadcasts
        # while per-connection threads replay the cached table — two
        # concurrent sendall()s on one socket could interleave frames
        self.send_lock = threading.Lock()
        # armed by a node-hang injection: the daemon answers nothing
        # (worker relays, root messages, ring pings) while every channel
        # stays open — only daemon-level observation can see it
        self._silent = threading.Event()
        # daemon-ring observation (node-level heartbeat): node -> wport
        # of every live daemon, from the root's DAEMON_TABLE broadcasts
        self.daemon_table: dict[str, int] = {}

        self.monitor = ChildMonitor(self._on_child_death)
        self.monitor.start()

        # listener for workers (and for neighbour daemons' ring pings)
        self.wsock = listener()
        self.wport = self.wsock.getsockname()[1]
        threading.Thread(target=self._worker_accept_loop,
                         daemon=True).start()

        # control channel to root
        self.root_sock = connect("127.0.0.1", args.root_port)
        self.root_send_lock = threading.Lock()
        # warm-standby root (replica mode): where to re-home if the
        # primary's channel breaks. One re-home only — if the standby
        # dies too, the node goes down like any root loss.
        self.standby_port = int(getattr(args, "standby_port", 0) or 0)
        self._rehome_lock = threading.Lock()
        self._rehomed = False
        self._send_root({"type": "REGISTER_DAEMON", "node": self.node,
                         "pid": os.getpid(), "port": self.wport})

        # neighbour-heartbeat ring over *daemons*: observe the successor
        # daemon's listener every period; `timeout` of consecutive
        # silence reports SUSPECT_NODE to the root — a hung daemon (node
        # loss) is detected even though its control channel stays open
        self.hb_period = getattr(args, "hb_period", 0.0)
        self.hb_timeout = getattr(args, "hb_timeout", 0.0)
        if self.hb_period > 0 and self.hb_timeout > 0:
            threading.Thread(target=self._hb_loop, daemon=True).start()

    def _send_root(self, msg: dict):
        # serializes run-loop relays against the heartbeat observer's
        # SUSPECT_NODE reports (two concurrent sendall()s interleave)
        with self.root_send_lock:
            sock = self.root_sock
            try:
                send_msg(sock, msg)
                return
            except OSError:
                if not self._rehome(sock):
                    raise
            send_msg(self.root_sock, msg)

    def _rehome(self, failed_sock) -> bool:
        """Swap the root channel over to the warm standby. Returns True
        when self.root_sock is usable again (either this call re-homed,
        or another thread already did and `failed_sock` was stale)."""
        if self.standby_port <= 0:
            return False
        with self._rehome_lock:
            if self.root_sock is not failed_sock:
                return True        # raced: someone re-homed already
            if self._rehomed:
                return False       # standby is gone too
            try:
                sock = connect("127.0.0.1", self.standby_port)
                send_msg(sock, {"type": "REGISTER_DAEMON",
                                "node": self.node, "pid": os.getpid(),
                                "port": self.wport, "rehome": True})
            except OSError:
                self._rehomed = True
                return False
            self.root_sock = sock
            self._rehomed = True
            return True

    # ------------------------------------------------------------ workers

    def spawn_worker(self, rank: int, *, restarted: bool, epoch: int,
                     shadow: bool = False):
        a = self.args
        cmd = [sys.executable, "-m", "repro.runtime.worker",
               "--rank", str(rank), "--world", str(a.world),
               "--daemon-port", str(self.wport),
               "--steps", str(a.steps), "--dim", str(a.dim),
               "--fail-step", str(a.fail_step),
               "--fail-rank", str(a.fail_rank),
               "--fail-kind", a.fail_kind,
               "--scenario", a.scenario,
               "--hb-period", str(getattr(a, "hb_period", 0.0)),
               "--hb-timeout", str(getattr(a, "hb_timeout", 0.0)),
               "--ckpt-dir", a.ckpt_dir,
               "--epoch", str(epoch)]
        if restarted:
            cmd.append("--restarted")
        if shadow:
            cmd.append("--shadow")
        env = dict(os.environ, PYTHONPATH=a.pythonpath)
        proc = subprocess.Popen(cmd, env=env)
        with self.lock:
            self.workers[rank] = proc
        self.monitor.watch(rank, proc.pid)

    def _on_child_death(self, rank: int, pid: int, status: int):
        # SIGCHLD: relay to root (paper: daemon notifies, root decides).
        # The pid lets the root drop stale reports — a death of an old
        # incarnation must not be mistaken for the current one's.
        if self._silent.is_set():
            return
        try:
            self._send_root({"type": "CHILD_DEAD", "rank": rank,
                             "pid": pid, "node": self.node,
                             "status": status})
        except OSError:
            pass

    def _hb_loop(self):
        """Daemon-ring observer: ping the successor daemon's listener
        every period; `timeout` seconds of consecutive silence raise a
        SUSPECT_NODE to the root. This is what catches a hung *daemon* —
        from outside, a panicked node: its control channel stays open
        but nothing (worker relays, CHILD_DEADs, ring ACKs) comes out."""
        missed = 0.0
        last_succ = None
        while True:
            time.sleep(self.hb_period)
            if self._silent.is_set():
                return
            table = dict(self.daemon_table)
            ring = sorted(table)
            if len(ring) < 2 or self.node not in ring:
                continue
            succ = ring[(ring.index(self.node) + 1) % len(ring)]
            if succ != last_succ:
                # ring moved (recovery, grow, spare admission): misses
                # accumulated against the old successor must not count
                # against the new one
                missed = 0.0
                last_succ = succ
            ok = False
            try:
                s = connect("127.0.0.1", table[succ],
                            timeout=self.hb_period)
                s.settimeout(max(self.hb_period, 0.05))
                send_msg(s, {"type": "DAEMON_HB_PING", "from": self.node})
                ok = recv_msg(s) is not None
                s.close()
            except OSError:
                ok = False
            if ok:
                missed = 0.0
                continue
            if succ not in self.daemon_table:
                missed = 0.0        # table moved: stale observation
                continue
            missed += self.hb_period
            if missed >= self.hb_timeout:
                try:
                    self._send_root({"type": "SUSPECT_NODE", "node": succ,
                                     "by": self.node})
                except OSError:
                    pass
                missed = 0.0

    def _worker_accept_loop(self):
        while True:
            try:
                conn, _ = self.wsock.accept()
            except OSError:
                return
            threading.Thread(target=self._worker_conn, args=(conn,),
                             daemon=True).start()

    def _worker_conn(self, conn):
        rank = None
        try:
            while True:
                msg = recv_msg(conn)
                if msg is None:
                    return
                if self._silent.is_set():
                    return          # hung daemon: answers nothing, to anyone
                t = msg["type"]
                if t == "DAEMON_HB_PING":
                    # a neighbour daemon's ring observation
                    send_msg(conn, {"type": "HB_ACK", "node": self.node})
                elif t == "HANG_NODE":
                    self._hang_node()
                elif t == "REGISTER_WORKER":
                    rank = msg["rank"]
                    with self.lock:
                        self.worker_socks[rank] = conn
                        table = self.last_table
                    self._send_root({**msg, "node": self.node})
                    # replay the newest rank table to the late joiner so a
                    # re-spawned rank starts its buddy pull immediately —
                    # overlapping the restore with the rest of the
                    # world's re-registration (survivor entries in the
                    # cached table stay valid; a stale entry for another
                    # re-spawned rank just refuses the connect and the
                    # puller falls back to its file checkpoint)
                    if table is not None:
                        try:
                            with self.send_lock:
                                send_msg(conn, table)
                        except OSError:
                            pass
                elif t == "KILL_NODE":
                    self._die_hard()
                elif t == "BREAK_CHANNEL":
                    # network-partition emulation: sever the root channel
                    # only. The root sees an EOF (node failure), and the
                    # shutdown wakes our own run loop blocked in recv —
                    # the partitioned node then fences itself (children
                    # first), exactly fail-stop semantics.
                    try:
                        self.root_sock.shutdown(socket.SHUT_RDWR)
                        self.root_sock.close()
                    except OSError:
                        pass
                else:      # BARRIER / DONE — relay up
                    self._send_root(msg)
        except OSError:
            return

    def _kill_children_silently(self):
        """SIGKILL every child with the monitor stopped first, so their
        deaths are never relayed — the way a dead or hung node looks."""
        self.monitor._stop.set()
        with self.lock:
            procs = list(self.workers.values())
        for p in procs:
            try:
                os.kill(p.pid, signal.SIGKILL)
            except ProcessLookupError:
                pass

    def _die_hard(self):
        """Node-failure emulation: children first, then ourselves — a
        real dead node sends nothing."""
        self._kill_children_silently()
        os.kill(os.getpid(), signal.SIGKILL)

    def _hang_node(self):
        """Node-hang emulation: the node panics — its processes stop
        responding but nothing exits, so every channel stays open and no
        SIGCHLD/EOF fires anywhere. Children are SIGKILLed silently and
        the daemon goes mute; only the daemon-ring heartbeat sees it."""
        self._kill_children_silently()
        self._silent.set()

    # --------------------------------------------------------------- root

    def _broadcast_workers(self, msg: dict):
        with self.lock:
            socks = dict(self.worker_socks)
        for rank, s in socks.items():
            try:
                with self.send_lock:
                    send_msg(s, msg)
            except OSError:
                pass

    def _spawn_many(self, ranks, *, restarted: bool, epoch: int,
                    shadow: bool = False):
        """fork+exec the ranks concurrently — the spawn fan-out inside a
        node happens in parallel, so a node-failure respawn costs one
        spawn latency, not len(ranks) of them."""
        if len(ranks) <= 1:
            for r in ranks:
                self.spawn_worker(r, restarted=restarted, epoch=epoch,
                                  shadow=shadow)
            return
        threads = [threading.Thread(target=self.spawn_worker, args=(r,),
                                    kwargs={"restarted": restarted,
                                            "epoch": epoch,
                                            "shadow": shadow})
                   for r in ranks]
        for th in threads:
            th.start()
        for th in threads:
            th.join()

    def run(self):
        while True:
            sock = self.root_sock
            try:
                msg = recv_msg(sock)
            except OSError:           # channel broken (possibly injected)
                msg = None
            if self._silent.is_set():
                threading.Event().wait()     # hung node: mute forever
            if msg is None:
                if self.root_sock is not sock:
                    continue          # relay thread already re-homed us
                if self._rehome(sock):
                    continue          # primary died: now homed on standby
                self._die_hard()      # root gone: tear everything down
            t = msg["type"]
            if t == "SPAWN":          # initial deployment or Algorithm 2
                self._spawn_many(msg["ranks"], restarted=msg["restarted"],
                                 epoch=msg["epoch"],
                                 shadow=msg.get("shadow", False))
            elif t in ("REINIT", "GROW"):
                # Algorithm 2: signal survivors, spawn assigned ranks.
                # GROW is the same daemon-side motion over an *expanding*
                # world: the rejoined daemon spawns the re-admitted ranks
                # (restarted=True: they restore from their last durable
                # checkpoints), survivors roll back to the pinned cut —
                # plus the membership relay so control loops adopt the
                # re-expanded world and mesh epoch
                mine = [r for d, r in msg["respawns"] if d == self.node]
                with self.lock:
                    pids = [p.pid for r, p in self.workers.items()
                            if r not in mine and p.poll() is None]
                for pid in pids:
                    try:
                        os.kill(pid, signal.SIGUSR1)
                    except ProcessLookupError:
                        pass
                for r in mine:
                    self.monitor.unwatch(r)
                if t == "GROW":
                    self._broadcast_workers(msg)
                self._spawn_many(mine, restarted=True, epoch=msg["epoch"])
                self._send_root({"type": "REINIT_DONE",
                                 "node": self.node,
                                 "epoch": msg["epoch"]})
            elif t == "SHRINK":
                # shrinking recovery: no spawns anywhere — signal every
                # live child to roll back, then relay the shrunk world so
                # their control loops pick up the new membership/epoch
                with self.lock:
                    pids = [p.pid for p in self.workers.values()
                            if p.poll() is None]
                for pid in pids:
                    try:
                        os.kill(pid, signal.SIGUSR1)
                    except ProcessLookupError:
                        pass
                self._broadcast_workers(msg)
            elif t == "PROMOTE":
                # replica failover: hand the promote order to the named
                # shadow only — it composes its warm frame and enters
                # the BSP loop at the resume step
                with self.lock:
                    s = self.worker_socks.get(msg["rank"])
                if s is not None:
                    try:
                        with self.send_lock:
                            send_msg(s, msg)
                    except OSError:
                        pass
            elif t == "KILL_RANK":
                # root-side stall watchdog: a silent (hung) child cannot
                # be detected by waitpid — the root orders the kill and
                # the ensuing SIGCHLD drives the normal failure path
                with self.lock:
                    p = self.workers.get(msg["rank"])
                if p is not None:
                    try:
                        os.kill(p.pid, signal.SIGKILL)
                    except ProcessLookupError:
                        pass
            elif t == "KILL_NODE":
                # root-ordered node drain (gray-failure mitigation): a
                # persistently degraded node is taken down whole — the
                # channel EOF then drives the normal node-failure path
                self._die_hard()
            elif t == "DAEMON_TABLE":
                # ring membership for the daemon-level heartbeat; not
                # relayed to workers (node-level concern only)
                self.daemon_table = dict(msg["table"])
            elif t in ("RANK_TABLE", "BARRIER_RELEASE", "JOIN_RELEASE",
                       "FENCE_RELEASE", "RESYNC", "SHUTDOWN"):
                if t == "RANK_TABLE":
                    with self.lock:
                        self.last_table = msg
                self._broadcast_workers(msg)
                if t == "SHUTDOWN":
                    # join on the children's exits (they os._exit on the
                    # relayed SHUTDOWN) rather than sleeping a fixed drain
                    with self.lock:
                        procs = list(self.workers.values())
                    for p in procs:
                        try:
                            p.wait(timeout=2)
                        except subprocess.TimeoutExpired:
                            p.terminate()
                            try:
                                p.wait(timeout=1)
                            except subprocess.TimeoutExpired:
                                p.kill()
                    os._exit(0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--node", required=True)
    ap.add_argument("--root-port", type=int, required=True)
    ap.add_argument("--world", type=int, required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--fail-step", type=int, default=-1)
    ap.add_argument("--fail-rank", type=int, default=-1)
    ap.add_argument("--fail-kind", default="process")
    ap.add_argument("--scenario", default="")
    ap.add_argument("--hb-period", type=float, default=0.0)
    ap.add_argument("--hb-timeout", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--pythonpath", default="")
    ap.add_argument("--standby-port", type=int, default=0)
    Daemon(ap.parse_args(argv)).run()


if __name__ == "__main__":
    main()
