"""Length-prefixed JSON-over-TCP messaging (the control-plane fabric).

Binary payloads (checkpoints) travel base64-encoded under "b64" keys —
adequate for the control plane; bulk data paths in the JAX substrate never
touch this fabric.
"""
from __future__ import annotations

import base64
import json
import socket
import struct
from typing import Any, Optional

_HDR = struct.Struct("!I")
MAX_MSG = 512 * 1024 * 1024


def send_msg(sock: socket.socket, msg: dict):
    data = json.dumps(msg, separators=(",", ":")).encode()
    sock.sendall(_HDR.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def recv_msg(sock: socket.socket) -> Optional[dict]:
    hdr = _recv_exact(sock, _HDR.size)
    if hdr is None:
        return None
    (n,) = _HDR.unpack(hdr)
    if n > MAX_MSG:
        raise IOError(f"message too large: {n}")
    data = _recv_exact(sock, n)
    if data is None:
        return None
    return json.loads(data)


def connect(host: str, port: int, timeout: float = 10.0) -> socket.socket:
    s = socket.create_connection((host, port), timeout=timeout)
    s.settimeout(None)
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return s


def listener(host: str = "127.0.0.1", port: int = 0) -> socket.socket:
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.bind((host, port))
    s.listen(128)
    return s


def pack_bytes(b: bytes) -> str:
    return base64.b64encode(b).decode()


def unpack_bytes(s: str) -> bytes:
    return base64.b64decode(s.encode())
