"""Length-prefixed JSON-over-TCP messaging (the control-plane fabric).

Wire format: an 8-byte header `!II` = (json_len, payload_len), then the
JSON document, then `payload_len` raw bytes. Control messages set
payload_len=0 and cost nothing extra; bulk data (checkpoint frames) rides
the payload channel untouched — no base64 inflation, no json escaping,
and sendall() works straight from a memoryview of the source buffer.
Receivers find the payload under msg["_payload"].

The base64 helpers are kept for small blobs embedded in control fields.

Lossy-link injection (`install_lossy`): the `lossy` gray-failure
mechanism degrades this layer in-process — every send_msg pays a fixed
delay, and a seeded fraction pays it twice (a modeled drop+retransmit;
the message itself is never lost, so the protocol stays exact while the
*timing* degrades). Seeded `random.Random` keeps runs reproducible.
"""
from __future__ import annotations

import base64
import json
import random
import socket
import struct
import time
from typing import Any, Optional

_HDR = struct.Struct("!II")
MAX_MSG = 512 * 1024 * 1024

# process-global lossy-link model, armed by install_lossy() in a worker
# whose scenario carries an active how="lossy" fault.
# [rng, delay, drop, sock-or-None]
_LOSSY: Optional[list] = None


def install_lossy(seed: int, delay_s: float, drop_frac: float = 0.2,
                  sock: Optional[socket.socket] = None):
    """Degrade subsequent send_msg calls in this process: +delay_s, and
    a seeded drop_frac of sends pay it doubled (drop + retransmit).
    With `sock` given only that channel degrades (one bad link, e.g.
    the victim's uplink to its daemon) — other fabrics stay healthy so
    the lateness is attributable to the victim alone."""
    global _LOSSY
    _LOSSY = [random.Random(seed), delay_s, drop_frac, sock]


def clear_lossy():
    global _LOSSY
    _LOSSY = None


def send_msg(sock: socket.socket, msg: dict,
             payload: bytes | bytearray | memoryview | None = None):
    if _LOSSY is not None:
        rng, delay_s, drop_frac, only = _LOSSY
        if only is None or sock is only:
            time.sleep(delay_s * (2.0 if rng.random() < drop_frac else 1.0))
    data = json.dumps(msg, separators=(",", ":")).encode()
    plen = 0 if payload is None else len(payload)
    sock.sendall(_HDR.pack(len(data), plen) + data)
    if plen:
        sock.sendall(payload)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def recv_msg(sock: socket.socket) -> Optional[dict]:
    hdr = _recv_exact(sock, _HDR.size)
    if hdr is None:
        return None
    n, plen = _HDR.unpack(hdr)
    if n > MAX_MSG or plen > MAX_MSG:
        raise IOError(f"message too large: {n}+{plen}")
    data = _recv_exact(sock, n)
    if data is None:
        return None
    msg = json.loads(data)
    if plen:
        payload = _recv_exact(sock, plen)
        if payload is None:
            return None
        msg["_payload"] = payload
    return msg


def connect(host: str, port: int, timeout: float = 10.0) -> socket.socket:
    s = socket.create_connection((host, port), timeout=timeout)
    s.settimeout(None)
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return s


def listener(host: str = "127.0.0.1", port: int = 0) -> socket.socket:
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.bind((host, port))
    s.listen(128)
    return s


def pack_bytes(b: bytes) -> str:
    return base64.b64encode(b).decode()


def unpack_bytes(s: str) -> bytes:
    return base64.b64decode(s.encode())
