"""Real-process control plane: root (HNP) → per-node daemons → workers.

This substrate runs the paper's deployment model (§3.2) with actual POSIX
processes on localhost: SIGKILL fault injection, SIGCHLD-equivalent child
monitoring, REINIT broadcast over TCP control channels, SIGUSR1 survivor
rollback, re-spawn, and an ORTE-style rejoin barrier. It exists to prove
the protocol outside simulation and to ground the simulator's constants.
"""
from .transport import send_msg, recv_msg, connect, listener
