"""Logical-axis → mesh-axis sharding rules.

Parameters are named by their pytree path (e.g. "layers/attn/wq"); each rule
maps a path *pattern* plus array rank to a tuple of logical axes, and a
preset maps logical axes onto physical mesh axes. This keeps the model code
free of mesh knowledge: the same pytree lowers under a 1-device CPU test, the
(16,16) pod mesh, or the (2,16,16) multi-pod mesh.

Logical axes used across the codebase:
  "batch"    — per-example axis (data parallel; "pod"+"data" on multi-pod)
  "embed"    — d_model / residual stream (FSDP axis: sharded over "data")
  "heads"    — attention heads / d_ff / d_inner (tensor parallel: "model")
  "kv_heads" — KV heads; sharded over "model" only when it divides evenly
  "expert"   — MoE expert axis (expert parallel: "model")
  "vocab"    — vocabulary (sharded over "model" for the big tables)
  "seq"      — sequence axis (sequence parallel, opt-in)
  None       — replicated
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping of logical axis names to physical mesh axes."""
    batch: Any = None
    embed: Any = None
    heads: Any = None
    kv_heads: Any = None
    expert: Any = None
    vocab: Any = None
    seq: Any = None
    kv_seq: Any = None     # decode KV-cache sequence axis (flash-decode)

    def physical(self, logical: Optional[str]):
        if logical is None:
            return None
        return getattr(self, logical)

    def spec(self, *logical_axes) -> P:
        return P(*(self.physical(a) for a in logical_axes))


# Presets keyed by mesh flavour. "model" carries TP + EP; "data" carries
# FSDP + DP; "pod" extends DP across pods.
PRESETS = {
    # single CPU device / smoke tests: everything replicated
    "single": ShardingRules(),
    # one pod: (data, model). kv_heads are REPLICATED over the model axis
    # (Megatron GQA convention): kv head counts (1/4/8) never divide a
    # 16-way TP axis, and replicating the small K/V lets the GQA head
    # expansion happen locally instead of as a per-chunk all-gather of the
    # repeated tensor (measured: 2×30 GB/step on qwen2-7b train_4k).
    "pod": ShardingRules(
        batch="data", embed="data", heads="model", kv_heads=None,
        expert="model", vocab="model", seq=None),
    # two pods: (pod, data, model); batch over both DP axes
    "multipod": ShardingRules(
        batch=("pod", "data"), embed="data", heads="model", kv_heads=None,
        expert="model", vocab="model", seq=None),
    # serving presets: weights are TP-sharded over "model" but REPLICATED
    # over the data axis (embed=None). There is no optimizer state to
    # justify FSDP at inference, and FSDP-sharded weights cost a full
    # weight all-gather per decoded token (measured: 424 GB/token on
    # yi-34b decode_32k under the train rules).
    # The decode KV cache is sequence-sharded over "model" (kv_seq):
    # kv-head counts rarely divide a 16-way TP axis, and flash-decode
    # (partial softmax per shard + tiny all-reduce of the normalizers)
    # shards the 1 TB 32k-cache 256-way instead of 16-way.
    "pod_serve": ShardingRules(
        batch="data", embed=None, heads="model", kv_heads=None,
        expert="model", vocab="model", seq=None, kv_seq="model"),
    "multipod_serve": ShardingRules(
        batch=("pod", "data"), embed=None, heads="model",
        kv_heads=None, expert="model", vocab="model", seq=None,
        kv_seq="model"),
}


# ------------------------------------------------------------- param rules
#
# (path-regex, logical axes per dim). The FIRST match wins. Patterns match
# the "/"-joined pytree path *suffix*. A leading "L/" dim is added
# automatically for stacked-layer params (rank == len(axes) + 1).

PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings / unembedding: vocab × embed
    (r"embedding/table$",        ("vocab", "embed")),
    # attention projections
    (r"attn/wq$|cross/wq$",      ("embed", "heads")),
    (r"attn/wk$|cross/wk$",      ("embed", "kv_heads")),
    (r"attn/wv$|cross/wv$",      ("embed", "kv_heads")),
    (r"attn/wo$|cross/wo$",      ("heads", "embed")),
    (r"attn/b[qkv]$|cross/b[qkv]$", ("heads",)),
    (r"(q|k)_norm/scale$",       (None,)),
    # dense mlp
    (r"mlp/wi_(gate|up)$",       ("embed", "heads")),
    (r"mlp/wo$",                 ("heads", "embed")),
    # MoE: expert-sharded tables; router replicated on its output axis
    (r"moe/router$",             ("embed", None)),
    (r"moe/wi_(gate|up)$",       ("expert", "embed", None)),
    (r"moe/wo$",                 ("expert", None, "embed")),
    # mamba (projections are split per output — see mamba.py)
    (r"mamba/in_(x|z)$",         ("embed", "heads")),
    (r"mamba/in_dt$",            ("embed", "heads")),
    (r"mamba/in_bc$",            ("embed", None)),
    (r"mamba/out_proj$",         ("heads", "embed")),
    (r"mamba/x_proj$",           ("heads", None)),
    (r"mamba/dt_proj$",          (None, "heads")),
    (r"mamba/(conv_w|conv_b|conv_bc_w|conv_bc_b|dt_bias|A_log|D)$", None),
    (r"mamba/norm/scale$",       (None,)),
    # norms and any other small vectors: replicated
    (r"(ln\d?|ln_x|norm)/scale$", (None,)),
    (r"frontend_proj/w$",        ("embed", "heads")),
    (r"frontend_proj/b$",        ("heads",)),
]


def _match_rule(path: str, rank: int):
    for pat, axes in PARAM_RULES:
        if re.search(pat, path):
            if axes is None:
                return P()
            if len(axes) == rank:
                return tuple(axes)
            if len(axes) + 1 == rank:          # stacked-layer leading dim(s)
                return (None,) + tuple(axes)
            if len(axes) + 2 == rank:          # hybrid grouped (G, K, ...)
                return (None, None) + tuple(axes)
    return None


def spec_for_path(path: str, rank: int, rules: ShardingRules) -> P:
    """PartitionSpec for a parameter leaf given its path and rank."""
    m = _match_rule(path, rank)
    if m is None or isinstance(m, P):
        return P()
    return rules.spec(*m)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_specs(params, rules: ShardingRules):
    """PartitionSpec pytree matching a parameter pytree."""
    def leaf_spec(path, leaf):
        rank = len(getattr(leaf, "shape", ()))
        return spec_for_path(_path_str(path), rank, rules)
    return jax.tree_util.tree_map_with_path(leaf_spec, params)
