from .rules import ShardingRules, PRESETS, spec_for_path, tree_specs
from .partition import (
    shard_constraint, constraint_scope, tree_shardings, state_shardings,
    batch_spec,
)
