"""Applying sharding rules: NamedShardings for states, constraint scope.

`constraint_scope(mesh, rules)` arms `shard_constraint` so model code can
annotate intermediates (e.g. the MoE dispatch tensor) with *logical* axes;
outside a scope the annotation is a no-op, which keeps single-device smoke
tests mesh-free.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .rules import ShardingRules, tree_specs

_CTX: contextvars.ContextVar = contextvars.ContextVar("shard_ctx", default=None)


@contextlib.contextmanager
def constraint_scope(mesh: Mesh, rules: ShardingRules):
    tok = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(tok)


def shard_constraint(x: jnp.ndarray, *logical_axes) -> jnp.ndarray:
    """with_sharding_constraint by logical axes; identity outside a scope."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.spec(*logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(mesh: Mesh, params, rules: ShardingRules):
    """NamedSharding pytree for a parameter pytree."""
    specs = tree_specs(params, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def _divisible(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim evenly."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def state_shardings(mesh: Mesh, state, rules: ShardingRules):
    """Shardings for a full train/serve state pytree.

    Falls back to dropping any axis that does not divide the dim — this is
    what keeps odd head counts (e.g. 56 heads on a 16-way model axis) legal:
    the rule is applied where it divides and dropped where it doesn't.
    """
    specs = tree_specs(state, rules)

    def fix(spec, leaf):
        shape = getattr(leaf, "shape", ())
        return _divisible(spec, shape, mesh)

    fixed = jax.tree.map(fix, specs, state,
                         is_leaf=lambda s: isinstance(s, P))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), fixed,
                        is_leaf=lambda s: isinstance(s, P))


def batch_spec(rules: ShardingRules, *, seq_axis: bool = False) -> P:
    """(B, S) token batches: batch over DP axes, optionally seq-parallel."""
    return P(rules.batch, rules.seq if seq_axis else None)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
