from .optimizer import AdamWConfig, adamw_init, adamw_update, lr_at
from .data import TokenPipeline
from .trainer import Trainer, TrainConfig
from .straggler import StragglerTracker
