"""Training driver package.

Lazy exports (PEP 562): `trainer`/`data`/`optimizer` pull jax at import
time, but light consumers — the process runtime's root imports only the
stdlib-only `straggler` module for gray-failure detection — must not pay
that. Submodules load on first attribute access; `from repro.train
import Trainer` and `from repro.train.straggler import ...` both keep
working, the latter without touching jax at all.
"""
import importlib

_EXPORTS = {
    "AdamWConfig": ".optimizer",
    "adamw_init": ".optimizer",
    "adamw_update": ".optimizer",
    "lr_at": ".optimizer",
    "TokenPipeline": ".data",
    "Trainer": ".trainer",
    "TrainConfig": ".trainer",
    "StragglerTracker": ".straggler",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(target, __name__), name)


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
