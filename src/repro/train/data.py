"""Deterministic, step-indexed, resumable synthetic token pipeline.

batch(step) is a pure function of (seed, step) — the pipeline cursor IS the
step counter, so checkpoint/restart resumes bit-identically with no
separate data-state to save. Tokens follow a Zipf-ish distribution with a
Markov drift so the LM loss actually decreases; labels are next-token.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        # Zipf-ish marginal via squared uniform → low ids frequent
        u = jax.random.uniform(k1, (self.global_batch, self.seq_len + 1))
        base = (u * u * (self.vocab_size - 1)).astype(jnp.int32)
        # short-range structure: every even position repeats its neighbour
        # shifted by +1 mod V, giving the model something learnable
        idx = jnp.arange(self.seq_len + 1)
        repeat = jnp.roll(base, 1, axis=1) + 1
        toks = jnp.where((idx % 2 == 0)[None, :], base,
                         repeat % self.vocab_size)
        drop = jax.random.bernoulli(k2, 0.1, toks.shape)
        toks = jnp.where(drop, base, toks)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_batch(self, step: int) -> dict:
        """NumPy twin for the process-runtime demo app (no jax on workers)."""
        rng = np.random.default_rng((self.seed << 20) ^ step)
        u = rng.random((self.global_batch, self.seq_len + 1))
        base = (u * u * (self.vocab_size - 1)).astype(np.int32)
        idx = np.arange(self.seq_len + 1)
        repeat = np.roll(base, 1, axis=1) + 1
        toks = np.where((idx % 2 == 0)[None, :], base,
                        repeat % self.vocab_size)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
