"""Straggler detection over step times — robust stats + per-rank streaks.

The paper's recovery model assumes fail-stop failures; production fleets
also see *gray* ones: slow nodes, lossy links — ranks that keep
answering but keep everyone waiting. The tracker keeps a robust running
estimate (median + MAD over a window of every observation) and flags
observations that exceed it; with `rank=` given, flags and
consecutive-flag streaks are attributed to that rank, and
`stragglers()`/`persistent()` answer the question mitigation acts on:
which ranks have been slow *persistently*, not just once. The root's
drain path and the trainer's ElasticManager re-host a persistent
straggler exactly like a failed rank — a deliberate reuse of the
Reinit++ shrink/grow machinery.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Callable, Deque, Optional


@dataclasses.dataclass
class StragglerTracker:
    """Robust straggler detector with optional per-rank attribution.

    Population model: every observation — whatever its rank — lands in
    one shared window, and the flagging baseline is the *population's*
    median + MAD. That is what keeps attribution honest: a persistently
    slow rank never normalises its own baseline (judged only against
    its own history it would stop looking slow after one window), and a
    healthy rank is never blamed for the population-wide noise floor.

    Usage:
      observe(step, seconds)          aggregate outlier detection (the
                                      trainer watching its own step dt)
      observe(step, seconds, rank=r)  per-rank attribution: the flag and
                                      the consecutive-flag streak are
                                      recorded against r (the root
                                      watching per-rank barrier lateness)

    Flag rule — all three must hold, and never before `min_samples`
    observations exist:
      seconds > median + threshold_mads * MAD   robust outlier
      seconds > 1.5 * median                    relative floor: a
                                                flat-line window's
                                                near-zero MAD must not
                                                flag noise
      seconds >= min_flag_s                     absolute floor: sub-
                                                resolution jitter is
                                                never a straggler

    `persistent(rank, persist)` / `stragglers(persist)` report ranks
    flagged on `persist` *consecutive* observations — one slow step is
    noise, a streak is a gray failure. `reset_streaks()` belongs at
    recovery boundaries: a re-formed world starts with a clean slate.
    """
    window: int = 50
    threshold_mads: float = 6.0
    min_samples: int = 10
    min_flag_s: float = 0.0
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def __post_init__(self):
        self._times: Deque[float] = collections.deque(maxlen=self.window)
        self.flagged: list[tuple[int, float]] = []
        self.flagged_by_rank: dict[int, list[tuple[int, float]]] = {}
        self._streak: dict[int, int] = {}

    def observe(self, step: int, seconds: float,
                rank: Optional[int] = None) -> bool:
        """Record one observation; returns True when it flags."""
        flagged = False
        if len(self._times) >= self.min_samples:
            med = statistics.median(self._times)
            mad = statistics.median(
                abs(t - med) for t in self._times) or 1e-9
            if (seconds > med + self.threshold_mads * mad
                    and seconds > 1.5 * med
                    and seconds >= self.min_flag_s):
                flagged = True
                self.flagged.append((step, seconds))
                if rank is not None:
                    self.flagged_by_rank.setdefault(rank, []).append(
                        (step, seconds))
                if self.on_straggler:
                    self.on_straggler(step, seconds, med)
        if rank is not None:
            self._streak[rank] = \
                (self._streak.get(rank, 0) + 1) if flagged else 0
        self._times.append(seconds)
        return flagged

    def persistent(self, rank: int, persist: int = 2) -> bool:
        """Has `rank` flagged on its last `persist` observations?"""
        return self._streak.get(rank, 0) >= persist

    def stragglers(self, persist: int = 2) -> set:
        """Every rank currently on a flag streak of at least `persist`."""
        return {r for r, n in self._streak.items() if n >= persist}

    def reset_streaks(self):
        """Recovery boundary: the world re-formed (drain, shrink, grow)
        and in-flight streaks describe incarnations that no longer
        exist."""
        self._streak.clear()

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0
