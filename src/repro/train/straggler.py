"""Straggler detection over step times (flat-line/outlier protection).

The paper's recovery model assumes fail-stop failures; production fleets
also see *slow* nodes. The tracker keeps a robust running estimate
(median + MAD over a window) and flags steps (or ranks, when per-rank times
are reported) that exceed `threshold` MADs. Mitigation is a hook: the
trainer logs, and at scale the ElasticManager can re-host the slow shard
exactly like a failed one — a deliberate reuse of the Reinit++ path.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Callable, Deque, Optional


@dataclasses.dataclass
class StragglerTracker:
    window: int = 50
    threshold_mads: float = 6.0
    min_samples: int = 10
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def __post_init__(self):
        self._times: Deque[float] = collections.deque(maxlen=self.window)
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        flagged = False
        if len(self._times) >= self.min_samples:
            med = statistics.median(self._times)
            mad = statistics.median(abs(t - med) for t in self._times) or 1e-9
            if seconds > med + self.threshold_mads * mad and seconds > 1.5 * med:
                flagged = True
                self.flagged.append((step, seconds))
                if self.on_straggler:
                    self.on_straggler(step, seconds, med)
        self._times.append(seconds)
        return flagged

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0
