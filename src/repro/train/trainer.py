"""Fault-tolerant training driver — the paper's Fig. 2 made executable.

The driver wraps its main loop in `reinit_main` (the MPI_Reinit analogue).
A deterministic FaultInjector kills a random rank (or node) at a random
step; the configured RecoveryStrategy then *actually performs* its recovery
actions on the training state:

  CR        drop everything (state, compiled-step caches), re-"deploy" and
            reload the latest FILE checkpoint.
  Reinit++  survivors keep device state and compiled steps; the lost
            shard's state is restored from the buddy MEMORY checkpoint
            (process failure) or the file checkpoint (node failure);
            Algorithms 1/2 re-form the cluster view.
  ULFM      like Reinit++ for state, but pays revoke/shrink/agree all-rank
            agreement rounds during recovery and a heartbeat tax on every
            fault-free step.

Because the data pipeline is step-indexed and checkpoints are taken every
policy-interval, a failed-and-recovered run converges to the bit-identical
state of an uninterrupted run — the integration tests assert exactly that.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import FileCheckpointer, buddy_exchange, \
    restore_from_buddy
from repro.checkpoint.policy import CheckpointPolicy
from repro.core import (ClusterView, ElasticManager, FailureEvent,
                        FailureType, FaultInjector, MeshEpoch, RankState,
                        RecoveryReport, ROLLBACK, RollbackSignal,
                        apply_recovery, get_strategy, reinit_main,
                        root_handle_failure, root_handle_failure_shrink)
from repro.models.model import Model
from repro.sharding.partition import constraint_scope, state_shardings
from repro.sharding.rules import ShardingRules, PRESETS

from .data import TokenPipeline
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .straggler import StragglerTracker


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    total_steps: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 1
    ckpt_shards: int = 4
    # K>1: full file snapshot every K-th save, dirty-tile deltas between
    ckpt_delta_every: int = 0
    async_file_ckpt: bool = False
    strategy: str = "reinit"
    # logical deployment (the paper's root/daemon/rank tree)
    n_nodes: int = 2
    ranks_per_node: int = 4
    spare_nodes: int = 1
    seed: int = 0
    log_every: int = 0


@dataclasses.dataclass
class StepLog:
    step: int
    loss: float
    seconds: float
    heartbeat_overhead: float = 0.0


class Trainer:
    def __init__(self, model: Model, data: TokenPipeline,
                 opt_cfg: AdamWConfig, tc: TrainConfig, *,
                 mesh=None, rules: Optional[ShardingRules] = None,
                 injector: Optional[FaultInjector] = None):
        self.model = model
        self.data = data
        self.opt_cfg = opt_cfg
        self.tc = tc
        self.mesh = mesh
        self.rules = rules or PRESETS["single"]
        self.strategy = get_strategy(tc.strategy)
        self.injector = injector
        self.view = ClusterView.build(tc.n_nodes, tc.ranks_per_node,
                                      tc.spare_nodes)
        self.n_ranks = tc.n_nodes * tc.ranks_per_node
        # elastic strategy: spare-pool consultation + shrink decision;
        # one node = one data-parallel group, the mesh epoch keys the
        # compiled-step cache across shrinks
        self.elastic = ElasticManager(
            self.view, MeshEpoch(epoch=0, data_parallel=tc.n_nodes,
                                 model_parallel=tc.ranks_per_node)) \
            if self.strategy.key == "shrink" else None
        self.policy = CheckpointPolicy(every_steps=tc.ckpt_every,
                                       async_file=tc.async_file_ckpt)
        self.file_ckpt = FileCheckpointer(tc.ckpt_dir,
                                          n_shards=tc.ckpt_shards,
                                          delta_every=tc.ckpt_delta_every)
        # buddy memory checkpoint: (step, state_copy, buddy_copy)
        self.mem_ckpt: Optional[tuple[int, Any, Any]] = None
        self.state: Optional[dict] = None
        self.logs: list[StepLog] = []
        self.reports: list[RecoveryReport] = []
        self.straggler = StragglerTracker()
        self._build_step()

    # ----------------------------------------------------------- stepping

    def _build_step(self):
        model, opt_cfg = self.model, self.opt_cfg

        def train_step(state, batch):
            def loss_fn(params):
                return model.loss_fn(params, batch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"])
            new_p, new_opt, om = adamw_update(state["params"], grads,
                                              state["opt"], opt_cfg)
            new_state = {"params": new_p, "opt": new_opt,
                         "step": state["step"] + 1}
            return new_state, (loss, {**metrics, **om})

        if self.mesh is not None:
            self._train_step_fn = train_step      # sharded jit built lazily
            self._jitted = None
        else:
            self._jitted = jax.jit(train_step, donate_argnums=0)

    def _step(self, state, batch):
        if self.mesh is None:
            return self._jitted(state, batch)
        if self._jitted is None:
            st_sh = state_shardings(self.mesh, state, self.rules)
            self._jitted = jax.jit(self._train_step_fn,
                                   in_shardings=(st_sh, None),
                                   out_shardings=(st_sh, None),
                                   donate_argnums=0)
        with constraint_scope(self.mesh, self.rules):
            return self._jitted(state, batch)

    # -------------------------------------------------------------- state

    def init_state(self) -> dict:
        params = self.model.init(jax.random.PRNGKey(self.tc.seed))
        return {"params": params, "opt": adamw_init(params),
                "step": jnp.zeros((), jnp.int32)}

    def _save_ckpt(self, step: int):
        """Both faces of Table 2: buddy memory copy + file checkpoint.

        The file path is the fast-path engine: with async_file the save
        snapshots on device (digests included), kicks the D2H drain and
        returns — serialization and sharded IO overlap the next step."""
        state = self.state
        if self.mesh is not None and self.mesh.shape.get("data", 1) > 1:
            buddy = buddy_exchange(state, self.mesh, self.rules)
        else:
            buddy = jax.tree.map(lambda a: a + 0, state)   # device copy
        local = jax.tree.map(lambda a: a + 0, state)
        self.mem_ckpt = (step, local, buddy)
        self.file_ckpt.save(step, state, async_=self.policy.async_file)

    # ----------------------------------------------------------- recovery

    def _handle_failure(self, failure: FailureEvent) -> RecoveryReport:
        rep = RecoveryReport(strategy=self.strategy.name, failure=failure)
        if self.elastic is not None \
                and self.elastic.decide(failure) == "shrink":
            return self._handle_failure_shrink(rep, failure)

        # --- detection (child monitor / channel break at the root)
        t0 = time.monotonic()
        cmd = root_handle_failure(self.view, failure)
        states = apply_recovery(self.view, cmd)
        assert len(states) == self.n_ranks      # non-shrinking invariant
        if self.elastic is not None:
            self.elastic.nonshrink_plan(failure)     # mesh bookkeeping
        rep.detect_s = time.monotonic() - t0

        # --- MPI recovery: what each strategy actually does
        t0 = time.monotonic()
        ckpt_kind = self.strategy.checkpoint_kind(failure.kind)
        if self.strategy.redeploys:
            # CR: teardown — lose device state AND compiled artifacts
            self.state = None
            self.mem_ckpt = None
            self._jitted = None
            self._build_step()
            jax.clear_caches()
        else:
            if self.strategy.allrank_collectives:
                # ULFM: revoke/shrink/agree rounds across all ranks
                x = jnp.ones((self.n_ranks,), jnp.float32)
                for _ in range(self.strategy.allrank_collectives):
                    x = jax.jit(lambda v: v / jnp.sum(v))(x)
                x.block_until_ready()
            if failure.kind is FailureType.NODE:
                # node loss invalidates buddy copies of that node's shards
                self.mem_ckpt = None
        rep.mpi_recovery_s = time.monotonic() - t0

        # --- application recovery: reload the appropriate checkpoint
        t0 = time.monotonic()
        if ckpt_kind == "memory" and self.mem_ckpt is not None:
            step, local, buddy = self.mem_ckpt
            if self.mesh is not None and self.mesh.shape.get("data", 1) > 1:
                restored = restore_from_buddy(buddy, self.mesh, self.rules)
            else:
                restored = buddy
            # survivors keep `local`; the failed shard comes from `restored`
            # (same global value — asserted in tests via digest equality)
            self.state = jax.tree.map(lambda a: a + 0, restored)
            rollback_step = step
        else:
            self.file_ckpt.wait()
            step, state = self.file_ckpt.load_latest()
            if step is None:
                self.state = self.init_state()
                rollback_step = 0
            else:
                self.state = jax.tree.map(jnp.asarray, state)
                rollback_step = step
        rep.ckpt_read_s = time.monotonic() - t0
        rep.rollback_step = rollback_step
        self.reports.append(rep)
        return rep

    def _handle_failure_shrink(self, rep: RecoveryReport,
                               failure: FailureEvent) -> RecoveryReport:
        """Elastic shrinking recovery in the in-process SPMD driver: the
        spare pool is exhausted by a node loss, so the data axis contracts
        instead of re-hosting. Survivors keep process + device state; the
        mesh epoch bump invalidates the compiled step (its logical world
        changed), and the batch re-balances over the survivors — the
        step-indexed TokenPipeline keeps the *global* batch, so the run
        stays on the same data trajectory through the shrink."""
        t0 = time.monotonic()
        cmd = root_handle_failure_shrink(self.view, failure)
        self.elastic.shrink_plan(failure)
        self.n_ranks = len(cmd.world)
        rep.detect_s = time.monotonic() - t0

        t0 = time.monotonic()
        self._build_step()           # mesh epoch bumped: re-lower the step
        self.mem_ckpt = None         # the lost node took its buddy-held
                                     # copies with it (decide() only
                                     # shrinks on node failures)
        rep.mpi_recovery_s = time.monotonic() - t0

        # survivors roll back to their newest durable state; with the
        # buddy copies gone that is the file checkpoint at the cut
        t0 = time.monotonic()
        self.file_ckpt.wait()
        step, state = self.file_ckpt.load_latest()
        if step is None:
            self.state = self.init_state()
            rollback_step = 0
        else:
            self.state = jax.tree.map(jnp.asarray, state)
            rollback_step = step
        rep.ckpt_read_s = time.monotonic() - t0
        rep.rollback_step = rollback_step
        rep.world_after = self.n_ranks
        self.reports.append(rep)
        return rep

    # ---------------------------------------------------------------- run

    def _resilient_body(self, rank_state: RankState) -> int:
        """The user-supplied restart-point function of MPI_Reinit."""
        tc = self.tc
        if rank_state is RankState.NEW and self.state is None:
            # fresh start — or resume from disk if a checkpoint exists
            step, state = self.file_ckpt.load_latest()
            self.state = self.init_state() if step is None \
                else jax.tree.map(jnp.asarray, state)
        assert self.state is not None
        hb = self.strategy.fault_free_overhead(self.n_ranks)

        step = int(self.state["step"])
        while step < tc.total_steps:
            ROLLBACK.check()                      # safe-point (paper §3.2)
            failure = self.injector.check(step, self.view) \
                if self.injector else None
            if failure is not None:
                self._handle_failure(failure)
                raise RollbackSignal(self.view.epoch)

            t0 = time.monotonic()
            batch = self.data.batch(step)
            self.state, (loss, _) = self._step(self.state, batch)
            jax.block_until_ready(self.state["params"])
            dt = time.monotonic() - t0
            step = int(self.state["step"])
            self.straggler.observe(step, dt)
            self.logs.append(StepLog(step=step, loss=float(loss),
                                     seconds=dt, heartbeat_overhead=hb))
            if self.policy.should_checkpoint(step):
                self._save_ckpt(step)
            if tc.log_every and step % tc.log_every == 0:
                print(f"[{self.strategy.name}] step {step} "
                      f"loss {float(loss):.4f} ({dt*1e3:.1f} ms)")
        self.file_ckpt.wait()
        return step

    def run(self) -> dict:
        final_step = reinit_main(self._resilient_body)
        return {
            "final_step": final_step,
            "losses": [l.loss for l in self.logs],
            "reports": self.reports,
            "stragglers": self.straggler.flagged,
        }
