"""Fault-tolerant training driver — the paper's Fig. 2 made executable.

The driver wraps its main loop in `reinit_main` (the MPI_Reinit analogue).
A deterministic FaultInjector kills a random rank (or node) at a random
step; the configured RecoveryStrategy then *actually performs* its recovery
actions on the training state:

  CR        drop everything (state, compiled-step caches), re-"deploy" and
            reload the latest FILE checkpoint.
  Reinit++  survivors keep device state and compiled steps; the lost
            shard's state is restored from the buddy MEMORY checkpoint
            (process failure) or the file checkpoint (node failure);
            Algorithms 1/2 re-form the cluster view.
  ULFM      like Reinit++ for state, but pays revoke/shrink/agree all-rank
            agreement rounds during recovery and a heartbeat tax on every
            fault-free step.

Because the data pipeline is step-indexed and checkpoints are taken every
policy-interval, a failed-and-recovered run converges to the bit-identical
state of an uninterrupted run — the integration tests assert exactly that.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import FileCheckpointer, buddy_exchange, \
    restore_from_buddy
from repro.checkpoint.policy import CheckpointPolicy
from repro.core import (ClusterView, ElasticManager, FailureEvent,
                        FailureType, FaultInjector, MeshEpoch, RankState,
                        RecoveryReport, ROLLBACK, RollbackSignal,
                        apply_recovery, get_strategy, reinit_main,
                        root_handle_failure)
from repro.models.model import Model
from repro.scenarios.schema import GRAY_DRAIN_PERSIST, GRAY_HOWS, \
    gray_delay_s
from repro.sharding.partition import constraint_scope, state_shardings
from repro.sharding.rules import ShardingRules, PRESETS

from .data import TokenPipeline
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .straggler import StragglerTracker


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    total_steps: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 1
    ckpt_shards: int = 4
    # K>1: full file snapshot every K-th save, dirty-tile deltas between
    ckpt_delta_every: int = 0
    # N>0: background re-base rewrites a delta chain as a fresh base once
    # it reaches N links, bounding restore cost so delta_every can be
    # raised aggressively
    ckpt_rebase_after: int = 0
    # device dirty-tile gather for delta saves: auto/on/off
    ckpt_gather: str = "auto"
    async_file_ckpt: bool = False
    strategy: str = "reinit"
    # logical deployment (the paper's root/daemon/rank tree)
    n_nodes: int = 2
    ranks_per_node: int = 4
    spare_nodes: int = 1
    # elastic world floor, in whole node groups: shrinking recovery
    # refuses to contract below min_data_parallel * ranks_per_node ranks
    min_data_parallel: int = 1
    # gray-failure policy: off tolerates a degraded rank (the run slows,
    # nothing else changes); on drains a persistent straggler through
    # the shrink path and re-admits it at the repair's grow-back
    mitigate: bool = False
    seed: int = 0
    log_every: int = 0


@dataclasses.dataclass
class StepLog:
    step: int
    loss: float
    seconds: float
    heartbeat_overhead: float = 0.0


class Trainer:
    def __init__(self, model: Model, data: TokenPipeline,
                 opt_cfg: AdamWConfig, tc: TrainConfig, *,
                 mesh=None, rules: Optional[ShardingRules] = None,
                 injector: Optional[FaultInjector] = None):
        self.model = model
        self.data = data
        self.opt_cfg = opt_cfg
        self.tc = tc
        self.mesh = mesh
        self.rules = rules or PRESETS["single"]
        self.strategy = get_strategy(tc.strategy)
        self.injector = injector
        self.view = ClusterView.build(tc.n_nodes, tc.ranks_per_node,
                                      tc.spare_nodes)
        self.n_ranks = tc.n_nodes * tc.ranks_per_node
        # elastic strategy: the membership machine owns the spare pool,
        # the shrink/grow decisions and the dropped-rank ledger; one node
        # = one data-parallel group, the mesh epoch keys the
        # compiled-step cache across shrinks and grow-backs
        self.elastic = ElasticManager(
            self.view, MeshEpoch(epoch=0, data_parallel=tc.n_nodes,
                                 model_parallel=tc.ranks_per_node),
            min_data_parallel=tc.min_data_parallel) \
            if self.strategy.key == "shrink" else None
        self.policy = CheckpointPolicy(every_steps=tc.ckpt_every,
                                       async_file=tc.async_file_ckpt)
        self.file_ckpt = FileCheckpointer(
            tc.ckpt_dir, n_shards=tc.ckpt_shards,
            delta_every=tc.ckpt_delta_every, gather=tc.ckpt_gather,
            rebase_after=tc.ckpt_rebase_after)
        # buddy memory checkpoint: (step, state_copy, buddy_copy)
        self.mem_ckpt: Optional[tuple[int, Any, Any]] = None
        # replica strategy: the victim's warm shadow — a device copy of
        # the state mirrored after *every* step (the replication stream),
        # hosted off-node by construction, so recovery is promote-and-
        # continue with zero rollback
        self.shadow_ckpt: Optional[tuple[int, Any]] = None
        self.state: Optional[dict] = None
        self.logs: list[StepLog] = []
        self.reports: list[RecoveryReport] = []
        self.straggler = StragglerTracker()
        # gray-failure plan from the injector's scenario (if any): the
        # (index, fault) pairs whose victims get synthesized per-rank
        # delays, and the set already cured by a drain. A gray plan
        # re-tunes the tracker: few samples suffice, and the absolute
        # floor at half the smallest injected delay keeps jitter out.
        self._gray: list = []
        self._gray_mitigated: set[int] = set()
        sc = getattr(injector, "scenario", None)
        if sc is not None:
            self._gray = [(i, f) for i, f in enumerate(sc.faults)
                          if f.how in GRAY_HOWS]
        if self._gray:
            self.straggler = StragglerTracker(
                window=32, threshold_mads=4.0, min_samples=2,
                min_flag_s=0.5 * min(gray_delay_s(f)
                                     for _, f in self._gray))
        self._build_step()

    # ----------------------------------------------------------- stepping

    def _build_step(self):
        model, opt_cfg = self.model, self.opt_cfg

        def train_step(state, batch):
            def loss_fn(params):
                return model.loss_fn(params, batch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"])
            new_p, new_opt, om = adamw_update(state["params"], grads,
                                              state["opt"], opt_cfg)
            new_state = {"params": new_p, "opt": new_opt,
                         "step": state["step"] + 1}
            return new_state, (loss, {**metrics, **om})

        if self.mesh is not None:
            self._train_step_fn = train_step      # sharded jit built lazily
            self._jitted = None
        else:
            self._jitted = jax.jit(train_step, donate_argnums=0)

    def _step(self, state, batch):
        if self.mesh is None:
            return self._jitted(state, batch)
        if self._jitted is None:
            st_sh = state_shardings(self.mesh, state, self.rules)
            self._jitted = jax.jit(self._train_step_fn,
                                   in_shardings=(st_sh, None),
                                   out_shardings=(st_sh, None),
                                   donate_argnums=0)
        with constraint_scope(self.mesh, self.rules):
            return self._jitted(state, batch)

    # -------------------------------------------------------------- state

    def init_state(self) -> dict:
        params = self.model.init(jax.random.PRNGKey(self.tc.seed))
        return {"params": params, "opt": adamw_init(params),
                "step": jnp.zeros((), jnp.int32)}

    def _injected_at(self, point: str, step: Optional[int] = None):
        """Scenario fault due at a named interruption point — how the
        in-process driver reaches the checkpoint-phase and cascade
        injection points the real runtime fires through
        repro.scenarios.hooks. A fault whose victim rank is currently
        out of the world is deferred, not claimed: its next incarnation
        first runs at the grow that re-admits it, whose own cascade
        pass fires it (mirrors the sim's deferred cascades)."""
        inj = self.injector
        if inj is None or not hasattr(inj, "check_point"):
            return None
        live = set(self.view.ranks())
        return inj.check_point(
            point, step=step, view=self.view,
            eligible=lambda f: f.target != "rank" or f.rank in live)

    def _save_ckpt(self, step: int):
        """Both faces of Table 2: buddy memory copy + file checkpoint.

        The file path is the fast-path engine: with async_file the save
        snapshots on device (digests included), kicks the D2H drain and
        returns — serialization and sharded IO overlap the next step.

        Mirrors the real worker's commit order (file first, then the
        buddy push) so the checkpoint-phase interruption points carry
        the same meaning: a mid-write death leaves both tiers at step-1;
        a pre-push death leaves the file one step ahead of the buddy
        copy, and the merged restore must still reach `step`."""
        failure = self._injected_at("worker.ckpt.mid_write", step)
        if failure is not None:
            # dies with the shard bytes un-renamed: nothing durable at
            # `step` anywhere — recovery resumes from step-1. Unfenced
            # checkpoint-phase deaths have no stalled kill barrier to
            # promote against, so replica falls back (shadow goes cold)
            self.shadow_ckpt = None
            self._handle_failure(failure)
            raise RollbackSignal(self.view.epoch)
        state = self.state
        if self.mesh is not None and self.mesh.shape.get("data", 1) > 1:
            buddy = buddy_exchange(state, self.mesh, self.rules)
        else:
            buddy = jax.tree.map(lambda a: a + 0, state)   # device copy
        local = jax.tree.map(lambda a: a + 0, state)
        self.file_ckpt.save(step, state, async_=self.policy.async_file)
        failure = self._injected_at("worker.ckpt.pre_push", step)
        if failure is not None:
            # ReStore's mid-replication failure: the file committed but
            # the buddy copy was never pushed — the memory tier stays at
            # step-1 and the merged restore takes the newer file. Same
            # unfenced-death fallback as mid_write for replica.
            self.shadow_ckpt = None
            self._handle_failure(failure)
            raise RollbackSignal(self.view.epoch)
        self.mem_ckpt = (step, local, buddy)

    # ----------------------------------------------------------- recovery

    def _handle_failure(self, failure: FailureEvent,
                        cascade: bool = False) -> RecoveryReport:
        rep = RecoveryReport(strategy=self.strategy.name, failure=failure)
        # cascades merge into the recovery in flight via respawn, never
        # shrink on their own (a second failure during recovery must not
        # drop a rank survivors are blocked waiting on) — same policy as
        # the sim and the real root's open-join-window classification
        if self.elastic is not None and not cascade \
                and self.elastic.decide(failure) == "shrink":
            return self._handle_failure_shrink(rep, failure)

        # --- detection (child monitor / channel break at the root)
        t0 = time.monotonic()
        cmd = root_handle_failure(self.view, failure)
        states = apply_recovery(self.view, cmd)
        assert len(states) == self.n_ranks      # non-shrinking invariant
        if self.elastic is not None:
            self.elastic.nonshrink_plan(failure)     # mesh bookkeeping
        rep.detect_s = time.monotonic() - t0

        # --- zero-rollback fast path (replica): the victim's warm shadow
        # holds the state at the failure step — promotion replaces the
        # heavyweight strategy recovery, and the run resumes exactly
        # where it stopped. A node loss does NOT invalidate the shadow
        # (shadows are hosted off the primary's node by construction); a
        # cold shadow (nothing mirrored yet, or consumed by the recovery
        # in flight) falls through to the ordinary path below.
        if self.strategy.replicates and self.shadow_ckpt is not None:
            t0 = time.monotonic()
            step, shadow = self.shadow_ckpt
            self.shadow_ckpt = None   # consumed: a cascade during this
                                      # recovery has no second standby
            if failure.kind is FailureType.NODE:
                self.mem_ckpt = None  # buddy copies died with the node
            rep.mpi_recovery_s = time.monotonic() - t0
            t0 = time.monotonic()
            self.state = jax.tree.map(lambda a: a + 0, shadow)
            rep.ckpt_read_s = time.monotonic() - t0
            rep.rollback_step = step
            self.reports.append(rep)
            self._fire_cascades()
            return rep

        # --- MPI recovery: what each strategy actually does
        t0 = time.monotonic()
        ckpt_kind = self.strategy.checkpoint_kind(failure.kind)
        if self.strategy.redeploys:
            # CR: teardown — lose device state AND compiled artifacts
            self.state = None
            self.mem_ckpt = None
            self._jitted = None
            self._build_step()
            jax.clear_caches()
        else:
            if self.strategy.allrank_collectives:
                # ULFM: revoke/shrink/agree rounds across all ranks
                x = jnp.ones((self.n_ranks,), jnp.float32)
                for _ in range(self.strategy.allrank_collectives):
                    x = jax.jit(lambda v: v / jnp.sum(v))(x)
                x.block_until_ready()
            if failure.kind is FailureType.NODE:
                # node loss invalidates buddy copies of that node's shards
                self.mem_ckpt = None
        rep.mpi_recovery_s = time.monotonic() - t0

        # --- application recovery: reload the appropriate checkpoint.
        # The memory tier is only taken when it is at least as new as the
        # file tier — a failure between the file commit and the buddy
        # push (worker.ckpt.pre_push) leaves the file one step ahead, and
        # the merged restore must reach it (the real runtime's merged
        # buddy+file restore maps, in-process)
        t0 = time.monotonic()
        use_memory = ckpt_kind == "memory" and self.mem_ckpt is not None
        if use_memory:
            self.file_ckpt.wait()
            fsteps = self.file_ckpt.steps()
            if fsteps and fsteps[-1] > self.mem_ckpt[0]:
                use_memory = False
        if use_memory:
            step, local, buddy = self.mem_ckpt
            if self.mesh is not None and self.mesh.shape.get("data", 1) > 1:
                restored = restore_from_buddy(buddy, self.mesh, self.rules)
            else:
                restored = buddy
            # survivors keep `local`; the failed shard comes from `restored`
            # (same global value — asserted in tests via digest equality)
            self.state = jax.tree.map(lambda a: a + 0, restored)
            rollback_step = step
        else:
            self.file_ckpt.wait()
            step, state = self.file_ckpt.load_latest()
            if step is None:
                self.state = self.init_state()
                rollback_step = 0
            else:
                self.state = jax.tree.map(jnp.asarray, state)
                rollback_step = step
        rep.ckpt_read_s = time.monotonic() - t0
        rep.rollback_step = rollback_step
        self.reports.append(rep)
        self._fire_cascades()
        return rep

    def _fire_cascades(self):
        """Cascade injection points (a second failure during the recovery
        just performed): a survivor right after rollback, a restoring
        rank right after gathering its frames, a kill mid-compose. Each
        fires at most once per scenario; the nested recovery re-restores
        the same state, so continuation stays bit-identical."""
        for point in ("worker.recovery.enter", "worker.recovery.pulled",
                      "worker.recovery.compose"):
            cascade = self._injected_at(point)
            if cascade is not None:
                self._handle_failure(cascade, cascade=True)
                return

    def _handle_failure_shrink(self, rep: RecoveryReport,
                               failure: FailureEvent) -> RecoveryReport:
        """Elastic shrinking recovery in the in-process SPMD driver: the
        spare pool is exhausted, so the data axis contracts instead of
        re-hosting — by a whole node group on a node loss, or by a single
        rank on a process loss (uneven groups). Survivors keep process +
        device state; the mesh epoch bump invalidates the compiled step
        (its logical world changed), and the batch re-balances over the
        survivors — the step-indexed TokenPipeline keeps the *global*
        batch, so the run stays on the same data trajectory through the
        shrink."""
        t0 = time.monotonic()
        cmd = self.elastic.shrink(failure)   # view+mesh+dropped ledger
        self.n_ranks = len(cmd.world)
        rep.detect_s = time.monotonic() - t0

        t0 = time.monotonic()
        self._build_step()           # mesh epoch bumped: re-lower the step
        if failure.kind is FailureType.NODE:
            self.mem_ckpt = None     # the lost node took its buddy-held
                                     # copies with it
        rep.mpi_recovery_s = time.monotonic() - t0

        # survivors roll back to their newest durable state: the buddy
        # memory copy when it survived (process shrink), else the file
        # checkpoint at the cut
        t0 = time.monotonic()
        if self.mem_ckpt is not None:
            step, local, _ = self.mem_ckpt
            self.state = jax.tree.map(lambda a: a + 0, local)
            rollback_step = step
        else:
            self.file_ckpt.wait()
            step, state = self.file_ckpt.load_latest()
            if step is None:
                self.state = self.init_state()
                rollback_step = 0
            else:
                self.state = jax.tree.map(jnp.asarray, state)
                rollback_step = step
        rep.ckpt_read_s = time.monotonic() - t0
        rep.rollback_step = rollback_step
        rep.world_after = self.n_ranks
        self.reports.append(rep)
        self._fire_cascades()
        return rep

    def _observe_gray(self, step: int, dt: float):
        """Per-rank gray-failure observation for the in-process driver.
        The SPMD emulation has one wall clock, so what the tracker sees
        is barrier LATENESS relative to the fastest member — healthy
        ranks observe 0.0, victims observe the injected deceleration
        delay. That is the same signal the real root reads off arrival
        spread, with the same tracker and thresholds, and it is immune
        to globally slow steps (the restore + recompile after a
        recovery inflates dt for everyone equally, which must never
        read as a straggler). With mitigate=on (and the
        elastic strategy, the only one that can re-host), a rank on a
        GRAY_DRAIN_PERSIST streak is drained: returns the FailureEvent
        that re-hosts it through the ordinary shrink path, and marks
        the fault cured — the drained rank's next incarnation (the
        grow-back) is healthy. Tolerate mode only records the flags."""
        if not self._gray:
            return None
        live = set(self.view.ranks())
        rpn = self.tc.ranks_per_node
        delays: dict[int, float] = {}
        for i, f in self._gray:
            # `step` is the post-increment count; the fault starts
            # degrading the iteration whose top is f.step
            if i in self._gray_mitigated or step <= f.step:
                continue
            if f.target == "node":
                node = f.rank // rpn
                victims = range(node * rpn, (node + 1) * rpn)
            else:
                victims = (f.rank,)
            for r in victims:
                delays[r] = delays.get(r, 0.0) + gray_delay_s(f)
        for r in sorted(live):
            self.straggler.observe(step, delays.get(r, 0.0), rank=r)
        if not (self.tc.mitigate and self.elastic is not None):
            return None
        flagged = self.straggler.stragglers(GRAY_DRAIN_PERSIST) & live
        if not flagged:
            return None
        self.straggler.reset_streaks()
        for i, f in self._gray:
            if i in self._gray_mitigated:
                continue
            if f.target == "node":
                node = f.rank // rpn
                group = set(range(node * rpn, (node + 1) * rpn)) & live
                if group and group <= flagged:
                    self._gray_mitigated.add(i)
                    return FailureEvent(kind=FailureType.NODE,
                                        node=f"node{node}", rank=f.rank,
                                        at_step=step)
            elif f.rank in flagged:
                self._gray_mitigated.add(i)
                return FailureEvent(kind=FailureType.PROCESS,
                                    rank=f.rank, at_step=step)
        return None

    def _handle_repair(self, repair) -> Optional[RecoveryReport]:
        """Grow-back in the in-process SPMD driver: a repaired node
        rejoins at a checkpoint boundary. The admission policy (the
        membership machine) re-admits the most recently dropped group —
        world re-expands, mesh epoch bumps, the step recompiles for the
        re-grown shape — or, with a full world, adds the node to the
        spare pool (no recovery, returns None)."""
        if self.elastic is None:
            return None              # non-elastic runs never shrank
        node = f"node{repair.rank // self.tc.ranks_per_node}"
        if node in self.view.children:
            return None              # node never left the world: no-op
        if self.elastic.admit(node) == "spare":
            self.elastic.grant_spare(node)
            return None
        rep = RecoveryReport(
            strategy=self.strategy.name,
            failure=FailureEvent(kind=FailureType.NODE, node=node,
                                 at_step=repair.step))
        t0 = time.monotonic()
        cmd = self.elastic.grow(node)
        self.n_ranks = len(cmd.world)
        rep.detect_s = time.monotonic() - t0

        t0 = time.monotonic()
        self._build_step()           # mesh epoch bumped: re-lower the
                                     # step for the re-expanded world
        rep.mpi_recovery_s = time.monotonic() - t0

        # the re-admitted ranks restore from the durable checkpoint at
        # the consistent cut (Table-2 "grow" scheme: file tier)
        t0 = time.monotonic()
        self.file_ckpt.wait()
        step, state = self.file_ckpt.load_latest()
        if step is not None:
            self.state = jax.tree.map(jnp.asarray, state)
            rep.rollback_step = step
        rep.ckpt_read_s = time.monotonic() - t0
        rep.world_after = self.n_ranks
        self.reports.append(rep)
        self._fire_cascades()
        return rep

    # ---------------------------------------------------------------- run

    def _resilient_body(self, rank_state: RankState) -> int:
        """The user-supplied restart-point function of MPI_Reinit."""
        tc = self.tc
        if rank_state is RankState.NEW and self.state is None:
            # fresh start — or resume from disk if a checkpoint exists
            step, state = self.file_ckpt.load_latest()
            self.state = self.init_state() if step is None \
                else jax.tree.map(jnp.asarray, state)
        assert self.state is not None
        hb = self.strategy.fault_free_overhead(self.n_ranks)

        step = int(self.state["step"])
        while step < tc.total_steps:
            ROLLBACK.check()                      # safe-point (paper §3.2)
            failure = self.injector.check(step, self.view) \
                if self.injector else None
            if failure is not None:
                self._handle_failure(failure)
                raise RollbackSignal(self.view.epoch)
            repair = self.injector.check_repair(step) \
                if self.injector is not None \
                and hasattr(self.injector, "check_repair") else None
            if repair is not None and self._handle_repair(repair):
                raise RollbackSignal(self.view.epoch)

            t0 = time.monotonic()
            batch = self.data.batch(step)
            self.state, (loss, _) = self._step(self.state, batch)
            jax.block_until_ready(self.state["params"])
            dt = time.monotonic() - t0
            step = int(self.state["step"])
            self.straggler.observe(step, dt)
            drain = self._observe_gray(step, dt)
            if drain is not None:
                # drain BEFORE this step's checkpoint commits: the last
                # durable cut is the completed boundary — the same place
                # the real root withholds the barrier release
                self._handle_failure(drain)
                raise RollbackSignal(self.view.epoch)
            if self.strategy.replicates:
                # replication stream: mirror every step's state to the
                # rank's off-node shadow (Table 2 replica rows) — this,
                # not the checkpoint cadence, is what makes the later
                # promote zero-rollback
                self.shadow_ckpt = (step, jax.tree.map(lambda a: a + 0,
                                                       self.state))
            self.logs.append(StepLog(step=step, loss=float(loss),
                                     seconds=dt, heartbeat_overhead=hb))
            if self.policy.should_checkpoint(step):
                self._save_ckpt(step)
            if tc.log_every and step % tc.log_every == 0:
                print(f"[{self.strategy.name}] step {step} "
                      f"loss {float(loss):.4f} ({dt*1e3:.1f} ms)")
        self.file_ckpt.wait()
        return step

    def run(self) -> dict:
        final_step = reinit_main(self._resilient_body)
        return {
            "final_step": final_step,
            "losses": [l.loss for l in self.logs],
            "reports": self.reports,
            "stragglers": self.straggler.flagged,
            "stragglers_by_rank": dict(self.straggler.flagged_by_rank),
        }
