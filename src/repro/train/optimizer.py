"""Hand-rolled AdamW with global-norm clipping and warmup+cosine schedule.

Pure functions over explicit pytrees — the optimizer state shards with the
same rules as the parameters (moments inherit each param's PartitionSpec),
which is what makes the buddy checkpoint of the full train state a single
collective-permute.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Warmup + cosine decay, traceable (step may be a tracer)."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** cf
    bc2 = 1 - cfg.b2 ** cf
    lr = lr_at(cfg, count)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m.astype(p.dtype), v.astype(p.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
