"""SeamlessM4T-medium — enc-dec; audio frontend stubbed as precomputed
frame embeddings via input_specs() [arXiv:2308.11596; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    n_enc_layers=12, enc_seq_len=1024, frontend="audio",
)
