"""Config registry: --arch <id> -> ModelConfig (+ reduced smoke variants)."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, ShapeConfig, SHAPES, shape_applicable

from .olmoe_1b_7b import CONFIG as olmoe_1b_7b
from .qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from .falcon_mamba_7b import CONFIG as falcon_mamba_7b
from .granite_20b import CONFIG as granite_20b
from .qwen3_32b import CONFIG as qwen3_32b
from .yi_34b import CONFIG as yi_34b
from .qwen2_7b import CONFIG as qwen2_7b
from .seamless_m4t_medium import CONFIG as seamless_m4t_medium
from .zamba2_7b import CONFIG as zamba2_7b
from .llava_next_34b import CONFIG as llava_next_34b
from .paper_demo import CONFIG as paper_demo

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        olmoe_1b_7b, qwen3_moe_30b_a3b, falcon_mamba_7b, granite_20b,
        qwen3_32b, yi_34b, qwen2_7b, seamless_m4t_medium, zamba2_7b,
        llava_next_34b, paper_demo,
    ]
}

ASSIGNED = [n for n in ARCHS if n != "paper-demo"]


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests: few layers, narrow
    width, small vocab/experts — exercises every code path of the family."""
    kw = dict(
        n_layers=max(2, (cfg.attn_every or 0) + 1) if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        ssm_chunk=16,
    )
    if cfg.is_moe:
        kw.update(n_experts=4, experts_per_token=2, d_ff=32)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=8, ssm_head_dim=16)
    if cfg.family == "hybrid":
        kw.update(attn_every=2, n_layers=5)      # 2 groups + tail of 1
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, enc_seq_len=16)
    if cfg.family == "vlm":
        kw.update(n_frontend_tokens=8)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)


__all__ = ["ARCHS", "ASSIGNED", "SHAPES", "get_config", "reduced",
           "ModelConfig", "ShapeConfig", "shape_applicable"]
