"""Zamba2-7B — Mamba2 backbone + weight-shared attention block applied
every 6 mamba layers [arXiv:2411.15242; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_version=2, ssm_expand=2, ssm_conv=4, ssm_head_dim=64,
    attn_every=6,
)
