"""Paper-demo config: ~100M-parameter dense LM used by the end-to-end
fault-tolerance examples/benchmarks (the HPC-proxy-app analogue)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-demo", family="dense",
    n_layers=8, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=32768, head_dim=64,
)
