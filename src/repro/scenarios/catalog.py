"""The scenario catalog: every failure shape we assert recovery against.

Each entry is one reproducible experiment (see schema.Scenario) that the
parametrized harness in tests/test_scenarios.py drives through BOTH
executors — the calibrated discrete-event simulator (all strategies,
16-1024 ranks) and the real root/daemon/worker process tree on this host
(reinit / cr). The breadth mirrors the related work: ReStore's
failures-during-recovery-and-replication, and Shrink-or-Substitute's
failure-mode × strategy matrix.

Tags:
  fast    the subset the default test run / CI `scenario_fast` job
          executes on the real runtime (the full matrix is `scenario_slow`)
  slow3   3-node topologies — opt-in in CI (ROADMAP: scale the real
          runtime past 2 nodes)
"""
from __future__ import annotations

from .schema import Fault, Repair, Scenario, ServeScenario, Topology

T22 = Topology(nodes=2, ranks_per_node=2, spares=1)      # world 4
T22S0 = Topology(nodes=2, ranks_per_node=2, spares=0)    # world 4, no pool
T32 = Topology(nodes=3, ranks_per_node=2, spares=1)      # world 6
T32S2 = Topology(nodes=3, ranks_per_node=2, spares=2)    # world 6, deep pool

CATALOG: tuple[Scenario, ...] = (
    # ------------------------------------------------ process failures
    Scenario(
        name="proc-sigkill-midstep",
        description="The paper's §4 baseline: SIGKILL one rank behind the "
                    "FENCE at mid-run.",
        topology=T22, faults=(Fault("rank", 1, 3),),
        strategies=("reinit", "cr", "ulfm")),
    Scenario(
        name="proc-sigkill-rank0",
        description="Victim is rank 0 — exercises the buddy-ring wrap "
                    "(rank 0 restores from rank 1, world-1 pushes to 0).",
        topology=T22, faults=(Fault("rank", 0, 2),),
        strategies=("reinit", "cr")),
    Scenario(
        name="proc-sigkill-early",
        description="Failure at the first fence-able step: only one "
                    "checkpoint exists anywhere.",
        topology=T22, faults=(Fault("rank", 3, 1),),
        strategies=("reinit", "cr", "ulfm")),
    Scenario(
        name="proc-sigkill-late",
        description="Failure at the second-to-last step: recovery, one "
                    "step, then straight into shutdown.",
        topology=T22, faults=(Fault("rank", 2, 4),),
        strategies=("reinit", "cr")),
    # --------------------------------------------------- node failures
    Scenario(
        name="node-sigkill",
        description="Whole-node loss (daemon + children): ranks re-hosted "
                    "on the least-loaded node, restore from file tier.",
        topology=T22, faults=(Fault("node", 1, 3),),
        strategies=("reinit", "cr", "ulfm")),
    Scenario(
        name="node-sigkill-late",
        description="Node loss on the other node, late in the run.",
        topology=T22, faults=(Fault("node", 3, 4),),
        strategies=("reinit", "cr")),
    # ------------------------------------- silent / partition failures
    Scenario(
        name="proc-hang",
        description="Rank goes silent (no SIGCHLD, channel intact): only "
                    "the root's stall watchdog can detect it, then kills "
                    "and recovers it like a process failure.",
        topology=T22, faults=(Fault("rank", 1, 3, how="hang"),),
        stall_timeout_s=6.0,
        strategies=("reinit", "cr", "ulfm")),
    Scenario(
        name="proc-hang-heartbeat",
        description="Rank goes silent with the stall watchdog DISARMED: "
                    "only the neighbour-heartbeat ring (each rank observes "
                    "its ring successor, SUSPECT to root on timeout) "
                    "detects it — hang cells measure detection latency "
                    "instead of charging the watchdog.",
        topology=T22, faults=(Fault("rank", 1, 3, how="hang"),),
        heartbeat_period_s=0.2, heartbeat_timeout_s=1.0,
        strategies=("reinit", "ulfm"), tags=("fast",)),
    Scenario(
        name="proc-channel-break",
        description="Rank's control channel to its daemon breaks; the "
                    "fail-stop rank fences itself and dies, detection via "
                    "the EOF/SIGCHLD path.",
        topology=T22, faults=(Fault("rank", 1, 3, how="channel_break"),),
        strategies=("reinit", "cr")),
    Scenario(
        name="node-channel-break",
        description="Daemon-root channel breaks (network partition): the "
                    "partitioned node self-fences, root sees a node loss "
                    "via channel EOF instead of silence.",
        topology=T22,
        faults=(Fault("node", 2, 3, how="channel_break"),),
        strategies=("reinit", "cr"), tags=("fast",)),
    # --------------------------------- failures inside the ckpt machinery
    Scenario(
        name="ckpt-midwrite-kill",
        description="SIGKILL between the tmp shard write and the atomic "
                    "rename: the in-flight checkpoint must be invisible "
                    "and the consensus lands one step back.",
        topology=T22,
        faults=(Fault("rank", 1, 3, point="worker.ckpt.mid_write"),),
        strategies=("reinit", "cr"), tags=("fast",)),
    Scenario(
        name="ckpt-prepush-kill",
        description="ReStore's mid-replication failure: the file commit "
                    "landed but the buddy copy was never pushed; the "
                    "merged buddy+file restore still reaches the step.",
        topology=T22,
        faults=(Fault("rank", 1, 3, point="worker.ckpt.pre_push"),),
        strategies=("reinit", "cr"), tags=("fast",)),
    # ------------------------------------ failures during recovery itself
    Scenario(
        name="cascade-respawn-dies",
        description="The re-spawned replacement dies again right after "
                    "pulling its frames — recovery of the recovery.",
        topology=T22,
        faults=(Fault("rank", 1, 3),
                Fault("rank", 1, None, point="worker.recovery.pulled")),
        strategies=("reinit",), tags=("fast",)),
    Scenario(
        name="cascade-survivor-dies",
        description="A survivor dies immediately after its SIGREINIT "
                    "rollback, while the first recovery is still in "
                    "flight — the recoveries must merge.",
        topology=T22,
        faults=(Fault("rank", 1, 3),
                Fault("rank", 2, None, point="worker.recovery.enter")),
        strategies=("reinit",)),
    Scenario(
        name="cascade-compose-kill",
        description="Kill mid delta-chain compose of the restore: the "
                    "next incarnation re-pulls and re-composes the same "
                    "frames.",
        topology=T22,
        faults=(Fault("rank", 1, 3),
                Fault("rank", 1, None, point="worker.recovery.compose")),
        strategies=("reinit",)),
    # ------------------------------------- elastic / shrinking recovery
    Scenario(
        name="double-node-loss",
        description="Two sequential whole-node losses absorbed by a "
                    "two-deep spare pool: Algorithm 1's least-loaded "
                    "choice re-hosts each onto a fresh spare and the "
                    "world never shrinks (the paper's §3.2 deployment "
                    "model at its provisioning limit).",
        topology=T32S2,
        faults=(Fault("node", 2, 2), Fault("node", 4, 4)),
        strategies=("reinit", "cr", "ulfm", "shrink"), tags=("fast",)),
    Scenario(
        name="spare-pool-exhaustion",
        description="Node losses outnumber the spare pool: the second "
                    "loss finds it empty. Elastic recovery shrinks the "
                    "world (survivors re-balance over a contracted data "
                    "axis, bumped mesh epoch); non-elastic strategies "
                    "over-subscribe a surviving host.",
        topology=T32,
        faults=(Fault("node", 2, 2), Fault("node", 4, 4)),
        strategies=("shrink", "reinit", "cr", "ulfm"),
        expect_bit_identical=False,      # a shrunk world sums fewer ranks
        tags=("fast",)),
    Scenario(
        name="proc-loss-shrink",
        description="Process-level shrink: a single-rank loss with the "
                    "spare pool empty drops that rank instead of "
                    "respawning — the surviving groups are uneven (one "
                    "node keeps 2 ranks, the victim's keeps 1) and the "
                    "world stays above the min_data_parallel floor. "
                    "Non-elastic strategies respawn in place.",
        topology=T22S0, faults=(Fault("rank", 1, 3),),
        strategies=("shrink", "reinit", "cr", "ulfm"),
        expect_bit_identical=False,      # a shrunk world sums fewer ranks
        tags=("fast",)),
    Scenario(
        name="shrink-then-growback",
        description="The full elastic lifecycle: a node loss with no "
                    "spares shrinks the world 4->2 (survivors pin the "
                    "cut); the repaired node's daemon re-registers at a "
                    "later checkpoint boundary (REJOIN) and the root "
                    "grows the world back 2->4 (GROW broadcast, bumped "
                    "mesh epoch) — the consensus lands on the pinned "
                    "pre-shrink cut and the re-expanded run finishes "
                    "bit-identically to fault-free.",
        topology=T22S0, steps=7,
        faults=(Fault("node", 2, 2),),
        repairs=(Repair(2, 4),),
        strategies=("shrink", "reinit", "cr", "ulfm"),
        tags=("fast",)),
    Scenario(
        name="growback-mid-cascade",
        description="A cascading failure during the grow-back itself: "
                    "one of the re-admitted ranks dies again right after "
                    "pulling its frames — the cascade merges into the "
                    "in-flight grow recovery and the world still ends "
                    "re-expanded and bit-identical.",
        topology=T22S0, steps=7,
        faults=(Fault("node", 2, 2),
                Fault("rank", 2, None, point="worker.recovery.pulled")),
        repairs=(Repair(2, 4),),
        strategies=("shrink", "reinit"), tags=("fast",)),
    Scenario(
        name="shrink-then-growback-3node",
        description="3-node lifecycle: the first node loss is absorbed "
                    "by the spare, the second shrinks 6->4, then the "
                    "repaired node rejoins and the world grows back to "
                    "6 at a checkpoint boundary.",
        topology=T32, steps=9,
        faults=(Fault("node", 2, 2), Fault("node", 4, 4)),
        repairs=(Repair(4, 6),),
        strategies=("shrink", "reinit", "cr", "ulfm"),
        tags=("slow3",)),
    Scenario(
        name="node-hang-heartbeat",
        description="The whole node goes silent (hung daemon: children "
                    "muted, control channel open, nothing relayed): only "
                    "the daemon-level heartbeat ring can see it — the "
                    "observer daemon SUSPECT_NODEs its successor, the "
                    "root kills the hung daemon and the channel EOF "
                    "drives the ordinary node-failure path.",
        topology=T22, faults=(Fault("node", 2, 3, how="hang"),),
        heartbeat_period_s=0.25, heartbeat_timeout_s=1.0,
        strategies=("reinit", "ulfm"), tags=("fast",)),
    Scenario(
        name="shrink-after-cascade",
        description="The first node recovery suffers a cascading "
                    "replacement death (ReStore's failure-during-"
                    "recovery); a later node loss then exhausts the "
                    "pool and the elastic path shrinks instead of "
                    "aborting.",
        topology=T32,
        faults=(Fault("node", 2, 2),
                Fault("rank", 2, None, point="worker.recovery.pulled"),
                Fault("node", 4, 4)),
        strategies=("shrink",),
        expect_bit_identical=False),
    # --------------------------------------- replica (zero-rollback) cells
    Scenario(
        name="replica-promote",
        description="Zero-rollback failover: rank 1 dies behind the FENCE "
                    "at step 3; its warm shadow (fed the buddy delta "
                    "stream every step) is promoted in place, completes "
                    "the stalled barrier, and the run resumes AT step 3 "
                    "with no rollback, no respawn and no recomputed "
                    "steps — bit-identical to fault-free.",
        topology=T22, faults=(Fault("rank", 1, 3),),
        strategies=("replica", "reinit"), tags=("fast",)),
    Scenario(
        name="replica-shadow-loss",
        description="The shadow dies, not the rank: the application never "
                    "notices (no consensus entry), rank 1 silently loses "
                    "its zero-rollback cover, and its later failure "
                    "falls back to global-restart recovery.",
        topology=T22,
        faults=(Fault("shadow", 1, 2), Fault("rank", 1, 4)),
        strategies=("replica",), tags=("fast",)),
    Scenario(
        name="replica-promote-cascade",
        description="Failure during the promotion window: the shadow "
                    "dies right as it is being promoted — the root must "
                    "merge the loss into the in-flight recovery (fall "
                    "back to respawn), never deadlock or double-promote.",
        topology=T22,
        faults=(Fault("rank", 1, 3),
                Fault("rank", 1, None, point="worker.recovery.pulled")),
        strategies=("replica",), tags=("fast",)),
    Scenario(
        name="replica-root-loss-standby",
        description="Root loss under replica: the warm standby (mirroring "
                    "the rank/daemon/membership tables over the "
                    "replication channel) takes over, daemons re-home to "
                    "it, and the job finishes with NO external relaunch "
                    "— the last single point of failure removed.",
        topology=T22, faults=(Fault("root", step=3),),
        strategies=("replica",), tags=()),
    Scenario(
        name="replica-3node-cascade",
        description="3-node replica matrix: a promote at step 2, then a "
                    "second rank loss at step 4 on another node — two "
                    "independent zero-rollback failovers in one run.",
        topology=T32,
        faults=(Fault("rank", 1, 2), Fault("rank", 4, 4)),
        strategies=("replica", "reinit"), tags=("slow3",)),
    # --------------------------------------- gray (degraded) failures
    Scenario(
        name="slow-rank-tolerate",
        description="Gray baseline: rank 1 decelerates x6 from step 3 "
                    "(injected per-step delay) but nothing dies. With "
                    "mitigate=False the policy is to tolerate: no "
                    "recovery fires, the whole BSP job just runs at the "
                    "straggler's pace and finishes bit-identical to "
                    "fault-free.",
        topology=T22,
        faults=(Fault("rank", 1, 3, how="slow", factor=6.0),),
        strategies=("reinit", "shrink", "cr", "ulfm"),
        tags=("fast", "gray")),
    Scenario(
        name="slow-rank-drain",
        description="Mitigated straggler: the root's per-rank lateness "
                    "tracker flags rank 1's sustained x6 slowdown and "
                    "drains it once the lateness persists — an ordinary "
                    "process-level shrink at the withheld barrier's cut "
                    "(pool empty), survivors re-balance and resume "
                    "bit-identically from the drain cut.",
        topology=T22S0, steps=7,
        faults=(Fault("rank", 1, 3, how="slow", factor=6.0),),
        mitigate=True, strategies=("shrink",),
        expect_bit_identical=False,      # a shrunk world sums fewer ranks
        tags=("fast", "gray")),
    Scenario(
        name="slow-node-drain-growback",
        description="Sick-host lifecycle: every rank on node1 runs x6 "
                    "slow from step 3 (degradation is per-host); the "
                    "root drains the whole node through SHRINK, and the "
                    "repaired (healthy again) node REJOINs at step 6 — "
                    "the grow-back re-admits it and the re-expanded run "
                    "finishes bit-identical to fault-free.",
        topology=T22S0, steps=8,
        faults=(Fault("node", 2, 3, how="slow", factor=6.0),),
        repairs=(Repair(2, 6),),
        mitigate=True, strategies=("shrink",),
        tags=("fast", "gray")),
    Scenario(
        name="lossy-rank-tolerate",
        description="Degraded link, tolerated: rank 1's control-channel "
                    "sends pay a seeded delay/retransmit tax from step 3 "
                    "(the transport layer's lossy injection). Barriers "
                    "arrive late but complete; no recovery fires and the "
                    "run finishes bit-identical.",
        topology=T22,
        faults=(Fault("rank", 1, 3, how="lossy", factor=6.0),),
        strategies=("reinit", "shrink", "cr", "ulfm"),
        tags=("fast", "gray")),
    Scenario(
        name="lossy-rank-drain",
        description="Degraded link, drained: the same lossy injection "
                    "with mitigation on — transport lateness is "
                    "indistinguishable from compute lateness at the "
                    "barrier, so the same tracker flags it and the same "
                    "shrink path drains the rank at the withheld cut.",
        topology=T22S0, steps=7,
        faults=(Fault("rank", 1, 3, how="lossy", factor=6.0),),
        mitigate=True, strategies=("shrink",),
        expect_bit_identical=False,      # a shrunk world sums fewer ranks
        tags=("fast", "gray")),
    # ------------------------------------------------- flapping nodes
    Scenario(
        name="flap-node-twice",
        description="A flapping node: node1 dies at step 2, its repair "
                    "rejoins (GROW) at step 4, the same node dies AGAIN "
                    "at step 5 and rejoins at step 7 — two full "
                    "shrink->grow round-trips in one run, each landing "
                    "on its own pinned cut, finishing bit-identical "
                    "with the full world.",
        topology=T22S0, steps=9,
        faults=(Fault("node", 2, 2), Fault("node", 2, 5)),
        repairs=(Repair(2, 4), Repair(2, 7)),
        strategies=("shrink",), tags=("fast", "flap")),
    Scenario(
        name="flap-refail-in-rejoin",
        description="Fail during the open rejoin consensus: node1 dies "
                    "and is dropped; its repair rejoins, and one of the "
                    "re-admitted ranks dies again right after pulling "
                    "its frames — while the grow's JOIN window is still "
                    "open. The root must merge the death into the "
                    "in-flight grow recovery (respawn within the same "
                    "consensus), never deadlock the held barrier.",
        topology=T22S0, steps=7,
        faults=(Fault("node", 2, 2),
                Fault("rank", 3, None, point="worker.recovery.pulled")),
        repairs=(Repair(2, 4),),
        strategies=("shrink",), tags=("fast", "flap")),
    # -------------------------------------------------------- root loss
    Scenario(
        name="root-restart",
        description="The HNP itself dies (Reinit++'s single point of "
                    "failure): only external job restart recovers; the "
                    "resume step is timing-dependent but the state is "
                    "still bit-identical.",
        topology=T22, faults=(Fault("root", step=3),),
        strategies=("cr",)),
    # ---------------------------------------------- 3-node topologies
    Scenario(
        name="three-node-node-kill",
        description="Node loss in a 3-node/6-rank tree: re-host on the "
                    "least-loaded of two surviving nodes (+spare).",
        topology=T32, faults=(Fault("node", 2, 3),),
        strategies=("reinit", "cr"), tags=("slow3",)),
    Scenario(
        name="three-node-cascade",
        description="6-rank tree, replacement dies again mid-restore.",
        topology=T32,
        faults=(Fault("rank", 4, 3),
                Fault("rank", 4, None, point="worker.recovery.pulled")),
        strategies=("reinit",), tags=("slow3",)),
)

BY_NAME = {s.name: s for s in CATALOG}


def get_scenario(name: str) -> Scenario:
    try:
        return BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {sorted(BY_NAME)}") from None


def fault_free(topology: Topology, steps: int = 6, dim: int = 64
               ) -> Scenario:
    """The reference run every expect_bit_identical scenario is compared
    against — same topology/steps/dim, zero faults."""
    return Scenario(name=f"fault-free-{topology.nodes}x"
                         f"{topology.ranks_per_node}",
                    faults=(), topology=topology, steps=steps, dim=dim,
                    strategies=("reinit",))


# ------------------------------------------------------- serving catalog
#
# Serving cells kill a rank of a live ServeCluster (repro.serve.cluster)
# under sustained open-loop load and assert the serving invariants: zero
# requests dropped, zero duplicate/lost tokens, transcripts bit-identical
# to the fault-free run. They live in their own catalog — the training
# matrices in tests/test_scenarios.py parametrize over CATALOG and must
# not pick these up.

SERVE_CATALOG: tuple[ServeScenario, ...] = (
    ServeScenario(
        name="serve-rank-loss",
        description="The serving baseline: SIGKILL-equivalent loss of a "
                    "decoding rank mid-stream under open-loop load; the "
                    "respawned rank composes its buddy's held delta "
                    "frames, replays with emission suppressed, and every "
                    "client transcript finishes bit-identical with zero "
                    "re-delivered tokens.",
        strategy="reinit", fault_point="serve.decode.step",
        fault_round=4, fault_rank=1, tags=("fast",)),
    ServeScenario(
        name="serve-mid-prefill",
        description="Kill between a prompt batch's prefill compute and "
                    "its commit: the queued requests were never admitted, "
                    "so the snapshot replays them from the queue — only "
                    "computed work is lost, never a request.",
        strategy="reinit", fault_point="serve.prefill.mid",
        fault_round=4, fault_rank=1, tags=("fast",)),
    ServeScenario(
        name="serve-replica-promote",
        description="Zero-rollback serving failover: the buddy applies "
                    "every per-step frame into a warm standby snapshot; "
                    "promotion restores it immediately with nothing to "
                    "compose, so the first recovered token arrives a "
                    "fraction of reinit's gap after the kill.",
        strategy="replica", fault_point="serve.decode.step",
        fault_round=4, fault_rank=1, tags=("fast",)),
    ServeScenario(
        name="serve-rank-loss-wide",
        description="High-slot-count variant of serve-rank-loss: a wide "
                    "slot pool under heavier load (nightly; the fast job "
                    "runs the small cells).",
        strategy="reinit", fault_point="serve.decode.step",
        n_slots=16, rounds=10, per_round=3, fault_round=5, fault_rank=1,
        tags=("nightly",)),
)

SERVE_BY_NAME = {s.name: s for s in SERVE_CATALOG}


def get_serve_scenario(name: str) -> ServeScenario:
    try:
        return SERVE_BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown serve scenario {name!r}; "
                       f"known: {sorted(SERVE_BY_NAME)}") from None
