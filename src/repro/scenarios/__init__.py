"""Declarative failure-scenario engine.

One scenario definition — who fails, when (step / mid-checkpoint-write /
mid-recovery), how (SIGKILL / channel break / hang) — drives both the
discrete-event simulator and the real process runtime. See
docs/scenarios.md for the schema and catalog.

`schema` and `hooks` are stdlib-only (safe for worker subprocesses);
`engine`/`catalog` may pull heavier deps and are imported lazily by
consumers that need them.
"""
from . import hooks
from .schema import (CASCADE_POINTS, Fault, GRAY_DRAIN_PERSIST, GRAY_HOWS,
                     GRAY_STEP_S, HOWS, POINTS, Repair, Scenario,
                     SERVE_POINTS, STRATEGY_KEYS, ServeScenario, TARGETS,
                     Topology, elastic_transitions, expected_resume_step,
                     expected_resume_steps, gray_delay_s, gray_drain_cut,
                     normalize_strategy)

__all__ = [
    "CASCADE_POINTS", "Fault", "GRAY_DRAIN_PERSIST", "GRAY_HOWS",
    "GRAY_STEP_S", "HOWS", "POINTS", "Repair", "Scenario",
    "SERVE_POINTS", "STRATEGY_KEYS", "ServeScenario", "TARGETS", "Topology",
    "elastic_transitions", "expected_resume_step", "expected_resume_steps",
    "gray_delay_s", "gray_drain_cut", "normalize_strategy", "hooks",
]
