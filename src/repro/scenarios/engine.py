"""Scenario executors: one scenario definition, two substrates.

  run_sim(scenario, strategy)    discrete-event replay over the real
                                 Algorithm-1/2 protocol with calibrated
                                 costs (all strategies, any scale).
  run_real(scenario, strategy)   deploys the actual root/daemon/worker
                                 process tree on this host, injects the
                                 scenario's faults at their named points,
                                 and returns the measured outcome.

Both consume the identical Scenario object; `expected_resume_step` is the
shared oracle — the sim asserts the protocol lands there, the real run is
checked against the root's reported rollback consensus.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
from typing import Optional

from .schema import (ROOT_INJECTED_EXIT, Scenario, expected_resume_steps,
                     normalize_strategy)

SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

#: strategies the real-process runtime implements. ULFM exists only as a
#: cost model (the paper measures its prototype; we charge its collectives
#: and heartbeat in the sim). "shrink" is elastic recovery: re-host onto
#: spares while the pool lasts, contract the world once it is empty.
#: "replica" is zero-rollback failover: warm shadows promote in place and
#: a warm-standby root absorbs HNP loss without an external relaunch.
REAL_MODES = {"reinit": "reinit", "cr": "cr", "shrink": "shrink",
              "replica": "replica"}


def real_strategies(scenario: Scenario) -> list[str]:
    """The scenario's strategies executable on the real runtime."""
    return [s for s in scenario.strategies if s in REAL_MODES]


@dataclasses.dataclass
class ScenarioOutcome:
    """Uniform result shape across both executors."""
    scenario: str
    strategy: str
    substrate: str                      # "sim" | "real"
    n_recoveries: int
    resume_steps: list
    expected_resume: list               # one cut per primary fault (None
                                        # entries = timing-dependent)
    checksums: dict                     # real only: rank -> final checksum
    total_s: float
    detail: dict                        # substrate-specific extras

    @property
    def resume_consistent(self) -> bool:
        """True when the observed rollback consensuses match the
        declarative per-fault predictions, in order (vacuously true when
        every cut is timing-dependent)."""
        exp = list(self.expected_resume or [])
        if not any(e is not None for e in exp):
            return True
        if len(self.resume_steps) != len(exp):
            return False
        return all(e is None or r == e
                   for r, e in zip(self.resume_steps, exp))


# ------------------------------------------------------------------- sim

def run_sim(scenario: Scenario, strategy: str, costs=None
            ) -> ScenarioOutcome:
    from repro.sim.cluster import simulate_scenario

    key = normalize_strategy(strategy)
    res = simulate_scenario(scenario, key, costs=costs)
    if not res.world_consistent:
        raise AssertionError(
            f"scenario {scenario.name}/{key}: world diverged from the "
            f"intended membership (unplanned shrink or lost rank)")
    # resume_steps carries the sim's own consensus replay (modeled
    # per-rank durable state, see sim.cluster._mech_resume) — the
    # harness checks it against the declarative oracle below, so the two
    # derivations guard each other
    return ScenarioOutcome(
        scenario=scenario.name, strategy=key, substrate="sim",
        n_recoveries=res.n_recoveries,
        resume_steps=list(res.resume_steps),
        expected_resume=expected_resume_steps(scenario, key), checksums={},
        total_s=res.total_recovery_s,
        detail={"rows": res.rows})


# ------------------------------------------------------------------ real

def _root_cmd(scenario_path: str, scenario: Scenario, mode: str,
              ckpt_dir: str, report: str) -> list[str]:
    t = scenario.topology
    return [sys.executable, "-m", "repro.runtime.root",
            "--nodes", str(t.nodes),
            "--ranks-per-node", str(t.ranks_per_node),
            "--spares", str(t.spares),
            "--steps", str(scenario.steps), "--dim", str(scenario.dim),
            "--min-data-parallel", str(scenario.min_data_parallel),
            "--mode", mode, "--ckpt-dir", ckpt_dir, "--report", report,
            "--scenario", scenario_path,
            "--stall-timeout", str(scenario.stall_timeout_s),
            "--hb-period", str(scenario.heartbeat_period_s),
            "--hb-timeout", str(scenario.heartbeat_timeout_s)]


def run_real(scenario: Scenario, strategy: str, workdir: str, *,
             timeout: float = 180.0, max_relaunches: int = 2
             ) -> ScenarioOutcome:
    """Execute the scenario on the live process runtime.

    Root-target faults exit the root with ROOT_INJECTED_EXIT; the
    executor relaunches the identical command (the INJECTED_* sentinel in
    the checkpoint dir keeps the fault from re-firing) — the external
    job-restart recovery the paper assumes for HNP loss."""
    key = normalize_strategy(strategy)
    mode = REAL_MODES.get(key)
    if mode is None:
        raise ValueError(f"strategy {key!r} has no real-runtime mode; "
                         f"executable: {sorted(REAL_MODES)}")
    os.makedirs(workdir, exist_ok=True)
    scenario_path = os.path.join(workdir, f"{scenario.name}.scenario.json")
    scenario.dump(scenario_path)
    ckpt_dir = os.path.join(workdir, "ckpt")
    os.makedirs(ckpt_dir, exist_ok=True)
    report_path = os.path.join(workdir, "report.json")
    cmd = _root_cmd(scenario_path, scenario, mode, ckpt_dir, report_path)
    env = dict(os.environ, PYTHONPATH=SRC)

    if os.path.exists(report_path):
        os.remove(report_path)

    relaunches = 0
    standby_takeover = False
    while True:
        proc = subprocess.run(cmd, env=env, capture_output=True,
                              text=True, timeout=timeout)
        if proc.returncode == ROOT_INJECTED_EXIT:
            if mode == "replica":
                # no external relaunch: the warm standby already took
                # over — wait for it to finish the job and write the
                # report the dead primary never could
                _await_report(report_path, timeout, scenario, proc)
                standby_takeover = True
                break
            relaunches += 1
            if relaunches > max_relaunches:
                raise RuntimeError(
                    f"{scenario.name}: root kept dying after "
                    f"{max_relaunches} relaunches")
            continue
        if proc.returncode != 0:
            raise RuntimeError(
                f"{scenario.name}/{key} failed rc={proc.returncode}\n"
                f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
        break

    with open(report_path) as f:
        report = json.load(f)
    events = report.get("events", [])
    resumes = [ev["resume_step"] for ev in events if "resume_step" in ev]
    return ScenarioOutcome(
        scenario=scenario.name, strategy=key, substrate="real",
        n_recoveries=len(events) + relaunches,
        resume_steps=resumes,
        expected_resume=expected_resume_steps(scenario, key),
        checksums=report.get("checksums", {}),
        total_s=report.get("total_s", 0.0),
        detail={"events": events, "relaunches": relaunches,
                "standby_takeover": standby_takeover, "report": report})


def _await_report(report_path: str, timeout: float, scenario: Scenario,
                  proc) -> None:
    """Block until the standby root commits the final report (it writes
    tmp + atomic rename, so existence means complete)."""
    import time
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if os.path.exists(report_path):
            return
        time.sleep(0.1)
    raise RuntimeError(
        f"{scenario.name}: primary root died but the standby never "
        f"finished the job (no report after {timeout}s)\n"
        f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")


def describe(scenario: Scenario) -> str:
    """One-paragraph human rendering — used by example dry-runs."""
    lines = [f"{scenario.name}: {scenario.description}".rstrip(": "),
             f"  topology  {scenario.topology.nodes} nodes x "
             f"{scenario.topology.ranks_per_node} ranks "
             f"(+{scenario.topology.spares} spare), "
             f"{scenario.steps} steps"]
    for i, f in enumerate(scenario.faults):
        when = f"@step {f.step}" if f.step is not None else "@recovery"
        lines.append(f"  fault {i}   {f.how} {f.target} {f.rank} "
                     f"{when} ({f.point})")
    for i, r in enumerate(scenario.repairs):
        lines.append(f"  repair {i}  node of rank {r.rank} rejoins "
                     f"@step {r.step} (elastic grow-back)")
    exp = expected_resume_steps(scenario)
    cuts = ", ".join("timing-dependent" if e is None else str(e)
                     for e in exp) or "none"
    lines.append(f"  expected consistent cut(s): {cuts}; "
                 f"strategies: {', '.join(scenario.strategies)}")
    return "\n".join(lines)
