"""Declarative failure-scenario schema.

A `Scenario` is a pure data description of one fault-injected execution:

  who fails    Fault.target — an MPI "rank", its whole "node" (the parent
               daemon and every child), or the "root" (HNP) itself;
  when         Fault.step + Fault.point — at the top of iteration N
               ("step", behind the FENCE kill barrier so the cut is a
               deterministic consistent cut), mid-checkpoint-write
               ("worker.ckpt.mid_write": the shard is on disk but not yet
               renamed), mid-replication ("worker.ckpt.pre_push": the file
               committed but the buddy copy never sent), or *during an
               in-flight recovery* ("worker.recovery.*": the ReStore-style
               cascading failures — a replacement dying mid-restore, a
               survivor dying right after rollback, a kill mid
               delta-chain-compose);
  how          Fault.how — SIGKILL, a broken control channel, or a silent
               hang (caught by the root's stall watchdog).

The same Scenario object drives both executors (repro.scenarios.engine):
the discrete-event simulator charges each phase its calibrated cost over
the real Algorithm-1/2 protocol, and the real-process runtime replays the
faults on live POSIX processes. The schema stays jax-free on purpose — it
is imported by repro.core.failure and by the worker subprocesses; its only
non-stdlib import is core.recovery's strategy registry (itself jax-free),
so the strategy keys have exactly one source of truth.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

# "shadow" targets the warm replica of `rank` (replica strategy): the
# shadow process dies, the rank silently loses its zero-rollback cover,
# and the next failure of that rank falls back to global-restart.
TARGETS = ("rank", "node", "root", "shadow")
# "slow" and "lossy" are the *gray* failure mechanisms: the victim keeps
# running (nothing dies, nothing signals) but degrades — a slow rank's
# compute stretches by Fault.factor, a lossy rank's control-channel
# sends pay seeded delay/retransmit. Detection is statistical (per-rank
# barrier-arrival lateness through StragglerTracker), and the response
# is a *policy*: Scenario.mitigate=False tolerates the degradation to
# the end of the run; mitigate=True drains the victim through the
# ordinary loss path (SHRINK when the pool is empty, grow-back on
# repair) once the lateness persists GRAY_DRAIN_PERSIST barriers.
HOWS = ("sigkill", "channel_break", "hang", "slow", "lossy")
GRAY_HOWS = ("slow", "lossy")

#: nominal healthy per-step quantum the gray degradation scales against:
#: a factor-k victim is delayed (k-1) * GRAY_STEP_S per step — large
#: against scheduling noise (~ms), small against the run (~s).
GRAY_STEP_S = 0.1
#: consecutive late barriers before a mitigating root drains the victim
#: (one flagged barrier is noise; two in a row is a trend)
GRAY_DRAIN_PERSIST = 2


def gray_delay_s(f: "Fault") -> float:
    """Injected per-step delay of a factor-k gray fault."""
    return (f.factor - 1.0) * GRAY_STEP_S


def gray_drain_cut(f: "Fault") -> int:
    """The consistent cut a mitigating drain resumes from. Lateness is
    first observable at barrier f.step (the first degraded iteration),
    the drain fires once it persists, i.e. at the completion of barrier
    f.step + GRAY_DRAIN_PERSIST - 1 — whose release the root withholds,
    making that barrier's step the deterministic consensus cut (every
    rank arrived, so every rank committed that step's checkpoint)."""
    return f.step + GRAY_DRAIN_PERSIST - 1

# Named interruption points. "step" is the only fenced point (the victim
# declares intent and dies only once every survivor has committed the
# fence step's checkpoint); the others interrupt a specific phase of the
# checkpoint or recovery machinery and rely on the rollback consensus
# (resume = min over ranks) for a consistent cut.
POINTS = (
    "step",                      # top of the BSP loop at iteration `step`
    "worker.ckpt.mid_write",     # rank file written to tmp, not renamed
    "worker.ckpt.pre_push",      # rank file committed, buddy push not sent
    "worker.recovery.enter",     # survivor just rolled back (REINITED)
    "worker.recovery.pulled",    # restoring rank gathered its frames
    "worker.recovery.compose",   # mid delta-chain compose of the restore
    # FileCheckpointer-internal points (unit-level crash tests / trainer)
    "ckpt.file.shard",           # one shard's bytes written
    "ckpt.file.pre_commit",      # shards + manifest down, COMMITTED not
    "ckpt.file.compose",         # applying a delta frame during load
    "ckpt.file.rebase.begin",    # background re-base starting its compose
    "ckpt.file.rebase.pre_commit",  # re-based frame staged, not renamed
)

CASCADE_POINTS = tuple(p for p in POINTS if p.startswith("worker.recovery."))

#: exit code of an injected root self-kill: the runtime root exits with it
#: (runtime.root) and the engine recognizes it as "relaunch me" (external
#: job restart). Lives here so both sides share one definition.
ROOT_INJECTED_EXIT = 42

#: strategy keys a scenario may request; "ulfm" is sim-only (the measured
#: runtime implements reinit, cr, shrink and replica — see
#: engine.real_strategies). "shrink" is elastic recovery: spare-pool
#: re-hosting while spares last, world contraction once the pool is
#: exhausted. "replica" is zero-rollback failover: warm shadows promote
#: in place, a warm standby absorbs root loss.
#: The key set and alias table live in core.recovery — the strategy
#: registry is the single source of truth the drift-guard test pins.
from repro.core.recovery import STRATEGIES as _STRATEGIES
from repro.core.recovery import STRATEGY_ALIASES

STRATEGY_KEYS = tuple(_STRATEGIES)


def normalize_strategy(name: str) -> str:
    k = STRATEGY_ALIASES.get(name.lower(), name.lower())
    if k not in STRATEGY_KEYS:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"known: {STRATEGY_KEYS + tuple(STRATEGY_ALIASES)}")
    return k


@dataclasses.dataclass(frozen=True)
class Topology:
    """Deployment tree shape (paper Fig. 3)."""
    nodes: int = 2
    ranks_per_node: int = 2
    spares: int = 1

    @property
    def world(self) -> int:
        return self.nodes * self.ranks_per_node

    def validate(self):
        if self.nodes < 1 or self.ranks_per_node < 1 or self.spares < 0:
            raise ValueError(f"bad topology {self}")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected failure. `rank` is the victim rank; for target="node"
    it selects the node hosting that rank; for target="root" it is
    ignored. `step` is the trigger iteration for point="step", the *save*
    step for the worker.ckpt.* points, and None (wildcard) for the
    recovery points, which fire at most once during the recovery that
    follows the previous fault.

    `factor` is only meaningful for the gray hows (`slow`, `lossy`): the
    degradation multiple (x-k deceleration / per-send delay scale); the
    victim's per-step penalty is (factor - 1) * GRAY_STEP_S. Gray faults
    are active from iteration `step` for the rest of the incarnation —
    a drained-and-respawned victim comes back healthy (degradation
    models a sick host, and a re-host moves off it)."""
    target: str = "rank"
    rank: int = 0
    step: Optional[int] = None
    point: str = "step"
    how: str = "sigkill"
    factor: float = 0.0

    def validate(self, topo: Topology, position: int):
        if self.target not in TARGETS:
            raise ValueError(f"fault target {self.target!r} not in {TARGETS}")
        if self.how not in HOWS:
            raise ValueError(f"fault how {self.how!r} not in {HOWS}")
        if self.point not in POINTS:
            raise ValueError(f"fault point {self.point!r} not in {POINTS}")
        if self.how in GRAY_HOWS:
            if self.target not in ("rank", "node"):
                raise ValueError(f"{self.how} faults degrade a rank/node "
                                 "(nothing else runs the BSP loop)")
            if self.point != "step":
                raise ValueError(f"{self.how} faults use point='step' "
                                 "(degradation starts at an iteration, "
                                 "not inside a checkpoint phase)")
            if not self.factor > 1.0:
                raise ValueError(f"{self.how} faults need factor > 1.0 "
                                 "(the degradation multiple)")
            if self.step is None or self.step < 2:
                raise ValueError(f"{self.how} faults need step >= 2: the "
                                 "lateness detector needs at least two "
                                 "healthy barriers as its baseline")
        elif self.factor != 0.0:
            raise ValueError(f"factor only applies to {GRAY_HOWS} faults")
        if self.target == "root":
            if self.how != "sigkill" or self.point != "step":
                raise ValueError("root faults support only sigkill @step")
        elif not (0 <= self.rank < topo.world):
            raise ValueError(f"victim rank {self.rank} outside world "
                             f"{topo.world}")
        if self.how == "hang" and self.target == "root":
            raise ValueError("hang faults only defined for rank/node")
        if self.target == "shadow" and (self.how != "sigkill"
                                        or self.point != "step"):
            raise ValueError("shadow faults support only sigkill @step "
                             "(the shadow runs no BSP loop to interrupt)")
        if self.point in CASCADE_POINTS:
            if position == 0:
                raise ValueError(f"{self.point} is a cascade point: it "
                                 "only fires during a recovery, so it "
                                 "cannot be the first fault")
            if self.step is not None:
                raise ValueError("recovery-point faults take step=None")
        elif self.step is None or self.step < 1:
            raise ValueError(f"fault at {self.point} needs step >= 1")
        if self.point.startswith(("worker.ckpt.", "ckpt.file.")) \
                and self.target != "rank":
            raise ValueError("checkpoint-phase faults target a rank")


@dataclasses.dataclass(frozen=True)
class Repair:
    """One node repair: the node that originally hosted `rank` (and has
    since died or been dropped) comes back — its daemon restarts at the
    `step` checkpoint boundary and re-registers with the root (REJOIN).

    Only the elastic runtime acts on it: the admission policy re-admits
    dropped ranks (GROW, at the next checkpoint boundary) when the world
    is shrunk, and otherwise adds the node to the spare pool. Non-elastic
    strategies ignore repairs — their world never shrank."""
    rank: int
    step: int

    def validate(self, topo: "Topology", steps: int):
        if not (0 <= self.rank < topo.world):
            raise ValueError(f"repair rank {self.rank} outside world "
                             f"{topo.world}")
        if not (1 <= self.step < steps):
            raise ValueError(f"repair step {self.step} outside run "
                             f"[1, {steps})")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A complete, reproducible failure experiment."""
    name: str
    faults: tuple[Fault, ...]
    topology: Topology = Topology()
    repairs: tuple[Repair, ...] = ()    # node repairs (elastic grow-back)
    steps: int = 6                      # application iterations
    dim: int = 64                       # per-rank state size
    # smallest legal world, in whole data-parallel groups: the elastic
    # strategy refuses to shrink below min_data_parallel * ranks_per_node
    min_data_parallel: int = 1
    strategies: tuple[str, ...] = ("reinit", "cr", "ulfm")
    expect_bit_identical: bool = True   # recovered == fault-free state
    # gray-failure policy knob (threaded root -> trainer -> sim): False
    # tolerates a degraded member to the end of the run (no recovery, no
    # oracle entry — the run must still finish bit-identical); True
    # drains a persistently-late victim through the ordinary loss path
    # (SHRINK when the pool is empty; a Repair grows it back) with the
    # drain's consistent cut in the oracle. Only meaningful with gray
    # faults, and only the elastic strategy can execute a drain.
    mitigate: bool = False
    stall_timeout_s: float = 0.0        # >0 arms the root stall watchdog
    # >0 arms the neighbour-heartbeat ring on the real runtime: each rank
    # observes its ring successor every period and reports SUSPECT to the
    # root after timeout seconds of consecutive silence — hang cells then
    # measure detection instead of relying on the watchdog kill
    heartbeat_period_s: float = 0.0
    heartbeat_timeout_s: float = 0.0
    tags: tuple[str, ...] = ()
    description: str = ""

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))
        object.__setattr__(self, "repairs", tuple(self.repairs))
        object.__setattr__(self, "strategies",
                           tuple(normalize_strategy(s)
                                 for s in self.strategies))
        object.__setattr__(self, "tags", tuple(self.tags))
        self.validate()

    # ------------------------------------------------------- validation

    def validate(self):
        self.topology.validate()
        if not self.name:
            raise ValueError("scenario needs a name")
        if not self.faults and self.expect_bit_identical is False:
            raise ValueError("fault-free scenario must expect identity")
        for i, f in enumerate(self.faults):
            f.validate(self.topology, i)
            if f.step is not None and f.step >= self.steps:
                raise ValueError(f"fault step {f.step} >= run steps "
                                 f"{self.steps}")
        for r in self.repairs:
            r.validate(self.topology, self.steps)
        if self.min_data_parallel < 1:
            raise ValueError("min_data_parallel must be >= 1")
        if self.min_data_parallel > self.topology.nodes:
            raise ValueError(f"min_data_parallel {self.min_data_parallel} "
                             f"exceeds {self.topology.nodes} nodes")
        if (self.heartbeat_period_s > 0) != (self.heartbeat_timeout_s > 0):
            raise ValueError("heartbeat needs both period and timeout > 0")
        if any(f.how == "hang" for f in self.faults) \
                and self.stall_timeout_s <= 0 \
                and self.heartbeat_period_s <= 0:
            raise ValueError("hang faults need stall_timeout_s > 0 or an "
                             "armed heartbeat ring (nothing else detects "
                             "a silent rank)")
        if any(f.how == "hang" and f.target == "node"
               for f in self.faults) and self.heartbeat_period_s <= 0:
            raise ValueError("node-hang faults need the heartbeat ring: "
                             "the watchdog's KILL_RANK order goes through "
                             "the hung daemon and dies there — only the "
                             "daemon-level ring observation detects it")
        if not self.strategies:
            raise ValueError("scenario needs at least one strategy")
        if any(f.target == "shadow" for f in self.faults) \
                and "replica" not in self.strategies:
            raise ValueError("shadow faults only exist under the replica "
                             "strategy (no other strategy runs shadows)")
        gray = [f for f in self.faults if f.how in GRAY_HOWS]
        if self.mitigate:
            if not gray:
                raise ValueError("mitigate=True without a gray fault: "
                                 "there is nothing to drain")
            if set(self.strategies) != {"shrink"}:
                raise ValueError("mitigate=True needs strategies="
                                 "('shrink',): only the elastic strategy "
                                 "can drain and re-host a live member")
            for f in gray:
                if gray_drain_cut(f) >= self.steps - 1:
                    raise ValueError(
                        f"gray fault at step {f.step}: the drain cut "
                        f"{gray_drain_cut(f)} leaves no post-drain step "
                        f"in a {self.steps}-step run")

    # --------------------------------------------------------- queries

    def faults_for_rank(self, rank: int) -> list[tuple[int, Fault]]:
        """(index, fault) pairs whose injection is driven by `rank` —
        rank faults on the rank itself, node faults by the victim rank
        on that node (the paper has the victim signal its daemon). Gray
        faults are excluded: they are degradations, not kills, and are
        applied via `gray_faults_for_rank` instead."""
        return [(i, f) for i, f in enumerate(self.faults)
                if f.target in ("rank", "node") and f.rank == rank
                and f.how not in GRAY_HOWS]

    def gray_faults_for_rank(self, rank: int) -> list[tuple[int, Fault]]:
        """(index, fault) pairs degrading `rank`: rank-target gray faults
        on the rank itself, node-target gray faults on every rank the
        victim's node hosts (a sick host slows all its children)."""
        rpn = self.topology.ranks_per_node
        out = []
        for i, f in enumerate(self.faults):
            if f.how not in GRAY_HOWS:
                continue
            if f.target == "rank" and f.rank == rank:
                out.append((i, f))
            elif f.target == "node" and f.rank // rpn == rank // rpn:
                out.append((i, f))
        return out

    def root_faults(self) -> list[tuple[int, Fault]]:
        return [(i, f) for i, f in enumerate(self.faults)
                if f.target == "root"]

    def shadow_faults(self, rank: int) -> list[tuple[int, Fault]]:
        """(index, fault) pairs killing the warm shadow of `rank` —
        injected by the shadow process itself when the delta stream
        reaches the trigger step."""
        return [(i, f) for i, f in enumerate(self.faults)
                if f.target == "shadow" and f.rank == rank]

    @property
    def is_cascading(self) -> bool:
        return any(f.point in CASCADE_POINTS for f in self.faults)

    # ----------------------------------------------------------- serde

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "description": self.description,
            "topology": dataclasses.asdict(self.topology),
            "repairs": [dataclasses.asdict(r) for r in self.repairs],
            "steps": self.steps,
            "dim": self.dim,
            "min_data_parallel": self.min_data_parallel,
            "strategies": list(self.strategies),
            "expect_bit_identical": self.expect_bit_identical,
            "mitigate": self.mitigate,
            "stall_timeout_s": self.stall_timeout_s,
            "heartbeat_period_s": self.heartbeat_period_s,
            "heartbeat_timeout_s": self.heartbeat_timeout_s,
            "tags": list(self.tags),
            "faults": [dataclasses.asdict(f) for f in self.faults],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        return cls(
            name=d["name"],
            description=d.get("description", ""),
            topology=Topology(**d.get("topology", {})),
            repairs=tuple(Repair(**r) for r in d.get("repairs", ())),
            steps=d.get("steps", 6),
            dim=d.get("dim", 64),
            min_data_parallel=d.get("min_data_parallel", 1),
            strategies=tuple(d.get("strategies", ("reinit", "cr", "ulfm"))),
            expect_bit_identical=d.get("expect_bit_identical", True),
            mitigate=d.get("mitigate", False),
            stall_timeout_s=d.get("stall_timeout_s", 0.0),
            heartbeat_period_s=d.get("heartbeat_period_s", 0.0),
            heartbeat_timeout_s=d.get("heartbeat_timeout_s", 0.0),
            tags=tuple(d.get("tags", ())),
            faults=tuple(Fault(**f) for f in d.get("faults", ())),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))

    def dump(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "Scenario":
        with open(path) as f:
            return cls.from_json(f.read())


def _fault_resume(f: Fault) -> Optional[int]:
    if f.target in ("root", "shadow"):
        return None
    if f.point == "step":
        return f.step
    if f.point == "worker.ckpt.mid_write":
        return f.step - 1
    if f.point == "worker.ckpt.pre_push":
        return f.step
    return None


def elastic_transitions(scenario: Scenario) -> list:
    """Replay the elastic (shrink-strategy) membership policy over the
    scenario's declarative timeline: primary faults and node repairs
    merged in step order (a step-N fault fires at the top of iteration N,
    a step-N repair at that step's checkpoint boundary — fault first).
    Rank hosting and node aliveness are modeled by name, mirroring the
    executors: Algorithm 1 re-hosts a dead node's ranks onto the
    least-loaded survivor, and a repair names the rank's *initial* node.

    Returns [(kind, obj, resume)] where kind is:
      "respawn"  spare-absorbed / in-place / over-subscribed recovery
      "shrink"   pool exhausted, world contracted by the lost ranks
                 (a whole node group, or a single rank — whose home
                 node then stays alive)
      "grow"     a repair of a dead node re-admitted a dropped group —
                 its own when it has one, else the most recent; resume
                 = that shrink's consistent cut (the pinned anchor)
      "spare"    a repair of a dead node with nothing dropped: the
                 node rejoins the pool
      "noop"     a repair of a node that never left the world (e.g.
                 after a process-level shrink): the executors skip it
      "restart"  root loss (external job restart, timing-dependent cut)

    This is the same admission/floor policy `MembershipMachine` executes;
    the harness cross-checks the two derivations against each other."""
    topo = scenario.topology
    floor = scenario.min_data_parallel * topo.ranks_per_node
    rpn = topo.ranks_per_node
    hosts = {r: f"node{r // rpn}" for r in range(topo.world)}
    ranks_on = {f"node{n}": set(range(n * rpn, (n + 1) * rpn))
                for n in range(topo.nodes)}
    ranks_on.update({f"spare{s}": set() for s in range(topo.spares)})
    drop_groups: list = []        # (home_node_or_None, ranks, cut)

    def have_spare():
        return any(not rs for rs in ranks_on.values())

    def world_size():
        return sum(len(rs) for rs in ranks_on.values())

    # a mitigated gray fault becomes an ordinary loss at its drain (the
    # root kills the victim once lateness persists), so its timeline
    # position and cut are the drain's, not the onset step; an
    # unmitigated one never enters the membership timeline at all
    timeline = sorted(
        [((gray_drain_cut(f) if f.how in GRAY_HOWS
           else f.step if f.step is not None else -1), 0, i, "fault", f)
         for i, f in enumerate(scenario.faults)
         if f.point not in CASCADE_POINTS and f.target != "shadow"
         and (f.how not in GRAY_HOWS or scenario.mitigate)]
        + [(r.step, 1, i, "repair", r)
           for i, r in enumerate(scenario.repairs)],
        key=lambda e: e[:3])
    out = []
    for _, _, _, what, obj in timeline:
        if what == "fault":
            cut = gray_drain_cut(obj) if obj.how in GRAY_HOWS \
                else _fault_resume(obj)
            if obj.target == "root":
                # external job restart redeploys the full topology (the
                # executors rebuild view + machine): membership resets
                hosts = {r: f"node{r // rpn}" for r in range(topo.world)}
                ranks_on = {f"node{n}": set(range(n * rpn, (n + 1) * rpn))
                            for n in range(topo.nodes)}
                ranks_on.update({f"spare{s}": set()
                                 for s in range(topo.spares)})
                drop_groups.clear()
                out.append(("restart", obj, cut))
            elif obj.target == "node":
                dead = hosts.get(obj.rank)
                if dead is None:
                    continue            # victim already out of the world
                lost = ranks_on.pop(dead)
                if have_spare() or world_size() < floor:
                    # a spare absorbs it, or the floor forbids the
                    # shrink: Algorithm 1 re-hosts onto the
                    # least-loaded survivor (over-subscribing if none
                    # is empty)
                    target = min((len(rs), d)
                                 for d, rs in ranks_on.items())[1]
                    ranks_on[target] |= lost
                    for r in sorted(lost):
                        hosts[r] = target
                    out.append(("respawn", obj, cut))
                else:
                    for r in sorted(lost):
                        del hosts[r]
                    drop_groups.append((dead, sorted(lost), cut))
                    out.append(("shrink", obj, cut))
            else:                         # rank loss
                host = hosts.get(obj.rank)
                if host is None:
                    continue
                if not have_spare() and world_size() - 1 >= floor:
                    ranks_on[host].discard(obj.rank)
                    del hosts[obj.rank]
                    drop_groups.append((None, [obj.rank], cut))
                    out.append(("shrink", obj, cut))
                else:
                    out.append(("respawn", obj, cut))
        else:                             # repair
            node = f"node{obj.rank // rpn}"
            if node in ranks_on:
                # the node never left the world (it survived, or a
                # process-level shrink dropped only a rank of it):
                # the executors skip the repair entirely
                out.append(("noop", obj, None))
            elif drop_groups:
                idx = next((i for i in range(len(drop_groups) - 1, -1, -1)
                            if drop_groups[i][0] == node),
                           len(drop_groups) - 1)
                _, granks, cut = drop_groups.pop(idx)
                ranks_on[node] = set(granks)
                for r in granks:
                    hosts[r] = node
                out.append(("grow", obj, cut))
            else:
                ranks_on[node] = set()
                out.append(("spare", obj, None))
    return out


def expected_resume_steps(scenario: Scenario,
                          strategy: Optional[str] = None) -> list:
    """The consistent cuts the rollback consensus must land on — one entry
    per *primary* (non-cascade) fault, in injection order; the shared
    oracle both executors are checked against. A None entry means that
    recovery's resume step is legitimately timing-dependent (root faults),
    and only bit-identity is asserted for it.

      step                 victim dies behind the FENCE: every rank has
                           committed checkpoint `step`  -> resume = step
      worker.ckpt.mid_write  victim dies with save `step` un-renamed; its
                           newest durable state is step-1 and min() over
                           ranks rules                  -> resume = step-1
      worker.ckpt.pre_push   the file committed before death, and the
                           restore merges buddy + file  -> resume = step
      cascades             a second failure during recovery replays the
                           same consensus over the same frames — the
                           primary fault's cut stands (no extra entry).

    Sequential primary faults (double node loss, spare-pool exhaustion)
    each trigger their own recovery and therefore their own entry.

    Under the elastic strategy (`strategy="shrink"`) node repairs add
    entries of their own: a grow-back's consensus lands exactly on the
    cut of the shrink it reverses (the rejoining ranks' newest durable
    checkpoint — which the survivors kept pinned as the grow anchor).
    Non-elastic strategies ignore repairs, so their oracle is unchanged.

    Gray faults (`slow`/`lossy`) add an entry only when the scenario
    mitigates under the elastic strategy: the drain is an ordinary loss
    at `gray_drain_cut` (the barrier whose release the root withheld).
    Tolerated gray faults trigger no recovery at all — their oracle is
    empty and the executors must report zero consensus entries.
    """
    include_gray = scenario.mitigate and (
        strategy is None or normalize_strategy(strategy) == "shrink")
    if strategy is not None and normalize_strategy(strategy) == "shrink" \
            and scenario.repairs:
        return [cut for kind, _, cut in elastic_transitions(scenario)
                if kind not in ("spare", "noop")]
    # shadow faults never interrupt the application: no consensus entry.
    # Replica promotions resume exactly at the step-point cut, the same
    # value the fence oracle already yields — so the default table below
    # is shared by every strategy (a replica fallback on a ckpt-phase
    # fault degrades to Reinit++, whose cut it also shares).
    return [(gray_drain_cut(f) if f.how in GRAY_HOWS else _fault_resume(f))
            for f in scenario.faults
            if f.point not in CASCADE_POINTS and f.target != "shadow"
            and (f.how not in GRAY_HOWS or include_gray)]


def expected_resume_step(scenario: Scenario) -> Optional[int]:
    """Back-compat single-fault view: the first primary fault's cut."""
    steps = expected_resume_steps(scenario)
    return steps[0] if steps else None


# --------------------------------------------------------------- serving

# Interruption points of the serving engine (serve.engine fires them each
# step / admission). Deliberately a separate namespace from POINTS: the
# training matrices parametrize over POINTS and a serve point can never
# appear in a training Fault.
SERVE_POINTS = (
    "serve.decode.step",     # top of an engine step, before admission
    "serve.prefill.mid",     # prompt prefill computed, not yet committed
)


@dataclasses.dataclass(frozen=True)
class ServeScenario:
    """One fault-injected *serving* experiment: a `ServeCluster` run
    under open-loop load with a single rank kill.

    The invariants are the serving analogue of `expect_bit_identical`:
    zero requests dropped, zero duplicate or re-emitted tokens (the
    TokenSink ledger raises on either), and — when
    `expect_bit_identical` — every request's delivered transcript
    bit-identical to the fault-free run of the same load. Kept jax-free
    like `Scenario`; the executor lives in repro.serve.cluster."""
    name: str
    strategy: str = "reinit"            # "reinit" | "replica"
    world: int = 2
    n_slots: int = 4
    max_len: int = 64
    rounds: int = 8                     # open-loop arrival horizon
    per_round: int = 1                  # arrivals per round (cluster-wide)
    max_new_tokens: int = 5
    seed: int = 0
    publish_every: int = 2              # replica forces 1 at run time
    respawn_delay: int = 2              # replica forces 0 at run time
    fault_round: int = 4
    fault_rank: int = 1
    fault_point: str = "serve.decode.step"
    expect_bit_identical: bool = True
    tags: tuple[str, ...] = ()
    description: str = ""

    def __post_init__(self):
        object.__setattr__(self, "tags", tuple(self.tags))
        self.validate()

    def validate(self):
        if not self.name:
            raise ValueError("serve scenario needs a name")
        if self.strategy not in ("reinit", "replica"):
            raise ValueError(f"serve strategy {self.strategy!r} not in "
                             "('reinit', 'replica')")
        if self.fault_point not in SERVE_POINTS:
            raise ValueError(f"serve fault point {self.fault_point!r} "
                             f"not in {SERVE_POINTS}")
        if not (0 <= self.fault_rank < self.world):
            raise ValueError(f"victim rank {self.fault_rank} outside "
                             f"world {self.world}")
        if not (0 <= self.fault_round < self.rounds):
            raise ValueError(f"fault round {self.fault_round} outside "
                             f"load horizon [0, {self.rounds})")
        if self.world < 2:
            raise ValueError("serving fault tolerance needs world >= 2 "
                             "(the buddy holds the frames)")
        if min(self.n_slots, self.max_len, self.rounds, self.per_round,
               self.max_new_tokens, self.publish_every) < 1 \
                or self.respawn_delay < 0:
            raise ValueError(f"bad serve scenario sizes in {self.name}")

    def fault(self) -> dict:
        """The `fault=` argument `ServeCluster.run` takes."""
        return {"round": self.fault_round, "rank": self.fault_rank,
                "point": self.fault_point}

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["tags"] = list(self.tags)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServeScenario":
        d = dict(d)
        d["tags"] = tuple(d.get("tags", ()))
        return cls(**d)
