"""Process-global injection hook registry.

Instrumented code (FileCheckpointer's write/compose paths, the worker's
checkpoint/recovery paths) calls `fire(point, **ctx)` at its named
interruption points; when no injector is installed the call is a
two-instruction no-op, so the hooks cost nothing in production paths.

An injector is any callable `(point: str, **ctx) -> None`. The worker
installs a scenario-driven one that SIGKILLs / hangs / breaks channels;
unit tests install ad-hoc ones (e.g. the crash-atomicity test kills the
process between a shard write and the COMMITTED marker).

Thread-safety: `install`/`clear` swap a single reference; `fire` reads it
once. Injectors themselves must tolerate concurrent calls (checkpoint IO
pools fire from worker threads).
"""
from __future__ import annotations

from typing import Callable, Optional

_injector: Optional[Callable] = None


def install(injector: Callable) -> None:
    """Install `injector` as the process-global hook target."""
    global _injector
    _injector = injector


def clear() -> None:
    global _injector
    _injector = None


def active() -> Optional[Callable]:
    return _injector


def fire(point: str, **ctx) -> None:
    """Fire a named interruption point. No-op unless an injector is
    installed. Whatever the injector raises propagates — a test injector
    may abort the surrounding operation with an exception on purpose."""
    inj = _injector
    if inj is not None:
        inj(point, **ctx)
