import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first initialization. Everything below is ordinary.

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp                            # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.configs import ASSIGNED, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.models.config import ShapeConfig        # noqa: E402
from repro.models.flops import cell_cost           # noqa: E402
from repro.models.model import Model               # noqa: E402
from repro.models.transformer import ExecConfig    # noqa: E402
from repro.sharding.partition import (_divisible, constraint_scope,
                                      state_shardings)        # noqa: E402
from repro.sharding.rules import PRESETS           # noqa: E402
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: E402
from repro.launch.hlo_analysis import (  # noqa: E402
    collective_summary, compiled_cost_analysis, while_report)
from repro.launch.mesh import make_production_mesh  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces a JSON artifact with:
  - memory_analysis (argument/output/temp bytes per device — proves fit),
  - cost_analysis raw numbers (per-device, scan-body-once caveat),
  - the collective schedule from the optimized HLO with while-trip-count
    correction (launch/hlo_analysis.py),
  - analytic FLOPs/bytes from models/flops.py,
  - lowering/compile wall times.

benchmarks/roofline.py consumes these artifacts to build the §Roofline
table.
"""


def _sds(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype) \
        if not isinstance(x, jax.ShapeDtypeStruct) else x


def batch_shardings(mesh, rules, batch):
    """NamedShardings for the input dict (tokens/labels/embeddings…)."""
    out = {}
    for k, v in batch.items():
        if k == "pos":
            out[k] = NamedSharding(mesh, P())
        elif k == "state":
            specs = None      # handled separately
        else:
            spec = P(rules.batch, *(None,) * (len(v.shape) - 1))
            out[k] = NamedSharding(mesh, _divisible(spec, v.shape, mesh))
    return out


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               ec: ExecConfig):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, why
    if shape.kind != "train":
        cfg = cfg.replace(param_dtype="bfloat16")    # serving dtype
    model = Model(cfg, ec)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if shape.kind == "train":
        rules = PRESETS["multipod" if multi_pod else "pod"]
    else:
        rules = PRESETS["multipod_serve" if multi_pod else "pod_serve"]
    # kv heads shard over the model axis only when they divide it evenly
    # (olmoe/seamless: 16 kv heads on a 16-way axis); otherwise they stay
    # replicated and the GQA expansion is local (rules.py comment).
    if (shape.kind == "train" and cfg.n_kv_heads
            and cfg.n_kv_heads % mesh.shape["model"] == 0):
        rules = dataclasses.replace(rules, kv_heads="model")
    return (cfg, shape, model, mesh, rules), ""


def lower_cell(cfg, shape: ShapeConfig, model: Model, mesh, rules,
               donate: bool = True, with_buddy: bool = False):
    """Returns (lowered, meta) for the cell's step function.

    with_buddy=True (train cells) fuses the paper's buddy memory
    checkpoint into the step: the post-update state is collective-permuted
    one step along the data axis and returned as a second output — the
    redundant HBM copy lives on the neighbour chip.
    """
    specs = model.input_specs(shape, abstract=True)

    if shape.kind == "train":
        params_abs = model.abstract_params()
        state_abs = {"params": params_abs,
                     "opt": jax.eval_shape(adamw_init, params_abs),
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
        st_sh = state_shardings(mesh, state_abs, rules)
        b_sh = batch_shardings(mesh, rules, specs)
        opt_cfg = AdamWConfig()

        M = model.ec.microbatches

        def grad_of(params, batch):
            def loss_fn(p):
                return model.loss_fn(p, batch)
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            return loss, grads

        def train_step(state, batch):
            if M > 1:
                # gradient accumulation: activation live-set shrinks by M,
                # FSDP weight gathers repeat per microbatch (the classic
                # memory ↔ collective trade)
                mb = jax.tree.map(
                    lambda a: a.reshape(M, a.shape[0] // M, *a.shape[1:]),
                    batch)

                def acc(carry, b):
                    gsum, lsum = carry
                    loss, g = grad_of(state["params"], b)
                    return (jax.tree.map(jnp.add, gsum, g),
                            lsum + loss), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                (gsum, lsum), _ = jax.lax.scan(
                    acc, (zeros, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / M, gsum)
                loss = lsum / M
            else:
                loss, grads = grad_of(state["params"], batch)
            new_p, new_opt, om = adamw_update(state["params"], grads,
                                              state["opt"], opt_cfg)
            return ({"params": new_p, "opt": new_opt,
                     "step": state["step"] + 1}, loss)

        if with_buddy:
            from repro.checkpoint.memory_ckpt import buddy_exchange

            def train_step_buddy(state, batch):
                new_state, loss = train_step(state, batch)
                buddy = buddy_exchange(new_state, mesh, rules)
                return new_state, (loss, buddy)

            fn = jax.jit(train_step_buddy, in_shardings=(st_sh, b_sh),
                         out_shardings=(st_sh, (None, st_sh)),
                         donate_argnums=(0,) if donate else ())
        else:
            fn = jax.jit(train_step, in_shardings=(st_sh, b_sh),
                         out_shardings=(st_sh, None),
                         donate_argnums=(0,) if donate else ())
        args = (state_abs, specs)

    elif shape.kind == "prefill":
        params_abs = model.abstract_params()
        p_sh = state_shardings(mesh, params_abs, rules)
        b_sh = batch_shardings(mesh, rules, specs)

        def prefill_step(params, batch):
            return model.prefill(params, batch, max_len=shape.seq_len)

        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        args = (params_abs, specs)

    else:  # decode
        params_abs = model.abstract_params()
        p_sh = state_shardings(mesh, params_abs, rules)
        state_abs = specs["state"]
        sspecs = model.decode_state_specs(rules)
        sspecs = jax.tree.map(
            lambda s, leaf: _divisible(s, leaf.shape, mesh),
            sspecs, state_abs, is_leaf=lambda s: isinstance(s, P))
        s_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                            is_leaf=lambda s: isinstance(s, P))
        tok_sh = NamedSharding(mesh, _divisible(
            P(rules.batch, None), specs["token"].shape, mesh))

        def serve_step(params, token, state, pos):
            return model.decode_step(params, token, state, pos)

        fn = jax.jit(serve_step,
                     in_shardings=(p_sh, tok_sh, s_sh, NamedSharding(mesh, P())),
                     out_shardings=(None, s_sh),
                     donate_argnums=(2,) if donate else ())
        args = (params_abs, specs["token"], state_abs, specs["pos"])

    with constraint_scope(mesh, rules):
        t0 = time.monotonic()
        lowered = fn.lower(*args)
        t_lower = time.monotonic() - t0
    return lowered, {"lower_s": t_lower}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_path: str | None = None, ec: ExecConfig | None = None,
             donate: bool = True, save_hlo: str | None = None,
             with_buddy: bool = False) -> dict:
    ec = ec or ExecConfig()
    built, why = build_cell(arch, shape_name, multi_pod, ec)
    mesh_name = "multipod" if multi_pod else "pod"
    if built is None:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "skipped": why}
    else:
        cfg, shape, model, mesh, rules = built
        lowered, meta = lower_cell(cfg, shape, model, mesh, rules,
                                   donate=donate,
                                   with_buddy=with_buddy and
                                   shape.kind == "train")
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0
        mem = compiled.memory_analysis()
        ca = compiled_cost_analysis(compiled)
        hlo = compiled.as_text()
        colls = collective_summary(hlo)
        whiles = while_report(hlo)
        ac = cell_cost(cfg, shape, flash=(ec.attn_impl == "pallas"),
               moe_group=ec.moe_group)
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "chips": mesh.size if hasattr(mesh, "size") else
            int(jnp.prod(jnp.array(list(mesh.shape.values())))),
            "exec_config": dataclasses.asdict(ec),
            "lower_s": meta["lower_s"], "compile_s": t_compile,
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
            "cost_analysis": {
                "flops_per_device": ca.get("flops", 0.0),
                "bytes_per_device": ca.get("bytes accessed", 0.0),
            },
            "collective_bytes": colls,
            "whiles": whiles,
            "analytic": {
                "flops_total": ac.flops,
                "hbm_bytes_total": ac.hbm_bytes,
                "model_flops": ac.details["model_flops"],
            },
        }
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", choices=list(SHAPES) + ["all"])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--out", default="")
    ap.add_argument("--attn-impl", default="chunked")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--no-scan", action="store_true")
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--with-buddy", action="store_true",
                    help="fuse the buddy memory checkpoint (a ppermute of "
                         "the train state) into the lowered step")
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args(argv)

    ec = ExecConfig(attn_impl=args.attn_impl, remat_policy=args.remat,
                    scan_layers=not args.no_scan,
                    microbatches=args.microbatches)
    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    failures = []
    for arch in archs:
        for shape in shapes:
            out = None
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                out = os.path.join(
                    args.out, f"{arch}__{shape}__{args.mesh}.json")
            try:
                r = run_cell(arch, shape, args.mesh == "multipod",
                             out_path=out, ec=ec,
                             donate=not args.no_donate,
                             save_hlo=args.save_hlo or None,
                             with_buddy=args.with_buddy)
                if "skipped" in r:
                    print(f"[dryrun] {arch} × {shape} × {args.mesh}: "
                          f"SKIP ({r['skipped']})")
                else:
                    print(f"[dryrun] {arch} × {shape} × {args.mesh}: OK "
                          f"compile={r['compile_s']:.1f}s "
                          f"coll={r['collective_bytes'].get('total',0)/1e9:.2f}GB "
                          f"arg={r['memory']['argument_bytes']/1e9:.2f}GB")
            except Exception as e:      # noqa: BLE001
                failures.append((arch, shape, str(e)))
                print(f"[dryrun] {arch} × {shape} × {args.mesh}: "
                      f"FAIL {type(e).__name__}: {e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
