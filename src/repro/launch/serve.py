"""Serving launcher: batched requests through the ServeEngine."""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-demo")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="exercise serving fault tolerance")
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_config, reduced
    from repro.models.model import Model
    from repro.serve import Request, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, n_slots=args.slots,
                      max_len=args.max_len)
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=list(range(2, 2 + args.prompt_len)),
                           max_new_tokens=args.max_new))
    t0 = time.monotonic()
    steps = 0
    snap = None
    while any(s is not None for s in eng.slots) or eng.queue:
        eng.step()
        steps += 1
        if args.snapshot_every and steps % args.snapshot_every == 0:
            snap = eng.snapshot()
    dt = time.monotonic() - t0
    # count what the engine actually produced, not the nominal request
    # shape: max_len truncation can cut a generation short
    generated = sum(len(r.out) - 1 for r in eng.completed)
    print(json.dumps({
        "arch": cfg.name, "requests": args.requests,
        "completed": len(eng.completed),
        "engine_steps": steps, "wall_s": round(dt, 3),
        "tokens_generated": generated,
        "tokens_per_s": round(generated / dt, 1),
        "snapshot_taken": snap is not None,
    }, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
