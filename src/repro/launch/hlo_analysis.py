"""Post-compile HLO analysis: collective schedule with scan correction.

XLA's cost_analysis() counts a `while` (scan) body once, not × trip-count.
This module parses the optimized HLO text of a compiled executable and:

  1. extracts every collective op (all-gather / all-reduce / reduce-scatter
     / all-to-all / collective-permute) with its result byte size,
  2. builds the computation call graph (which computation is the body of
     which while, which whiles are nested in which bodies),
  3. recovers each while's trip count from the constant in its condition
     computation (XLA scan conditions compare the induction variable
     against a literal),
  4. reports per-collective totals with each body's bytes multiplied by
     the product of trip counts along its nesting path.

The same machinery corrects FLOPs/bytes when validating the analytic
roofline model against small unrolled configs.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->", re.M)


def shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[4,128,64]{2,1,0}'
    (tuples: sum of elements)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveInfo:
    kind: str
    bytes_each: int          # result bytes, one execution
    computation: str
    multiplier: int          # product of enclosing while trip counts

    @property
    def bytes_total(self) -> int:
        return self.bytes_each * self.multiplier


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text."""
    comps: Dict[str, str] = {}
    current, buf, depth = None, [], 0
    for line in hlo.splitlines():
        if current is None:
            m = _COMP_HDR.match(line.strip())
            if m and "{" in line:
                current = m.group(1)
                buf = [line]
                depth = line.count("{") - line.count("}")
                if depth <= 0:
                    comps[current] = line
                    current = None
        else:
            buf.append(line)
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                comps[current] = "\n".join(buf)
                current = None
    return comps


# The while operand list may itself contain tuple shapes (nested parens),
# so match lazily up to the `condition=`/`body=` attributes on the line.
_WHILE_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)"
    r"\s*,\s*body=%?([\w\.\-]+)(.*)$", re.M)
_CONST_RE = re.compile(r"[su]32\[\]\s+constant\((\d+)\)")
_KTC_RE = re.compile(r"known_trip_count[^\d]*(\d+)")


def _trip_count(cond_text: str, while_line_rest: str = "") -> int:
    """Trip count of a while: XLA's `known_trip_count` backend_config when
    present, else the largest integer literal in the condition (XLA scan
    conditions compare the induction variable against a literal)."""
    m = _KTC_RE.search(while_line_rest)
    if m:
        return int(m.group(1))
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def compiled_cost_analysis(compiled) -> dict:
    """Version-compat: `Compiled.cost_analysis()` returns a per-device list
    of dicts on older jax and a plain dict on newer; normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def analyze_collectives(hlo: str) -> List[CollectiveInfo]:
    comps = _split_computations(hlo)

    # while structure: body -> (trip, parent computation)
    body_info: Dict[str, Tuple[int, str]] = {}
    for cname, ctext in comps.items():
        for m in _WHILE_RE.finditer(ctext):
            cond, body = m.group(2), m.group(3)
            trip = _trip_count(comps.get(cond, ""), m.group(4))
            body_info[body] = (trip, cname)

    def multiplier(comp: str) -> int:
        mult, seen = 1, set()
        cur = comp
        while cur in body_info and cur not in seen:
            seen.add(cur)
            trip, parent = body_info[cur]
            mult *= trip
            cur = parent
        return mult

    # fused computations inherit their caller's multiplier: map each
    # computation to the computation that calls it (fusion/call sites)
    callers: Dict[str, str] = {}
    call_re = re.compile(r"(?:calls=|to_apply=|fusion[^\n]*calls=)%?"
                         r"([\w\.\-]+)")
    for cname, ctext in comps.items():
        for m in call_re.finditer(ctext):
            callee = m.group(1)
            callers.setdefault(callee, cname)

    def effective_multiplier(comp: str) -> int:
        cur, seen = comp, set()
        while cur not in body_info and cur in callers and cur not in seen:
            seen.add(cur)
            cur = callers[cur]
        return multiplier(cur)

    out: List[CollectiveInfo] = []
    coll_re = re.compile(
        r"=\s*((?:\([^)]*\))|(?:[\w\[\],\{\}]+))\s+"
        r"(" + "|".join(COLLECTIVES) + r")((?:-start|-done)?)\(")
    for cname, ctext in comps.items():
        for m in coll_re.finditer(ctext):
            shape_str, kind, suffix = m.group(1), m.group(2), m.group(3)
            if suffix == "-done":
                continue      # counted at the matching -start
            b = shape_bytes(shape_str)
            if b == 0:
                continue
            out.append(CollectiveInfo(
                kind=kind, bytes_each=b, computation=cname,
                multiplier=effective_multiplier(cname)))
    return out


def collective_summary(hlo: str) -> Dict[str, int]:
    """kind -> corrected total bytes (plus 'total')."""
    infos = analyze_collectives(hlo)
    out: Dict[str, int] = defaultdict(int)
    for i in infos:
        out[i.kind] += i.bytes_total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def while_report(hlo: str) -> List[dict]:
    """Debug view: every while with its trip count."""
    comps = _split_computations(hlo)
    out = []
    for cname, ctext in comps.items():
        for m in _WHILE_RE.finditer(ctext):
            out.append({"in": cname, "body": m.group(3),
                        "trip": _trip_count(comps.get(m.group(2), ""),
                                            m.group(4))})
    return out
