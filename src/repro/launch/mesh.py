"""Production mesh construction (a function — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax

# jax added `jax.sharding.AxisType` + the `axis_types=` kwarg on
# `jax.make_mesh` after 0.4.x; support both (pattern: kernels/_compat.py).
_AxisType = getattr(jax.sharding, "AxisType", None)


def _compat_make_mesh(shape, axes):
    if _AxisType is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(_AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_host_mesh(shape=(1,), axes=("data",)):
    """Small mesh over however many (host) devices exist — tests only."""
    n = 1
    for s in shape:
        n *= s
    if n > len(jax.devices()):
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    return _compat_make_mesh(shape, axes)
