"""Production mesh construction (a function — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=(1,), axes=("data",)):
    """Small mesh over however many (host) devices exist — tests only."""
    n = 1
    for s in shape:
        n *= s
    if n > len(jax.devices()):
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
