"""Training launchers.

Two entry points, mirroring the paper's two execution substrates:

  in-process   fault-tolerant JAX trainer on this host's devices
               (`python -m repro.launch.train --arch paper-demo ...`)
  cluster      the mpirun-analogue: deploys the root/daemon/worker tree
               with fault injection (`--cluster`), i.e. the real-process
               runtime of repro.runtime.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.core.recovery import STRATEGIES


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-demo")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--strategy", default="reinit",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=1)
    ap.add_argument("--ckpt-delta-every", type=int, default=0,
                    help="K>1: full file snapshot every K-th save, "
                         "dirty-tile delta frames between")
    ap.add_argument("--fail-kind", default="",
                    choices=["", "process", "node"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-size config variant")
    ap.add_argument("--cluster", action="store_true",
                    help="launch the real-process runtime instead")
    ap.add_argument("--report", default="")
    args = ap.parse_args(argv)

    if args.cluster:
        from repro.runtime.root import MODES, main as root_main
        # ulfm is sim-only: the cluster path runs it as reinit
        mode = args.strategy if args.strategy in MODES else "reinit"
        rt_args = ["--nodes", "2", "--ranks-per-node", "4", "--spares", "1",
                   "--steps", str(args.steps),
                   "--ckpt-dir", args.ckpt_dir,
                   "--mode", mode]
        if args.fail_kind:
            rt_args += ["--fail-step", str(max(args.steps // 2, 1)),
                        "--fail-rank", "1", "--fail-kind", args.fail_kind]
        if args.report:
            rt_args += ["--report", args.report]
        return root_main(rt_args)

    from repro.configs import get_config, reduced
    from repro.core import FaultInjector, FailureType
    from repro.models.model import Model
    from repro.train import (AdamWConfig, TokenPipeline, TrainConfig,
                             Trainer)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg)
    data = TokenPipeline(cfg.vocab_size, args.batch, args.seq,
                         seed=args.seed)
    opt = AdamWConfig(total_steps=args.steps,
                      warmup_steps=max(args.steps // 10, 1))
    tc = TrainConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, strategy=args.strategy,
                     ckpt_delta_every=args.ckpt_delta_every,
                     seed=args.seed, log_every=10)
    injector = None
    if args.fail_kind:
        injector = FaultInjector(
            n_ranks=tc.n_nodes * tc.ranks_per_node, n_steps=args.steps,
            kind=FailureType.NODE if args.fail_kind == "node"
            else FailureType.PROCESS, seed=args.seed)
    trainer = Trainer(model, data, opt, tc, injector=injector)
    result = trainer.run()
    summary = {
        "arch": cfg.name, "final_step": result["final_step"],
        "first_loss": result["losses"][0] if result["losses"] else None,
        "last_loss": result["losses"][-1] if result["losses"] else None,
        "recoveries": [
            {"strategy": r.strategy, "total_s": r.total_s,
             "rollback_step": r.rollback_step}
            for r in result["reports"]],
    }
    print(json.dumps(summary, indent=2))
    if args.report:
        with open(args.report, "w") as f:
            json.dump(summary, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
