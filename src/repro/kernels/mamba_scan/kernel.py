"""Pallas TPU selective-scan (Mamba1) kernel.

The recurrence h_t = exp(dt_t⊙A)·h_{t-1} + (dt_t⊙x_t)⊗B_t is sequential in
t but embarrassingly parallel over the d_inner channel axis. The GPU
implementation in the Mamba paper parallelizes with a work-efficient
prefix scan in shared memory; the TPU adaptation instead:

  - tiles d_inner into `block_d`-wide VMEM-resident stripes (grid axis 1),
  - streams the sequence in `chunk`-length tiles (grid axis 2, "arbitrary"
    semantics) carrying the (block_d, ds) state stripe in VMEM scratch,
  - runs the time recurrence as a fori_loop of VPU element-wise ops — on
    TPU the bottleneck is HBM streaming of x/dt (ds≤64 keeps the state in
    registers/VMEM), so a sequential-in-t loop at full VPU width is the
    roofline-appropriate schedule, not a tree scan.

VMEM per program: x,dt tiles 2·(chunk·block_d)·4B, B,C tiles 2·(chunk·ds)·4B,
A stripe block_d·ds·4B, state block_d·ds·4B → ≈1.1 MB at the default
chunk=256, block_d=512, ds=16 — comfortably inside 16 MB VMEM with double
buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref, h_ref,
                 *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)                  # (block_d, ds)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)      # (block_d,)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)        # (ds,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        dA = jnp.exp(dt_t[:, None] * A)                 # (block_d, ds)
        h = h * dA + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)         # (block_d,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == num_chunks - 1)
    def _final():
        hout_ref[0, ...] = h_ref[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def selective_scan(x, dt, B, C, A, *, chunk: int = 256, block_d: int = 512,
                   interpret: bool = False):
    """x, dt: (batch,S,di); B, C: (batch,S,ds); A: (di,ds) →
    (y (batch,S,di), h_final (batch,di,ds))."""
    bsz, S, di = x.shape
    ds = B.shape[-1]
    chunk = min(chunk, S)
    while S % chunk != 0:
        chunk //= 2
    block_d = min(block_d, di)
    while di % block_d != 0:
        block_d //= 2
    nc, nd = S // chunk, di // block_d

    kernel = functools.partial(_scan_kernel, chunk=chunk, num_chunks=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(bsz, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((block_d, ds), lambda b, d, c: (d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, block_d, ds), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, S, di), x.dtype),
            jax.ShapeDtypeStruct((bsz, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, ds), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, B, C, A)
    return y, h
