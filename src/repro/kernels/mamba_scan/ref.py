"""Pure-jnp oracle for the selective-scan kernel: straight recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, B, C, A, h0=None):
    """x, dt: (batch, S, di); B, C: (batch, S, ds); A: (di, ds).

    h_t = exp(dt_t ⊙ A) * h_{t-1} + (dt_t ⊙ x_t) ⊗ B_t
    y_t = h_t · C_t
    Returns (y (batch, S, di), h_final (batch, di, ds)); all math fp32.
    """
    bsz, S, di = x.shape
    ds = B.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf, Af = (B.astype(jnp.float32), C.astype(jnp.float32),
                  A.astype(jnp.float32))
    if h0 is None:
        h0 = jnp.zeros((bsz, di, ds), jnp.float32)

    def step(h, t):
        dA = jnp.exp(dtf[:, t][..., None] * Af)                # (b, di, ds)
        dBx = (dtf[:, t] * xf[:, t])[..., None] * Bf[:, t][:, None, :]
        h = h * dA + dBx
        y = jnp.einsum("bds,bs->bd", h, Cf[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return ys.transpose(1, 0, 2).astype(x.dtype), h
