"""Public wrapper for the selective-scan Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import selective_scan
from .ref import selective_scan_ref


def mamba_scan(x, dt, B, C, A, *, interpret: bool = False,
               chunk: int = 256, block_d: int = 512):
    """Selective scan y_t = C_t·h_t with h_t = exp(dt_t A)h_{t-1}+dt_t x_t B_t.

    x, dt: (batch, S, di); B, C: (batch, S, ds); A: (di, ds).
    Tiny shapes fall back to the jnp oracle (not worth a kernel launch).
    """
    bsz, S, di = x.shape
    if S < 8 or di < 8:
        return selective_scan_ref(x, dt, B, C, A)
    return selective_scan(x, dt, B, C, A, chunk=chunk, block_d=block_d,
                          interpret=interpret)
