"""Pallas TPU tiled-reduction checksum kernel.

Computes the (s0, s1) word-sums of `ref.py` over a uint32 word stream
entirely on device: the words are tiled into (block_rows, 128) VMEM
stripes, the grid walks the stripes sequentially ("arbitrary" semantics),
and two (1, 1) SMEM scalars accumulate

    s0 += sum(tile)
    s1 += sum(tile * (global_word_index + 1))      (all mod 2^32)

Only the two 4-byte scalars ever cross back to the host — the checkpoint
path never materializes a host-side `tobytes()` copy just to hash it.
uint32 arithmetic wraps mod 2^32 natively, which is exactly the checksum's
definition, so no masking is needed on device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

_COLS = 128


def _checksum_kernel(w_ref, s0_ref, s1_ref, *, block_rows: int):
    gi = pl.program_id(0)

    @pl.when(gi == 0)
    def _init():
        s0_ref[0, 0] = jnp.uint32(0)
        s1_ref[0, 0] = jnp.uint32(0)

    w = w_ref[...]                                   # (block_rows, 128)
    base = jnp.uint32(block_rows * _COLS) * gi.astype(jnp.uint32)
    row = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, _COLS), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, _COLS), 1)
    idx = base + row * jnp.uint32(_COLS) + col + jnp.uint32(1)
    s0_ref[0, 0] += jnp.sum(w, dtype=jnp.uint32)
    s1_ref[0, 0] += jnp.sum(w * idx, dtype=jnp.uint32)


def _tile_checksum_kernel(w_ref, out_ref):
    """One grid step = one 4 KB tile = one (8, 128) block: emit the
    tile's standalone (s0, s1, m) digest row — the local-weighted
    word-sum pair plus the nonlinear xor-shift-multiply mix column (the
    delta checkpointer compares these rows across consecutive
    snapshots)."""
    from .ref import MIX_C
    w = w_ref[...]                                   # (8, 128)
    row = jax.lax.broadcasted_iota(jnp.uint32, w.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, w.shape, 1)
    idx = row * jnp.uint32(_COLS) + col + jnp.uint32(1)
    mixed = (w ^ (w >> jnp.uint32(16))) * jnp.uint32(MIX_C)
    out_ref[0, 0] = jnp.sum(w, dtype=jnp.uint32)
    out_ref[0, 1] = jnp.sum(w * idx, dtype=jnp.uint32)
    out_ref[0, 2] = jnp.sum(mixed, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tile_checksum_kernel(words, *, interpret: bool = False):
    """words: 1-D uint32 → (n_tiles, 3) uint32 per-4KB-tile digests.

    The tile is TILE_WORDS = 8*128 words, matching `ref.tile_checksums_ref`
    bit-for-bit (trailing partial tile zero-padded). Grid steps are
    independent ("parallel" semantics); only 12 bytes per tile — 0.3% of
    the data — ever leave the device.
    """
    from .ref import TILE_WORDS
    rows_per_tile = TILE_WORDS // _COLS              # 8
    n = words.size
    nt = max(1, -(-n // TILE_WORDS))
    w2 = jnp.pad(words, (0, nt * TILE_WORDS - n)) \
        .reshape(nt * rows_per_tile, _COLS)
    return pl.pallas_call(
        _tile_checksum_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((rows_per_tile, _COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 3), lambda i: (i, 0),
                               memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((nt, 3), jnp.uint32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(w2)


def _gather_tiles_kernel(idx_ref, in_ref, out_ref):
    """Grid step i copies the one (8, 128) tile block the scalar-
    prefetched index map already DMA'd into VMEM — tile idx[i] of the
    source stream lands at row i of the compact output."""
    out_ref[...] = in_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_tiles_kernel(tiles, idx, *, interpret: bool = False):
    """tiles: (nt*8, 128) uint32 word rows; idx: (k,) int32 ascending
    tile indices → (k, TILE_WORDS) uint32 compact dirty-tile buffer.

    The dirty-tile indices are scalar-prefetched so the input BlockSpec's
    index map can read them: grid step i DMAs exactly the (8, 128) block
    of tile idx[i] from HBM and streams it to output block i. Only the
    gathered tiles ever move — the D2H copy that follows is O(dirt), not
    O(state).
    """
    from .ref import TILE_WORDS
    rows_per_tile = TILE_WORDS // _COLS              # 8
    k = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[pl.BlockSpec((rows_per_tile, _COLS),
                               lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((rows_per_tile, _COLS),
                               lambda i, idx_ref: (i, 0)),
    )
    out = pl.pallas_call(
        _gather_tiles_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k * rows_per_tile, _COLS),
                                       jnp.uint32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(idx, tiles)
    return out.reshape(k, TILE_WORDS)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def checksum_kernel(words, *, block_rows: int = 8, interpret: bool = False):
    """words: 1-D uint32 → (s0, s1) uint32 device scalars."""
    n = words.size
    rows = -(-n // _COLS)
    rows_pad = -(-rows // block_rows) * block_rows
    w2 = jnp.pad(words, (0, rows_pad * _COLS - n)).reshape(rows_pad, _COLS)

    kernel = functools.partial(_checksum_kernel, block_rows=block_rows)
    s0, s1 = pl.pallas_call(
        kernel,
        grid=(rows_pad // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, _COLS), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.uint32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(w2)
    return s0[0, 0], s1[0, 0]
