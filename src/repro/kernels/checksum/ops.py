"""Dispatch wrapper for the checkpoint checksum.

`leaf_checksum` routes each leaf to the cheapest correct implementation:

  - host numpy arrays       → vectorized numpy reference (no tobytes copy)
  - device jax arrays, TPU  → Pallas tiled-reduction kernel (on-device)
  - device jax arrays, else → jitted jnp reduction (same math, same wrap)

All three compute the identical (s0, s1) word-sum pair defined in
`ref.py`; parity is asserted in tests/test_checksum.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .ref import TILE_WORDS, checksum_words_ref, tile_checksums_ref

# Below this many words a kernel launch costs more than it saves.
_PALLAS_MIN_WORDS = 1 << 15


def _device_words(x: jax.Array) -> jax.Array:
    """Bitcast a device array to its little-endian uint32 word stream,
    zero-padded to a whole number of words (matches ref._byte_view)."""
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.uint8)
    flat = x.reshape(-1)
    isz = x.dtype.itemsize
    if isz == 4:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32)
    if isz == 8:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32).reshape(-1)
    if isz == 2:
        u16 = jax.lax.bitcast_convert_type(flat, jnp.uint16)
        if u16.size % 2:
            u16 = jnp.concatenate([u16, jnp.zeros((1,), jnp.uint16)])
        pairs = u16.reshape(-1, 2).astype(jnp.uint32)
        return pairs[:, 0] | (pairs[:, 1] << 16)
    if isz == 1:
        u8 = jax.lax.bitcast_convert_type(flat, jnp.uint8)
        pad = -u8.size % 4
        if pad:
            u8 = jnp.concatenate([u8, jnp.zeros((pad,), jnp.uint8)])
        quads = u8.reshape(-1, 4).astype(jnp.uint32)
        return (quads[:, 0] | (quads[:, 1] << 8)
                | (quads[:, 2] << 16) | (quads[:, 3] << 24))
    raise TypeError(f"unsupported itemsize {isz} for dtype {x.dtype}")


@jax.jit
def _wordsum_jnp(words):
    idx = jnp.arange(1, words.size + 1, dtype=jnp.uint32)
    s0 = jnp.sum(words, dtype=jnp.uint32)
    s1 = jnp.sum(words * idx, dtype=jnp.uint32)
    return s0, s1


def checksum_words(x, *, interpret: bool = False) -> tuple[int, int]:
    """(s0, s1) of an array's byte stream via the device path.

    `x` must be a jax array (or convertible); use `checksum_words_ref`
    for the pure-host path. `interpret=True` forces the Pallas kernel in
    interpret mode (for CPU parity testing).
    """
    words = _device_words(jnp.asarray(x))
    if words.size == 0:
        return 0, 0
    if interpret or (jax.default_backend() == "tpu"
                     and words.size >= _PALLAS_MIN_WORDS):
        # lazy: host-only digest paths never pay the pallas import
        from .kernel import checksum_kernel
        s0, s1 = checksum_kernel(words, interpret=interpret)
    else:
        s0, s1 = _wordsum_jnp(words)
    return int(s0), int(s1)


def checksum_words_device(x: jax.Array):
    """Like checksum_words but returns the (s0, s1) *device scalars*
    without forcing a host sync — the async checkpoint path enqueues the
    reduction alongside the D2H drain and int()s the result on the
    writer thread. Returns None for empty arrays (checksum (0, 0))."""
    words = _device_words(jnp.asarray(x))
    if words.size == 0:
        return None
    if (jax.default_backend() == "tpu"
            and words.size >= _PALLAS_MIN_WORDS):
        from .kernel import checksum_kernel
        return checksum_kernel(words)
    return _wordsum_jnp(words)


@jax.jit
def _tilesum_jnp(words):
    from .ref import MIX_C
    n = words.size
    nt = max(1, -(-n // TILE_WORDS))
    w = jnp.pad(words, (0, nt * TILE_WORDS - n)).reshape(nt, TILE_WORDS)
    idx = jnp.arange(1, TILE_WORDS + 1, dtype=jnp.uint32)
    mixed = (w ^ (w >> jnp.uint32(16))) * jnp.uint32(MIX_C)
    s0 = jnp.sum(w, axis=1, dtype=jnp.uint32)
    s1 = jnp.sum(w * idx, axis=1, dtype=jnp.uint32)
    m = jnp.sum(mixed, axis=1, dtype=jnp.uint32)
    return jnp.stack([s0, s1, m], axis=1)


def tile_checksums_device(x, *, interpret: bool = False):
    """Per-4KB-tile (s0, s1, mix) digests of a device array, computed on
    device and returned as *device* (n_tiles, 3) uint32 — the delta
    checkpoint path enqueues this alongside the D2H drain and
    np.asarray()s the tiny result (12 B/tile) on the writer thread.
    Returns None for empty arrays. Same values as `tile_checksums_ref`
    (parity-tested)."""
    words = _device_words(jnp.asarray(x))
    if words.size == 0:
        return None
    if interpret or (jax.default_backend() == "tpu"
                     and words.size >= _PALLAS_MIN_WORDS):
        from .kernel import tile_checksum_kernel
        return tile_checksum_kernel(words, interpret=interpret)
    return _tilesum_jnp(words)


@jax.jit
def _gather_jnp(tiles2d, idx):
    return jnp.take(tiles2d, idx, axis=0)


def _device_tiles2d(x) -> jax.Array:
    """Device array → its (n_tiles, TILE_WORDS) uint32 tile matrix,
    trailing partial tile zero-padded (same padding as the digest path,
    so tile t here is byte-identical to digest row t's input)."""
    words = _device_words(jnp.asarray(x))
    nt = max(1, -(-words.size // TILE_WORDS))
    return jnp.pad(words, (0, nt * TILE_WORDS - words.size)) \
        .reshape(nt, TILE_WORDS)


def gather_tiles_device(x, idx, *, interpret: bool = False) -> jax.Array:
    """Gather the 4 KB tiles named by `idx` (host int array, ascending)
    from a device array into one compact (len(idx), TILE_WORDS) uint32
    *device* buffer — the delta checkpointer's dirty-tile gather. The
    caller kicks copy_to_host_async on the result, so the D2H transfer
    moves only the dirty tiles (plus 12 B/tile of digest rows), never
    the full state. Parity with `gather_tiles_ref` is tested.
    """
    tiles2d = _device_tiles2d(x)
    idx = jnp.asarray(np.asarray(idx, np.int32))
    if interpret or (jax.default_backend() == "tpu"
                     and tiles2d.size >= _PALLAS_MIN_WORDS):
        from .kernel import gather_tiles_kernel
        return gather_tiles_kernel(
            tiles2d.reshape(-1, 128), idx, interpret=interpret)
    return _gather_jnp(tiles2d, idx)


def tile_checksums(arr) -> np.ndarray:
    """Type-dispatching per-tile digest entry point (host ndarray out):
    device arrays stay on device for the reduction, host arrays go through
    the vectorized numpy reference."""
    if isinstance(arr, jax.Array):
        try:
            t = tile_checksums_device(arr)
            return np.zeros((0, 3), np.uint32) if t is None \
                else np.asarray(t)
        except TypeError:       # exotic itemsize — fall through to host
            pass
    return tile_checksums_ref(np.asarray(arr))


def leaf_checksum(arr) -> tuple[int, int]:
    """Type-dispatching entry point used by checkpoint.manifest."""
    if isinstance(arr, jax.Array):
        try:
            return checksum_words(arr)
        except TypeError:       # exotic itemsize — fall through to host
            pass
    return checksum_words_ref(np.asarray(arr))
