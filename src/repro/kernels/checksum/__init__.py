from .ops import checksum_words, leaf_checksum
from .ref import checksum_words_ref

__all__ = ["checksum_words", "checksum_words_ref", "leaf_checksum"]
