"""Numpy reference for the word-sum checkpoint checksum.

The checksum is defined over the little-endian byte stream of a
C-contiguous array, zero-padded to a multiple of 4 bytes and read as
uint32 words w[0..n):

    s0 = sum_i w_i                  (mod 2^32)
    s1 = sum_i (i + 1) * w_i        (mod 2^32)

s0 is the Fletcher-style content sum; the (i+1) weighting in s1 makes the
pair order-sensitive (a swap of two unequal words changes s1) while both
terms stay pure tiled reductions — each tile contributes

    s1_tile = local_weighted_sum + tile_base_index * s0_tile

so the whole digest parallelizes over VMEM-resident tiles on device and
over vectorized chunks here. Trailing zero words alias with padding, which
is harmless: the digest string mixes in dtype and shape (hence byte
length) before hashing.

This module is pure numpy — it is both the host fallback used by
`checkpoint.manifest` for host-resident leaves and the oracle the Pallas
kernel is tested against.
"""
from __future__ import annotations

import numpy as np

M32 = 0xFFFFFFFF
_CHUNK_WORDS = 1 << 20          # 4 MB per chunk keeps temporaries cache-friendly


def byte_view(arr: np.ndarray) -> np.ndarray:
    """Flat uint8 view of the array's bytes (copy only if non-contiguous).
    Shared by the digest path and checkpoint serde so both always see the
    identical byte stream."""
    a = np.ascontiguousarray(arr)
    return a.reshape(-1).view(np.uint8)


_ARANGE = np.arange(1, _CHUNK_WORDS + 1, dtype=np.uint32)   # reused weights


def checksum_words_ref(arr: np.ndarray) -> tuple[int, int]:
    """(s0, s1) word-sums of `arr`'s byte stream. Vectorized, no tobytes.

    Per chunk at base index B:  sum(w * (B + j)) = sum(w * j) + B * sum(w)
    (all mod 2^32), so each chunk needs one uint32 wrap-multiply by a
    precomputed 1..N weight vector and two SIMD sums — no uint64
    temporaries, ~4 memory passes total.
    """
    b = byte_view(np.asarray(arr))
    nbytes = b.size
    n_main = (nbytes // 4) * 4
    s0 = 0
    s1 = 0
    words = b[:n_main].view(np.uint32)
    for start in range(0, words.size, _CHUNK_WORDS):
        w = words[start:start + _CHUNK_WORDS]
        c0 = int(w.sum(dtype=np.uint64)) & M32
        local = int(np.multiply(w, _ARANGE[:w.size], dtype=np.uint32)
                    .sum(dtype=np.uint64)) & M32
        s0 = (s0 + c0) & M32
        s1 = (s1 + local + start * c0) & M32
    tail = b[n_main:]
    if tail.size:
        w_tail = int.from_bytes(tail.tobytes(), "little")
        i_tail = words.size + 1
        s0 = (s0 + w_tail) & M32
        s1 = (s1 + i_tail * w_tail) & M32
    return s0, s1
