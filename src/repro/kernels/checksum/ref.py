"""Numpy reference for the word-sum checkpoint checksum.

The checksum is defined over the little-endian byte stream of a
C-contiguous array, zero-padded to a multiple of 4 bytes and read as
uint32 words w[0..n):

    s0 = sum_i w_i                  (mod 2^32)
    s1 = sum_i (i + 1) * w_i        (mod 2^32)

s0 is the Fletcher-style content sum; the (i+1) weighting in s1 makes the
pair order-sensitive (a swap of two unequal words changes s1) while both
terms stay pure tiled reductions — each tile contributes

    s1_tile = local_weighted_sum + tile_base_index * s0_tile

so the whole digest parallelizes over VMEM-resident tiles on device and
over vectorized chunks here. Trailing zero words alias with padding, which
is harmless: the digest string mixes in dtype and shape (hence byte
length) before hashing.

This module is pure numpy — it is both the host fallback used by
`checkpoint.manifest` for host-resident leaves and the oracle the Pallas
kernel is tested against.
"""
from __future__ import annotations

import numpy as np

M32 = 0xFFFFFFFF
_CHUNK_WORDS = 1 << 20          # 4 MB per chunk keeps temporaries cache-friendly

# Delta-checkpoint tile: 1024 words = 4 KB. Matches the Pallas kernel's
# (8, 128) grid block exactly, so one device pass yields both the per-tile
# digests and (via scalar_from_tiles) the whole-leaf digest.
TILE_WORDS = 1 << 10
TILE_BYTES = TILE_WORDS * 4


def byte_view(arr: np.ndarray) -> np.ndarray:
    """Flat uint8 view of the array's bytes (copy only if non-contiguous).
    Shared by the digest path and checkpoint serde so both always see the
    identical byte stream."""
    a = np.ascontiguousarray(arr)
    return a.reshape(-1).view(np.uint8)


_ARANGE = np.arange(1, _CHUNK_WORDS + 1, dtype=np.uint32)   # reused weights


def checksum_words_ref(arr: np.ndarray) -> tuple[int, int]:
    """(s0, s1) word-sums of `arr`'s byte stream. Vectorized, no tobytes.

    Per chunk at base index B:  sum(w * (B + j)) = sum(w * j) + B * sum(w)
    (all mod 2^32), so each chunk needs one uint32 wrap-multiply by a
    precomputed 1..N weight vector and two SIMD sums — no uint64
    temporaries, ~4 memory passes total.
    """
    b = byte_view(np.asarray(arr))
    nbytes = b.size
    n_main = (nbytes // 4) * 4
    s0 = 0
    s1 = 0
    words = b[:n_main].view(np.uint32)
    for start in range(0, words.size, _CHUNK_WORDS):
        w = words[start:start + _CHUNK_WORDS]
        c0 = int(w.sum(dtype=np.uint64)) & M32
        local = int(np.multiply(w, _ARANGE[:w.size], dtype=np.uint32)
                    .sum(dtype=np.uint64)) & M32
        s0 = (s0 + c0) & M32
        s1 = (s1 + local + start * c0) & M32
    tail = b[n_main:]
    if tail.size:
        w_tail = int.from_bytes(tail.tobytes(), "little")
        i_tail = words.size + 1
        s0 = (s0 + w_tail) & M32
        s1 = (s1 + i_tail * w_tail) & M32
    return s0, s1


_TILE_ARANGE = np.arange(1, TILE_WORDS + 1, dtype=np.uint32)

# Odd (invertible mod 2^32) diffusion constant for the nonlinear mix
# column — the golden-ratio multiplier.
MIX_C = np.uint32(0x9E3779B1)


def n_tiles(nbytes: int) -> int:
    """Tile count of an nbytes-long byte stream (ceil over 4 KB tiles)."""
    return max(1, -(-nbytes // TILE_BYTES)) if nbytes else 0


def _mix(w: np.ndarray) -> np.ndarray:
    """Nonlinear per-word mix: x ^= x >> 16; x *= MIX_C (mod 2^32)."""
    return np.multiply(w ^ (w >> np.uint32(16)), MIX_C, dtype=np.uint32)


def tile_checksums_ref(arr: np.ndarray) -> np.ndarray:
    """Per-tile (s0, s1, m) digests of `arr`'s byte stream.

    Each TILE_WORDS-word tile is digested as a standalone word stream:
    s0/s1 are the local-weighted word-sum pair of `checksum_words_ref`
    (so `scalar_from_tiles` folds them back into the whole-leaf digest),
    and m = sum(mix(w)) is a *nonlinear* mix column. The mix is what
    makes dirtiness detection sound against structured updates: a
    uniform shift of every word in a tile (e.g. float32 `x *= 2` bumps
    each exponent, adding 2^23 to every word — and 1024 * 2^23 ≡ 0 mod
    2^32) is invisible to any linear-in-words sum, but scatters under
    xor-shift-multiply. Equal rows between two snapshots mean the tile
    is clean (up to the 96-bit digest).

    Returns shape (n_tiles, 3) uint32; a trailing partial tile is
    zero-padded (harmless: padding contributes 0 to all three columns
    and the byte length is fixed by the leaf's dtype/shape).
    """
    b = byte_view(np.asarray(arr))
    nbytes = b.size
    nt = n_tiles(nbytes)
    if nt == 0:
        return np.zeros((0, 3), np.uint32)
    out = np.zeros((nt, 3), np.uint32)
    n_main = (nbytes // 4) * 4
    words = b[:n_main].view(np.uint32)
    full = words.size // TILE_WORDS
    if full:
        w = words[:full * TILE_WORDS].reshape(full, TILE_WORDS)
        out[:full, 0] = w.sum(axis=1, dtype=np.uint64) & M32
        out[:full, 1] = np.multiply(w, _TILE_ARANGE,
                                    dtype=np.uint32) \
            .sum(axis=1, dtype=np.uint64) & M32
        out[:full, 2] = _mix(w).sum(axis=1, dtype=np.uint64) & M32
    rest = words[full * TILE_WORDS:]
    tail = b[n_main:]
    if rest.size or tail.size:
        s0 = int(rest.sum(dtype=np.uint64)) & M32
        s1 = int(np.multiply(rest, _TILE_ARANGE[:rest.size],
                             dtype=np.uint32).sum(dtype=np.uint64)) & M32
        m = int(_mix(rest).sum(dtype=np.uint64)) & M32
        if tail.size:
            w_tail = int.from_bytes(tail.tobytes(), "little")
            s0 = (s0 + w_tail) & M32
            s1 = (s1 + (rest.size + 1) * w_tail) & M32
            m = (m + int(_mix(np.array([w_tail], np.uint32))[0])) & M32
        out[full, 0] = s0
        out[full, 1] = s1
        out[full, 2] = m
    return out


def gather_tiles_ref(arr: np.ndarray, idx) -> np.ndarray:
    """Gather the 4 KB tiles named by `idx` (ascending tile indices) from
    `arr`'s byte stream into one compact (len(idx), TILE_WORDS) uint32
    buffer, trailing partial tile zero-padded — the numpy oracle for the
    on-device dirty-tile gather (`ops.gather_tiles_device`)."""
    b = byte_view(np.asarray(arr))
    idx = np.asarray(idx, np.int64)
    nt = n_tiles(b.size)
    pad = nt * TILE_BYTES - b.size
    if pad:
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    tiles = b.view(np.uint32).reshape(nt, TILE_WORDS)
    return tiles[idx]


def scalar_from_tiles(tiles: np.ndarray) -> tuple[int, int]:
    """Fold per-tile digests into the whole-stream (s0, s1) pair (the mix
    column is dirtiness-only and does not participate).

    Tile t's local weights j+1 relate to global weights t*W + j + 1 by
        s1 = sum_t (s1_t + t*W * s0_t)    (mod 2^32)
    so the scalar digest costs nothing beyond the tiled pass. Bit-equal to
    `checksum_words_ref` on the same byte stream (asserted in tests).
    """
    t = np.asarray(tiles, dtype=np.uint64)
    if t.size == 0:
        return 0, 0
    s0 = int(t[:, 0].sum()) & M32
    base = (np.arange(t.shape[0], dtype=np.uint64) * TILE_WORDS) & M32
    s1 = int(((t[:, 1] + base * t[:, 0]) & M32).sum()) & M32
    return s0, s1
