"""Pallas API drift shims shared by all kernel packages.

jax renamed `pltpu.TPUCompilerParams` to `pltpu.CompilerParams`; support
both so the kernels run on whichever jax the image bakes in.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
