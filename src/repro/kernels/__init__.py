"""Pallas TPU kernels for the compute hot-spots.

  flash_attention/  causal block-skipping flash attention (forward):
                    kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
                    ops.py (jit'd GQA-aware wrapper), ref.py (jnp oracle)
  mamba_scan/       Mamba1 selective scan: d_inner-striped VMEM state,
                    sequence streamed in chunks (TPU adaptation of the
                    paper's GPU shared-memory prefix scan)

Both are validated in interpret mode on CPU against their oracles
(tests/test_kernels.py, tests/test_mamba_kernel_integration.py) and sweep
shapes/dtypes; on TPU they compile to Mosaic.
"""
