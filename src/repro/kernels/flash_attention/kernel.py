"""Pallas TPU flash-attention kernel (forward).

Tiling (BlockSpec → VMEM):
  grid = (B·H, Sq/bq, Sk/bk), k-blocks innermost ("arbitrary" semantics so
  the online-softmax carry in VMEM scratch is legal).
  q tile  (bq, hd)   — one VMEM-resident query block per (bh, qi)
  k tile  (bk, hd)   — streamed over the ki axis
  v tile  (bk, hd)
  scratch: acc (bq, hd) f32, m (bq, 128) f32, l (bq, 128) f32

GQA is handled in the k/v index_map: query head h reads kv head h // rep,
so K/V tiles are never replicated in HBM — the MXU sees the shared tile.
Causal masking is two-level: whole k-blocks strictly above the diagonal are
skipped with @pl.when (no FLOPs for masked tiles), and the diagonal block is
masked element-wise with iota.

MXU alignment: bq, bk default to 128; hd ∈ {64, 112, 128} keeps the last
dim on the 128-lane axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, sm_scale: float, block_q: int, block_k: int,
                  q_offset: int, num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute (key-aligned) position of this tile's first query/key
    q_start = qi * block_q + q_offset
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)                       # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                       # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                        # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_ref[:, 0]                                    # (bq,)
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])                         # (bq, bk)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        v = v_ref[0].astype(jnp.float32)                        # (bk, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    if causal:
        # skip k-blocks entirely above the diagonal of this q tile
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "n_q_heads",
                     "interpret", "q_offset"))
def flash_attention_bhsd(q, k, v, *, causal: bool, n_q_heads: int,
                         block_q: int = 128, block_k: int = 128,
                         q_offset: int = 0, interpret: bool = False):
    """Flattened layout: q (B·H, Sq, hd); k, v (B·Hkv, Sk, hd)."""
    BH, Sq, hd = q.shape
    BHkv, Sk, _ = k.shape
    H = n_q_heads
    B = BH // H
    Hkv = BHkv // B
    rep = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    sm_scale = 1.0 / (hd ** 0.5)

    def kv_index(bh, qi, ki):
        b = bh // H
        kvh = (bh % H) // rep
        return (b * Hkv + kvh, ki, 0)

    kernel = functools.partial(
        _flash_kernel, causal=causal, sm_scale=sm_scale, block_q=block_q,
        block_k=block_k, q_offset=q_offset + (Sk - Sq), num_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
