"""Pure-jnp oracle for the flash attention kernel (full-materialization)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True) -> jnp.ndarray:
    """q: (B,Sq,H,hd); k,v: (B,Sk,Hkv,hd) -> (B,Sq,H,hd).

    GQA: head h of q attends to kv head h // (H // Hkv). Softmax in fp32.
    Query position i is aligned to key position i + (Sk - Sq) so a query
    suffix against a longer KV prefix masks correctly.
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if causal:
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kpos = jnp.arange(Sk)[None, :]
        scores = jnp.where(kpos <= qpos, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
