"""jit'd public wrapper around the Pallas flash-attention kernel.

Accepts the model-layer layout q (B,Sq,H,hd), k/v (B,Sk,Hkv,hd); flattens
batch×head, pads hd/seq to hardware-aligned tiles when necessary, and
dispatches to the kernel. `interpret=True` runs the kernel body in Python on
CPU (how this container validates it); on a real TPU it compiles to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd
from .ref import flash_attention_ref


def _pick_block(s: int, target: int = 128) -> int:
    b = min(target, s)
    while s % b != 0:
        b //= 2
    return max(b, 1)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jnp.ndarray:
    """q: (B,Sq,H,hd); k,v: (B,Sk,Hkv,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    if bq < 8 or bk < 8:
        # degenerate tiny shapes: not worth a kernel launch
        return flash_attention_ref(q, k, v, causal=causal)

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    of = flash_attention_bhsd(qf, kf, vf, causal=causal, n_q_heads=H,
                              block_q=bq, block_k=bk, interpret=interpret)
    return of.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
