"""Failure detectors for the three substrates.

- ChildMonitor: daemon-side, POSIX wait-based (SIGCHLD semantics) — detects
  crashed child worker processes.
- ChannelMonitor: root-side, detects broken daemon control channels (proxy
  for node failures).
- HeartbeatModel: ULFM-style always-on heartbeat — not used by Reinit++
  (one of the paper's findings is precisely that its absence keeps
  fault-free time clean); the trainer/sim charge its overhead to the ULFM
  strategy.
- FaultInjector: the paper's evaluation methodology (§4 "Emulating
  failures"): at a pre-drawn random iteration, a pre-drawn random rank (or
  its node) is killed. Deterministic per seed so every strategy sees the
  identical failure.
"""
from __future__ import annotations

import dataclasses
import os
import random
import signal
import threading
import time
from typing import Callable, Dict, Optional

from .events import FailureEvent, FailureType


class ChildMonitor:
    """Watches child PIDs; invokes callback(rank, pid, returncode) when one
    dies. Poll-based (portable SIGCHLD equivalent) with a tight period."""

    def __init__(self, on_death: Callable[[int, int, int], None],
                 period_s: float = 0.02):
        self._children: Dict[int, int] = {}       # rank -> pid
        self._on_death = on_death
        self._period = period_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def watch(self, rank: int, pid: int):
        with self._lock:
            self._children[rank] = pid

    def unwatch(self, rank: int):
        with self._lock:
            self._children.pop(rank, None)

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)

    def _loop(self):
        while not self._stop.is_set():
            dead = []
            with self._lock:
                items = list(self._children.items())
            for rank, pid in items:
                try:
                    got, status = os.waitpid(pid, os.WNOHANG)
                    if got == pid:
                        dead.append((rank, pid, status))
                except ChildProcessError:
                    dead.append((rank, pid, -1))
            for rank, pid, status in dead:
                self.unwatch(rank)
                self._on_death(rank, pid, status)
            self._stop.wait(self._period)


class ChannelMonitor:
    """Root-side liveness via open channels: a broken/EOF channel marks the
    daemon (and transitively its node) failed."""

    def __init__(self, on_daemon_death: Callable[[str], None]):
        self._on_death = on_daemon_death
        self._alive: Dict[str, bool] = {}

    def register(self, daemon: str):
        self._alive[daemon] = True

    def channel_broken(self, daemon: str):
        if self._alive.get(daemon):
            self._alive[daemon] = False
            self._on_death(daemon)


@dataclasses.dataclass(frozen=True)
class HeartbeatModel:
    """ULFM-style heartbeat cost model [Bosilca et al., 2018]: each rank
    observes its successor on a period; the always-on observation plus the
    fault-tolerant wrappers around communication primitives inflate
    fault-free execution — measurably so at scale (paper Fig. 5).

    per_step_overhead(n) is charged to every application step under ULFM:
    a fixed wrapper cost plus a slowly growing term for network noise on
    larger rings (empirical fit to Fig. 5's divergence).
    """
    period_s: float = 0.1
    wrapper_cost_s: float = 2.0e-4
    noise_coeff_s: float = 8.0e-4

    def per_step_overhead(self, n_ranks: int) -> float:
        import math
        return self.wrapper_cost_s + self.noise_coeff_s * math.log2(max(n_ranks, 2)) ** 2

    def detection_latency(self) -> float:
        """Expected time to observe a dead neighbour: half a period."""
        return self.period_s / 2


@dataclasses.dataclass
class ScenarioInjector:
    """Replays a declarative Scenario's step-triggered faults against an
    in-process driver (the trainer / the simulator): `check(step, view)`
    returns the FailureEvent of the first un-fired fault due at `step`.

    This is the generalization of the original single-(step, rank)
    FaultInjector: any number of faults, rank or node targets, each fired
    exactly once — the scenario file, not code, decides the shape.
    Phase-point faults (mid-checkpoint-write, mid-recovery) don't flow
    through check(); they fire through repro.scenarios.hooks at the named
    interruption points of the real runtime."""
    scenario: "object"                 # scenarios.schema.Scenario
    enabled: bool = True

    def __post_init__(self):
        self._fired: set = set()
        self._fired_repairs: set = set()

    def reset(self):
        self._fired.clear()
        self._fired_repairs.clear()
        self.enabled = True

    def _to_event(self, f, step, view) -> FailureEvent:
        if f.target == "node":
            node = view.parent(f.rank) if view is not None else None
            return FailureEvent(kind=FailureType.NODE, node=node,
                                rank=f.rank, at_step=step)
        return FailureEvent(kind=FailureType.PROCESS, rank=f.rank,
                            at_step=step)

    def check(self, step: int, view=None) -> Optional[FailureEvent]:
        return self.check_point("step", step=step, view=view)

    def check_point(self, point: str, step: Optional[int] = None,
                    view=None, eligible=None) -> Optional[FailureEvent]:
        """First un-fired fault due at the named interruption point —
        `step` faults at the top of iteration N, checkpoint-phase faults
        at the matching save step, cascade faults (step=None wildcard) at
        their first firing opportunity during a recovery. This is how the
        in-process trainer reaches the same injection points the real
        runtime fires through repro.scenarios.hooks.

        `eligible(fault) -> bool` defers a matching fault without
        claiming it (e.g. a cascade whose victim rank is currently
        dropped from the world: its next incarnation only exists at the
        grow that re-admits it, where the next check fires it)."""
        if not self.enabled:
            return None
        # method-level import: schema -> core.recovery -> core/__init__
        # -> failure would cycle at module import time
        from repro.scenarios.schema import GRAY_HOWS
        for i, f in enumerate(self.scenario.faults):
            if i in self._fired or f.point != point \
                    or f.target == "root":
                continue
            if f.how in GRAY_HOWS:
                # gray faults degrade, they never kill: the trainer/sim
                # apply them through the straggler path, not as events
                continue
            if f.step is not None and step is not None and f.step != step:
                continue
            if eligible is not None and not eligible(f):
                continue
            self._fired.add(i)
            return self._to_event(f, step, view)
        return None

    def check_repair(self, step: int):
        """The node repair (if any) due at `step`'s checkpoint boundary —
        fired exactly once; the elastic driver turns it into a REJOIN ->
        GROW / spare-grant transition."""
        if not self.enabled:
            return None
        for i, r in enumerate(getattr(self.scenario, "repairs", ())):
            if i in self._fired_repairs or r.step != step:
                continue
            self._fired_repairs.add(i)
            return r
        return None


@dataclasses.dataclass
class FaultInjector(ScenarioInjector):
    """Pre-draws (step, rank) so every strategy replays the same failure —
    the paper's §4 methodology, kept as a thin shim over ScenarioInjector:
    the drawn (step, rank) becomes a one-fault Scenario.

    kind=NODE kills the rank's whole node (the paper has the victim signal
    its parent daemon instead of itself).
    """
    scenario: "object" = None          # synthesized in __post_init__
    n_ranks: int = 0
    n_steps: int = 0
    kind: FailureType = FailureType.PROCESS
    seed: int = 0

    def __post_init__(self):
        from repro.scenarios.schema import Fault, Scenario, Topology
        rng = random.Random(self.seed)
        lo = max(1, self.n_steps // 4)
        hi = max(lo + 1, (3 * self.n_steps) // 4)
        self.fail_step = rng.randint(lo, hi)
        self.fail_rank = rng.randrange(self.n_ranks)
        target = "node" if self.kind is FailureType.NODE else "rank"
        self.scenario = Scenario(
            name=f"drawn-seed{self.seed}",
            topology=Topology(nodes=1, ranks_per_node=self.n_ranks,
                              spares=0),
            steps=max(self.n_steps, self.fail_step + 1),
            faults=(Fault(target, self.fail_rank, self.fail_step),),
        )
        super().__post_init__()

    def check(self, step: int, view=None) -> Optional[FailureEvent]:
        ev = super().check(step, view)
        if ev is not None:
            self.enabled = False      # single failure per run (paper §4)
        return ev


def kill_process(pid: int):
    """SIGKILL — the injection primitive used by the process runtime."""
    os.kill(pid, signal.SIGKILL)
