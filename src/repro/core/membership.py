"""Cluster membership as a first-class, bidirectional state machine.

Before this module the membership/epoch bookkeeping was smeared across
the root (a `world_ranks` set mutated by three recovery paths), the
worker (its own `world_ranks` list adopted from broadcasts) and the
`ElasticManager` (spare-pool consultation only, one-way: a shrunk world
could never grow back).  `MembershipMachine` centralizes all of it:

    states       the current world (rank-id set), the spare pool, the
                 ranks currently *dropped* out of the world, and the
                 mesh epoch that keys compiled-step caches
    transitions  node_loss / rank_loss  -> respawn | shrink
                 rejoin (repaired node) -> grow | spare_grant
    invariants   floor <= |world| <= |initial world|
                 mesh epoch strictly monotonic across re-meshing
                 world == initial - dropped (shrink/grow round-trips
                 restore exactly the pre-shrink cut)

The same machine drives the real root (`--mode shrink`), the in-process
trainer and the discrete-event simulator, so the property tests in
`tests/test_membership.py` state the protocol invariants once and every
substrate inherits them.

Worker-side, `RankMembership` is the rank's adopted view of the same
state: world membership + recovery epoch, updated only through the
root's broadcasts (RANK_TABLE / SHRINK / GROW), never locally invented.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from .events import FailureEvent, FailureType, GrowCommand, PromoteCommand, \
    ReinitCommand, ShrinkCommand
from .protocol import ClusterView, root_handle_failure, \
    root_handle_failure_promote, root_handle_failure_shrink, \
    root_handle_rejoin


@dataclasses.dataclass
class MeshEpoch:
    """One incarnation of the device mesh. The epoch is the compiled-step
    cache key: recovery that re-forms the mesh bumps the epoch, anything
    that keeps it (Reinit++ process recovery) reuses compiled artifacts."""
    epoch: int
    data_parallel: int
    model_parallel: int
    pods: int = 1

    @property
    def n_shards(self) -> int:
        return self.pods * self.data_parallel * self.model_parallel


@dataclasses.dataclass(frozen=True)
class Transition:
    """One audited membership transition (the machine's history log)."""
    kind: str        # respawn | shrink | grow | spare | shadow |
                     # shadow_lost | promote
    trigger: str                     # node_loss | rank_loss | rejoin
    epoch: int                       # cluster-view epoch after
    mesh_epoch: int                  # mesh epoch after
    world: tuple                     # rank ids after
    dropped: tuple = ()              # ranks leaving the world (shrink)
    added: tuple = ()                # ranks re-admitted (grow)


class MembershipMachine:
    """Root-side membership + mesh-epoch state machine (see module doc).

    `decide` is pure policy; `respawn`/`shrink`/`grow`/`grant_spare`
    execute a transition (mutating the ClusterView through the protocol
    functions, which the simulator and runtime share) and append it to
    the audit log. `check_invariants` is called after every transition
    and is what the property suite hammers."""

    def __init__(self, view: ClusterView, mesh: MeshEpoch, *,
                 min_data_parallel: int = 1,
                 ranks_per_node: Optional[int] = None):
        self.view = view
        self.mesh = mesh
        self.min_data_parallel = min_data_parallel
        # group width used by the world-size floor and by grow capacity;
        # the root builds the mesh with model_parallel == ranks-per-node
        self.ranks_per_node = ranks_per_node if ranks_per_node is not None \
            else mesh.model_parallel
        self.initial_world: tuple = tuple(sorted(view.ranks()))
        # rank groups currently outside the world, in drop order. Each
        # entry is (home_node, ranks): one shrink = one group = one
        # consistent cut, so a grow re-admits whole groups — its own
        # node's group when that node rejoins, else the most recently
        # dropped one (whose cut the survivors still hold pinned).
        # home_node is None for process-level drops (their node lives).
        self._drop_groups: List[tuple] = []
        # pre-admitted warm members: rank -> daemon hosting its shadow.
        # Shadows are *outside* the world (they hold state, not a rank
        # id) until a promote transition swaps them in.
        self._shadows: dict = {}
        self.log: List[Transition] = []

    @property
    def dropped(self) -> List[int]:
        """Ranks currently outside the world, in drop order."""
        return [r for _, ranks in self._drop_groups for r in ranks]

    # ----------------------------------------------------------- queries

    @property
    def floor_world(self) -> int:
        """Smallest legal world: `min_data_parallel` whole groups."""
        return self.min_data_parallel * self.ranks_per_node

    def world(self) -> tuple:
        return tuple(self.view.ranks())

    def spares(self) -> list:
        return self.view.spares()

    def _lost_count(self, failure: FailureEvent) -> int:
        if failure.kind is FailureType.NODE:
            return len(self.view.children.get(failure.node, ()))
        return 1

    # ------------------------------------------------------------ policy

    def decide(self, failure: FailureEvent) -> str:
        """The spare-pool consultation of §3.2, extended past the paper:

          "respawn"  a spare slot (or a surviving host) can absorb the
                     loss — global-restart recovery re-hosts the failed
                     ranks (Algorithm 1);
          "shrink"   the spare pool is exhausted and the world can still
                     legally contract — the lost ranks (a whole node's,
                     or a single rank's, leaving uneven groups) are
                     dropped and survivors re-balance.

        Falls back to "respawn" (over-subscription / in-place respawn)
        when shrinking would cross the `min_data_parallel` world floor."""
        if self.spares():
            return "respawn"
        lost = self._lost_count(failure)
        if len(self.world()) - lost >= self.floor_world and lost > 0:
            return "shrink"
        return "respawn"

    def admit(self, node: str) -> str:
        """Root-side admission policy for a REJOIN: a repaired node grows
        the world back while ranks are missing from it, and otherwise
        joins the spare pool."""
        return "grow" if self.dropped else "spare"

    @property
    def shadows(self) -> dict:
        """rank -> daemon hosting that rank's warm shadow (read-only)."""
        return dict(self._shadows)

    def can_promote(self, failure: FailureEvent) -> bool:
        """True iff every rank lost by `failure` has a warm shadow — the
        precondition of the zero-rollback path. A rank without one falls
        back to decide() (respawn/shrink)."""
        if failure.kind is FailureType.NODE:
            lost = self.view.children.get(failure.node, set())
            return bool(lost) and all(
                self._shadows.get(r) not in (None, failure.node)
                for r in lost)
        return failure.rank in self._shadows

    # ------------------------------------------------------- transitions

    def respawn(self, failure: FailureEvent) -> ReinitCommand:
        """Global-restart (paper): same world, failed ranks re-hosted.
        Mesh epoch only bumps for node failures (device set changed)."""
        cmd = root_handle_failure(self.view, failure)
        if failure.kind is FailureType.NODE:
            self.mesh = dataclasses.replace(self.mesh,
                                            epoch=self.mesh.epoch + 1)
        trigger = "node_loss" if failure.kind is FailureType.NODE \
            else "rank_loss"
        self._record("respawn", trigger)
        return cmd

    def shrink(self, failure: FailureEvent) -> ShrinkCommand:
        """Contract the world by the lost ranks (node group or single
        rank — the latter leaves uneven rank-per-node groups). Always
        bumps the mesh epoch: the logical world changed, compiled steps
        keyed on the old shape are invalid."""
        lost = self._lost_count(failure)
        assert len(self.world()) - lost >= self.floor_world, \
            f"shrink below floor {self.floor_world}"
        cmd = root_handle_failure_shrink(self.view, failure)
        dp = self.mesh.data_parallel
        # dp tracks whole data-parallel groups, symmetrically with
        # grow(): only a full node group moves it — partial groups
        # (uneven worlds) leave it conservative
        if failure.kind is FailureType.NODE and dp > 1 \
                and len(cmd.dropped) == self.ranks_per_node:
            dp -= 1
        self.mesh = dataclasses.replace(self.mesh,
                                        epoch=self.mesh.epoch + 1,
                                        data_parallel=dp)
        home = failure.node if failure.kind is FailureType.NODE else None
        self._drop_groups.append((home, tuple(sorted(cmd.dropped))))
        trigger = "node_loss" if failure.kind is FailureType.NODE \
            else "rank_loss"
        self._record("shrink", trigger, dropped=cmd.dropped)
        return cmd

    def grow(self, node: str) -> GrowCommand:
        """Re-admit one dropped group onto a repaired node (REJOIN ->
        GROW): the rejoined node's own group when it is among the drops,
        else the most recently dropped one — in both cases a group whose
        consistent cut the survivors still hold pinned, so the grow
        consensus lands exactly back on it. Never mixes ranks from
        different shrinks (different cuts) into one grow. Bumps the mesh
        epoch; restores a data-parallel degree when a full node group
        returns."""
        assert self._drop_groups, \
            "grow with no dropped ranks (use grant_spare)"
        idx = next((i for i in range(len(self._drop_groups) - 1, -1, -1)
                    if self._drop_groups[i][0] == node),
                   len(self._drop_groups) - 1)
        _, added = self._drop_groups.pop(idx)
        cmd = root_handle_rejoin(self.view, node, added)
        dp = self.mesh.data_parallel
        if len(added) == self.ranks_per_node:
            dp += 1
        self.mesh = dataclasses.replace(self.mesh,
                                        epoch=self.mesh.epoch + 1,
                                        data_parallel=dp)
        cmd = dataclasses.replace(cmd, mesh_epoch=self.mesh.epoch)
        self._record("grow", "rejoin", added=added)
        return cmd

    def admit_shadow(self, rank: int, node: str):
        """Pre-admit a warm shadow for `rank`, hosted on `node` (normally
        a spare). Shadows are warm state outside the world: no epoch or
        mesh change — membership is untouched until a promote."""
        assert rank in self.world(), f"shadow for unknown rank {rank}"
        assert node in self.view.children, f"shadow on unknown node {node}"
        assert node != self.view.parent(rank), \
            f"shadow for rank {rank} co-hosted with its primary"
        self._shadows[rank] = node
        self._record("shadow", "admit", added=(rank,))

    def shadow_lost(self, rank: int):
        """A shadow died (or its host did): the rank loses replica
        protection and future failures fall back to decide()."""
        if self._shadows.pop(rank, None) is not None:
            self._record("shadow_lost", "shadow_loss", dropped=(rank,))

    def promote(self, failure: FailureEvent) -> PromoteCommand:
        """Zero-rollback failover: the failed ranks' warm shadows take
        over their rank ids in place. The world's rank set and the mesh
        shape are unchanged, so the mesh epoch does NOT bump — compiled
        steps everywhere stay valid. Consumes the shadows."""
        assert self.can_promote(failure), f"no warm shadow for {failure}"
        cmd = root_handle_failure_promote(self.view, failure, self._shadows)
        for p in cmd.promotions:
            self._shadows.pop(p.rank, None)
        # a dead node also takes down any shadows it hosted
        if failure.kind is FailureType.NODE:
            for r, host in list(self._shadows.items()):
                if host == failure.node:
                    self._shadows.pop(r)
        trigger = "node_loss" if failure.kind is FailureType.NODE \
            else "rank_loss"
        self._record("promote", trigger,
                     added=tuple(p.rank for p in cmd.promotions))
        return cmd

    def grant_spare(self, node: str):
        """A repaired node rejoins a full world: it becomes an (empty)
        over-provisioned spare. No epoch or mesh change — nothing about
        the running world moved."""
        self.view.children.setdefault(node, set())
        self._record("spare", "rejoin")

    # --------------------------------------------------------- integrity

    def _record(self, kind: str, trigger: str, *, dropped=(), added=()):
        self.log.append(Transition(
            kind=kind, trigger=trigger, epoch=self.view.epoch,
            mesh_epoch=self.mesh.epoch, world=self.world(),
            dropped=tuple(dropped), added=tuple(added)))
        self.check_invariants()

    def check_invariants(self):
        world = set(self.world())
        assert self.floor_world <= len(world) <= len(self.initial_world), \
            (sorted(world), self.floor_world, self.initial_world)
        assert world == set(self.initial_world) - set(self.dropped), \
            "world diverged from initial - dropped"
        assert world.isdisjoint(self.dropped)
        mesh_epochs = [t.mesh_epoch for t in self.log]
        assert all(a <= b for a, b in zip(mesh_epochs, mesh_epochs[1:])), \
            "mesh epoch went backwards"
        remesh = [t.mesh_epoch for t in self.log
                  if t.kind in ("shrink", "grow")
                  or (t.kind == "respawn" and t.trigger == "node_loss")]
        assert all(a < b for a, b in zip(remesh, remesh[1:])), \
            "re-meshing transition without a strict mesh-epoch bump"
        # a promote is in-place: the rank set and the mesh shape are
        # untouched, so its mesh epoch must equal its predecessor's
        for i, t in enumerate(self.log):
            if t.kind == "promote" and i > 0:
                prev = self.log[i - 1]
                assert t.mesh_epoch == prev.mesh_epoch, \
                    "promote bumped the mesh epoch"
                assert set(t.world) == set(prev.world), \
                    "promote changed the rank set"
        # shadows never alias live hosting: a rank's shadow lives on a
        # different daemon than the rank itself
        for r, host in self._shadows.items():
            if r in world:
                assert self.view.parent(r) != host, \
                    f"rank {r} shadowed on its own host {host}"


@dataclasses.dataclass
class RankMembership:
    """One rank's adopted view of the membership (worker side).

    The worker never invents membership: this object only changes when a
    root broadcast (RANK_TABLE carrying the world, SHRINK, GROW) says
    so, and the recovery epoch is what unblocks stale barrier waits."""
    rank: int
    world_ranks: List[int]
    epoch: int
    initial_world: int

    @property
    def size(self) -> int:
        return len(self.world_ranks)

    @property
    def shrunk(self) -> bool:
        """True while ranks are missing — the worker keeps its consistent
        cut pinned on disk as the grow-back anchor exactly while this
        holds."""
        return self.size < self.initial_world

    def adopt(self, world=None, epoch: Optional[int] = None):
        if world is not None:
            self.world_ranks = [int(r) for r in world]
        if epoch is not None:
            self.epoch = int(epoch)
