"""Reinit++ — the paper's contribution as a composable library.

Layers:
  events     RankState / FailureEvent / ReinitCommand vocabulary
  protocol   Algorithms 1 & 2 (root HandleFailure, daemon HandleReinit)
  failure    detectors (child/channel monitors, ULFM heartbeat model,
             deterministic fault injection)
  reinit     reinit_main() rollback-point API (the MPI_Reinit analogue)
  elastic    spare pool, mesh epochs, shrinking-recovery option
  recovery   CR / Reinit++ / ULFM strategy objects
"""
from .events import (FailureEvent, FailureType, GrowCommand, PromoteCommand,
                     Promotion, RankState, RecoveryReport, ReinitCommand,
                     Respawn, ShrinkCommand)
from .protocol import (ClusterView, DaemonActions, apply_recovery,
                       daemon_handle_reinit, root_handle_failure,
                       root_handle_failure_promote,
                       root_handle_failure_shrink, root_handle_rejoin)
from .failure import (ChannelMonitor, ChildMonitor, FaultInjector,
                      HeartbeatModel, ScenarioInjector, kill_process)
from .reinit import (ROLLBACK, RollbackSignal, SIGREINIT, install_sigreinit,
                     reinit_main)
from .membership import MembershipMachine, RankMembership, Transition
from .elastic import ElasticManager, MeshEpoch
from .recovery import (CR, REINIT, REPLICA, SHRINK, STRATEGIES,
                       STRATEGY_ALIASES, ULFM, get_strategy)
