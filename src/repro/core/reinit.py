"""The MPI_Reinit analogue: a rollback-point API for resilient drivers.

Paper interface (C):
    int MPI_Reinit(int argc, char **argv, MPI_Restart_point fn)
Here:
    reinit_main(fn, runtime=...) -> runs fn(state) under rollback protection.

`fn` receives the RankState (NEW / REINITED / RESTARTED) exactly like the
paper's restart-point function, and is expected to load its latest
checkpoint and resume. Rollback is requested either synchronously (the
driver observes a failure and raises RollbackSignal — the "test function"
variant the paper proposes in §3.2 Discussion, which is the only sound
option inside a jitted SPMD step), or asynchronously via SIGUSR1
(SIGREINIT) in the process runtime, where the handler arms a flag and the
next safe-point check raises.
"""
from __future__ import annotations

import contextlib
import signal
import threading
from typing import Callable, Optional

from .events import RankState


class RollbackSignal(Exception):
    """Raised at a safe point to unwind to the reinit rollback point
    (the setjmp/longjmp adaptation)."""

    def __init__(self, epoch: int = 0):
        super().__init__(f"rollback to reinit point (epoch {epoch})")
        self.epoch = epoch


class _RollbackFlag:
    def __init__(self):
        self._armed = threading.Event()
        self.epoch = 0
        # True only while the main thread sits inside an interruptible()
        # region (a blocking wait that is safe to unwind). The paper's
        # masked/deferred-signal split: SIGREINIT raises *immediately*
        # inside the region — no polling period — and defers to the next
        # check() everywhere else.
        self._interruptible = False

    def arm(self, epoch: int = 0):
        self.epoch = epoch
        self._armed.set()

    def check(self):
        """Safe-point test: raises RollbackSignal if a rollback is armed."""
        if self._armed.is_set():
            self._armed.clear()
            raise RollbackSignal(self.epoch)

    def clear(self):
        self._armed.clear()

    @contextlib.contextmanager
    def interruptible(self):
        """Marks a blocking wait as a safe point: SIGREINIT delivered
        inside unwinds the wait at once (event-driven rollback, replacing
        the recovery path's polling sleeps)."""
        self._interruptible = True
        try:
            self.check()          # armed before we blocked: unwind now
            yield
        finally:
            self._interruptible = False


ROLLBACK = _RollbackFlag()

SIGREINIT = signal.SIGUSR1


def install_sigreinit(flag: _RollbackFlag = ROLLBACK):
    """Installs the SIGREINIT (SIGUSR1) handler. Python delivers signals at
    bytecode boundaries in the main thread — the handler arms the flag and
    raises immediately when the main thread is inside an
    ROLLBACK.interruptible() wait (a declared safe point), which matches
    the paper's masked-deferred-signal implementation."""

    def handler(signum, frame):
        flag.arm()
        if flag._interruptible:
            flag._armed.clear()
            raise RollbackSignal(flag.epoch)

    signal.signal(SIGREINIT, handler)


def reinit_main(fn: Callable[[RankState], int], *,
                initial_state: RankState = RankState.NEW,
                max_restarts: int = 16,
                flag: _RollbackFlag = ROLLBACK,
                on_rollback: Optional[Callable[[int], None]] = None) -> int:
    """Run `fn` under rollback protection; returns its final return value.

    Mirrors MPI_Reinit's control flow: first entry with NEW (or RESTARTED
    for re-spawned processes), subsequent entries after rollback with
    REINITED. MPI state outside the loop is the runtime's job; application
    state is the checkpoint's job (both per the paper's split).
    """
    state = initial_state
    for _ in range(max_restarts):
        try:
            flag.clear()
            return fn(state)
        except RollbackSignal as rb:
            if on_rollback is not None:
                on_rollback(rb.epoch)
            state = RankState.REINITED
    raise RuntimeError(f"exceeded {max_restarts} rollbacks")
