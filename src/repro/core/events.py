"""Event and state vocabulary of the Reinit++ protocol (paper §3.1).

`RankState` mirrors MPI_Reinit_state_t exactly:
  NEW       — first execution of the resilient function
  REINITED  — survivor that rolled back after a failure
  RESTARTED — failed process re-spawned to resume

Failures are fail-stop, of an MPI process or of a daemon (≡ node).
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Optional


class RankState(enum.Enum):
    NEW = "MPI_REINIT_NEW"
    REINITED = "MPI_REINIT_REINITED"
    RESTARTED = "MPI_REINIT_RESTARTED"


class FailureType(enum.Enum):
    PROCESS = "process"
    NODE = "node"


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    kind: FailureType
    rank: Optional[int] = None       # failed MPI process (PROCESS failures)
    node: Optional[str] = None       # failed daemon/node (NODE failures)
    at_step: Optional[int] = None    # iteration at which it was injected
    wallclock: float = dataclasses.field(default_factory=time.monotonic)

    def __str__(self):
        tgt = f"rank {self.rank}" if self.kind is FailureType.PROCESS \
            else f"node {self.node}"
        return f"<{self.kind.value} failure of {tgt} @step {self.at_step}>"


@dataclasses.dataclass(frozen=True)
class Respawn:
    """One ⟨parent daemon, child rank⟩ pair from Algorithm 1's REINIT msg."""
    daemon: str
    rank: int


@dataclasses.dataclass(frozen=True)
class ReinitCommand:
    """The broadcast the root sends to all daemons on a failure."""
    respawns: tuple[Respawn, ...]
    epoch: int                       # recovery epoch (monotonically grows)


@dataclasses.dataclass(frozen=True)
class ShrinkCommand:
    """The broadcast of a shrinking recovery: no respawns — the dropped
    ranks leave the world and survivors re-balance over what remains."""
    dropped: tuple[int, ...]
    epoch: int
    world: tuple[int, ...]           # surviving rank ids (sorted)


@dataclasses.dataclass(frozen=True)
class GrowCommand:
    """The broadcast of a grow-back: a repaired node re-registered
    (REJOIN) and the admission policy re-admits previously dropped ranks
    onto it. Survivors roll back to the pinned pre-shrink cut and the
    re-admitted ranks restore from their last durable checkpoints; the
    world re-expands and the mesh epoch bumps (new logical shape)."""
    added: tuple[int, ...]           # ranks re-entering the world
    epoch: int
    world: tuple[int, ...]           # full rank membership after the grow
    node: str                        # the rejoined daemon hosting `added`
    mesh_epoch: int = 0


@dataclasses.dataclass(frozen=True)
class Promotion:
    """One ⟨failed rank, shadow's hosting daemon⟩ pair: the shadow that
    was warming that rank's delta stream takes over the rank id."""
    rank: int
    daemon: str


@dataclasses.dataclass(frozen=True)
class PromoteCommand:
    """The broadcast of a zero-rollback failover: no respawns and no
    rollback — each failed rank is replaced in place by its warm shadow.
    Survivors stay parked at the stalled step; the promoted shadows
    simply complete it. The mesh shape is unchanged, so the mesh epoch
    does NOT bump (compiled steps stay valid everywhere)."""
    promotions: tuple[Promotion, ...]
    epoch: int
    world: tuple[int, ...]           # full rank membership (unchanged set)


@dataclasses.dataclass
class RecoveryReport:
    """Timings of one recovery, broken down the way the paper reports them
    (Figures 4/6/7): detection, MPI recovery, checkpoint read."""
    strategy: str
    failure: FailureEvent
    detect_s: float = 0.0
    mpi_recovery_s: float = 0.0
    ckpt_read_s: float = 0.0
    rollback_step: int = 0
    world_after: Optional[int] = None   # set by a shrinking recovery

    @property
    def total_s(self) -> float:
        return self.detect_s + self.mpi_recovery_s + self.ckpt_read_s
