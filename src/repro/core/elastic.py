"""Elastic mesh management: spare capacity, re-meshing, shrink + grow.

The paper's deployment requires over-provisioned slots to survive node
failures (§3.2 "Application Deployment"); `shrink`/`grow` go beyond the
paper (its deferred future work): when the pool is exhausted the data
axis contracts instead of re-spawning, and a repaired node's REJOIN
re-expands it back toward the initial world.

All the actual state lives in `repro.core.membership.MembershipMachine`
— this module keeps the historical `ElasticManager` name plus the
mesh-only `nonshrink_plan` helper the global-restart recovery paths use
(they run Algorithm 1 themselves and only need the mesh bookkeeping).
Shrinks and grows go through the machine's audited transitions
(`shrink`/`grow`/`grant_spare`) exclusively.
"""
from __future__ import annotations

import dataclasses

from .events import FailureEvent, FailureType
from .membership import MembershipMachine, MeshEpoch, RankMembership, \
    Transition

__all__ = ["ElasticManager", "MeshEpoch", "RankMembership", "Transition"]


class ElasticManager(MembershipMachine):
    """The membership machine under its original name."""

    def nonshrink_plan(self, failure: FailureEvent) -> MeshEpoch:
        """Global-restart (paper): same mesh shape, failed shard re-hosted.
        Mesh epoch only bumps for node failures (device set changed)."""
        if failure.kind is FailureType.NODE:
            self.mesh = dataclasses.replace(self.mesh,
                                            epoch=self.mesh.epoch + 1)
        return self.mesh
