"""Elastic mesh management: spare capacity, re-meshing, shrink option.

The paper's deployment requires over-provisioned slots to survive node
failures (§3.2 "Application Deployment"). Here that is a SparePool of empty
nodes in the ClusterView; Algorithm 1's least-loaded choice naturally picks
them first. Beyond the paper, `shrink_plan` implements shrinking recovery
for data-parallel groups (the paper's future work): instead of re-spawning,
the data axis contracts and the batch is re-balanced over survivors.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .events import FailureEvent, FailureType
from .protocol import ClusterView


@dataclasses.dataclass
class MeshEpoch:
    """One incarnation of the device mesh. The epoch is the compiled-step
    cache key: recovery that re-forms the mesh bumps the epoch, anything
    that keeps it (Reinit++ process recovery) reuses compiled artifacts."""
    epoch: int
    data_parallel: int
    model_parallel: int
    pods: int = 1

    @property
    def n_shards(self) -> int:
        return self.pods * self.data_parallel * self.model_parallel


@dataclasses.dataclass
class ElasticManager:
    view: ClusterView
    mesh: MeshEpoch
    min_data_parallel: int = 1

    def spares(self) -> list[str]:
        return self.view.spares()

    def grow(self, node: str):
        """Add a fresh (spare) node to the pool."""
        self.view.children.setdefault(node, set())

    def decide(self, failure: FailureEvent) -> str:
        """The spare-pool consultation of §3.2, extended past the paper:

          "respawn"  a spare slot (or a surviving host, for process
                     failures) can absorb the loss — global-restart
                     recovery re-hosts the failed ranks (Algorithm 1);
          "shrink"   the spare pool is exhausted by a node loss and the
                     data axis can still legally contract — survivors
                     re-balance and continue on a shrunk mesh.

        Falls back to "respawn" (over-subscription) when shrinking would
        cross the min_data_parallel floor."""
        if failure.kind is not FailureType.NODE:
            return "respawn"
        if self.spares():
            return "respawn"
        if self.mesh.data_parallel > self.min_data_parallel:
            return "shrink"
        return "respawn"

    def nonshrink_plan(self, failure: FailureEvent):
        """Global-restart (paper): same mesh shape, failed shard re-hosted.
        Mesh epoch only bumps for node failures (device set changed)."""
        if failure.kind is FailureType.NODE:
            self.mesh = dataclasses.replace(self.mesh,
                                            epoch=self.mesh.epoch + 1)
        return self.mesh

    def shrink_plan(self, failure: FailureEvent) -> Optional[MeshEpoch]:
        """Beyond-paper shrinking recovery: drop one data-parallel group.

        Only legal when the lost ranks map onto a whole DP slice and the
        remaining DP degree stays above the floor; returns None when
        shrinking is not possible (caller falls back to global-restart)."""
        if self.mesh.data_parallel <= self.min_data_parallel:
            return None
        self.mesh = MeshEpoch(
            epoch=self.mesh.epoch + 1,
            data_parallel=self.mesh.data_parallel - 1,
            model_parallel=self.mesh.model_parallel,
            pods=self.mesh.pods)
        return self.mesh
