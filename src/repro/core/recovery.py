"""Recovery strategies: CR / Reinit++ / ULFM — one protocol, three costs.

Each strategy declares *what actually happens* on failure; the trainer
executes those actions for real (reload files, restore buddy shards, drop
compiled-step caches, run agreement collectives) and the simulator charges
their calibrated large-scale costs. The asymmetries the paper measures:

  CR        tear down + re-deploy the job; file checkpoints only; compiled
            artifacts and device state all lost.
  Reinit++  root↔daemon tree recovery; survivors keep process + device
            state; memory (buddy) checkpoints valid for process failures.
  ULFM      all-rank revoke/shrink/agree collectives; survivors keep
            process; always-on heartbeat taxes every fault-free step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .events import FailureEvent, FailureType
from .failure import HeartbeatModel


@dataclasses.dataclass(frozen=True)
class RecoveryStrategy:
    name: str
    # costs that exist at any scale
    redeploys: bool                      # CR: full job teardown + relaunch
    keeps_jit_cache: bool                # survivors keep compiled steps
    # recovery communication shape
    allrank_collectives: int             # ULFM: shrink/agree/merge rounds
    tree_broadcasts: int                 # Reinit++: root->daemon REINIT
    # fault-free overhead
    heartbeat: Optional[HeartbeatModel]  # ULFM only
    # pipelined recovery: survivors redistribute/restore state while the
    # replacement ranks are still spawning (the REINIT broadcast carries
    # enough context to start the restore early). CR cannot overlap —
    # nothing survives the teardown to do the restoring.
    overlap_restore: bool = False

    def checkpoint_kind(self, failure: FailureType) -> str:
        from repro.checkpoint.policy import checkpoint_kind_for
        key = "node" if failure is FailureType.NODE else "process"
        return checkpoint_kind_for(key, self.key)

    @property
    def key(self) -> str:
        return {"CR": "cr", "Reinit++": "reinit", "ULFM": "ulfm",
                "Shrink": "shrink"}[self.name]

    def fault_free_overhead(self, n_ranks: int) -> float:
        return self.heartbeat.per_step_overhead(n_ranks) if self.heartbeat \
            else 0.0


CR = RecoveryStrategy(
    name="CR", redeploys=True, keeps_jit_cache=False,
    allrank_collectives=0, tree_broadcasts=0, heartbeat=None,
    overlap_restore=False)

REINIT = RecoveryStrategy(
    name="Reinit++", redeploys=False, keeps_jit_cache=True,
    allrank_collectives=0, tree_broadcasts=1, heartbeat=None,
    overlap_restore=True)

ULFM = RecoveryStrategy(
    name="ULFM", redeploys=False, keeps_jit_cache=True,
    # revoke + shrink + agree + spawn/merge — each an all-rank operation;
    # the agreement rounds serialize against the restore, no overlap
    allrank_collectives=4, tree_broadcasts=0, heartbeat=HeartbeatModel())

# Elastic shrinking recovery (beyond the paper — its deferred future work,
# made practical by ReStore-style replicated in-memory state): behaves like
# Reinit++ while the spare pool holds, and contracts the data axis instead
# of respawning once it is exhausted. Survivors keep process + device
# state; a shrink bumps the mesh epoch, so compiled steps are dropped.
SHRINK = RecoveryStrategy(
    name="Shrink", redeploys=False, keeps_jit_cache=True,
    allrank_collectives=0, tree_broadcasts=1, heartbeat=None,
    overlap_restore=True)

STRATEGIES = {s.key: s for s in (CR, REINIT, ULFM, SHRINK)}


def get_strategy(name: str) -> RecoveryStrategy:
    k = name.lower().replace("++", "").replace("reinitpp", "reinit")
    if k not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; known: {list(STRATEGIES)}")
    return STRATEGIES[k]
