"""Recovery strategies: CR / Reinit++ / ULFM — one protocol, three costs.

Each strategy declares *what actually happens* on failure; the trainer
executes those actions for real (reload files, restore buddy shards, drop
compiled-step caches, run agreement collectives) and the simulator charges
their calibrated large-scale costs. The asymmetries the paper measures:

  CR        tear down + re-deploy the job; file checkpoints only; compiled
            artifacts and device state all lost.
  Reinit++  root↔daemon tree recovery; survivors keep process + device
            state; memory (buddy) checkpoints valid for process failures.
  ULFM      all-rank revoke/shrink/agree collectives; survivors keep
            process; always-on heartbeat taxes every fault-free step.
  Replica   shadow ranks consume the buddy delta stream every step; a
            failure is repaired by promoting the warm shadow in place —
            no rollback, no respawn, no recomputed steps. The stream
            fan-out taxes every fault-free step instead.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .events import FailureEvent, FailureType
from .failure import HeartbeatModel


@dataclasses.dataclass(frozen=True)
class RecoveryStrategy:
    name: str
    # costs that exist at any scale
    redeploys: bool                      # CR: full job teardown + relaunch
    keeps_jit_cache: bool                # survivors keep compiled steps
    # recovery communication shape
    allrank_collectives: int             # ULFM: shrink/agree/merge rounds
    tree_broadcasts: int                 # Reinit++: root->daemon REINIT
    # fault-free overhead
    heartbeat: Optional[HeartbeatModel]  # ULFM only
    # pipelined recovery: survivors redistribute/restore state while the
    # replacement ranks are still spawning (the REINIT broadcast carries
    # enough context to start the restore early). CR cannot overlap —
    # nothing survives the teardown to do the restoring.
    overlap_restore: bool = False
    # replication: shadow ranks hold warm state and failover is an
    # in-place promotion (no rollback-to-checkpoint on the critical path)
    replicates: bool = False

    def checkpoint_kind(self, failure: FailureType) -> str:
        from repro.checkpoint.policy import checkpoint_kind_for
        key = "node" if failure is FailureType.NODE else "process"
        return checkpoint_kind_for(key, self.key)

    @property
    def key(self) -> str:
        return {"CR": "cr", "Reinit++": "reinit", "ULFM": "ulfm",
                "Shrink": "shrink", "Replica": "replica"}[self.name]

    def fault_free_overhead(self, n_ranks: int,
                            stream_mb_per_rank: float = 0.0,
                            nic_bw_MBps: float = 1_200.0) -> float:
        """Per-step tax this strategy pays when nothing fails.

        ULFM pays its heartbeat; Replica pays the extra delta-frame push
        to the shadow (one more NIC copy per rank per step — pairs are
        parallel, so it scales with the per-rank frame size, not the
        world size). The other strategies are free when healthy."""
        cost = self.heartbeat.per_step_overhead(n_ranks) if self.heartbeat \
            else 0.0
        if self.replicates and stream_mb_per_rank > 0.0:
            cost += stream_mb_per_rank / nic_bw_MBps
        return cost


CR = RecoveryStrategy(
    name="CR", redeploys=True, keeps_jit_cache=False,
    allrank_collectives=0, tree_broadcasts=0, heartbeat=None,
    overlap_restore=False)

REINIT = RecoveryStrategy(
    name="Reinit++", redeploys=False, keeps_jit_cache=True,
    allrank_collectives=0, tree_broadcasts=1, heartbeat=None,
    overlap_restore=True)

ULFM = RecoveryStrategy(
    name="ULFM", redeploys=False, keeps_jit_cache=True,
    # revoke + shrink + agree + spawn/merge — each an all-rank operation;
    # the agreement rounds serialize against the restore, no overlap
    allrank_collectives=4, tree_broadcasts=0, heartbeat=HeartbeatModel())

# Elastic shrinking recovery (beyond the paper — its deferred future work,
# made practical by ReStore-style replicated in-memory state): behaves like
# Reinit++ while the spare pool holds, and contracts the data axis instead
# of respawning once it is exhausted. Survivors keep process + device
# state; a shrink bumps the mesh epoch, so compiled steps are dropped.
SHRINK = RecoveryStrategy(
    name="Shrink", redeploys=False, keeps_jit_cache=True,
    allrank_collectives=0, tree_broadcasts=1, heartbeat=None,
    overlap_restore=True)

# Zero-rollback replica failover (FTHP-MPI / PartRePer-MPI lineage):
# shadow ranks drawn from the spare pool apply the buddy delta stream as
# it flows, so they always hold the state of the current step. Failover
# is PROMOTE shadow + re-form ring + resume — survivors never roll back
# and the failed step is never recomputed. The price is paid fault-free:
# one extra NIC push per rank per step, plus a shadow process per
# protected rank.
REPLICA = RecoveryStrategy(
    name="Replica", redeploys=False, keeps_jit_cache=True,
    allrank_collectives=0, tree_broadcasts=1, heartbeat=None,
    overlap_restore=True, replicates=True)

STRATEGIES = {s.key: s for s in (CR, REINIT, ULFM, SHRINK, REPLICA)}

# Accepted spellings → canonical strategy keys. This is the single alias
# table; scenarios/schema.py re-exports it so the CLI, the scenario
# schema and this registry can never drift apart.
STRATEGY_ALIASES = {
    "reinit++": "reinit",
    "reinitpp": "reinit",
    "restart": "cr",
    "ulfm-shrink": "ulfm",
    "elastic": "shrink",
}


def get_strategy(name: str) -> RecoveryStrategy:
    """Resolve a strategy by key or documented alias.

    Raises ValueError on empty/ambiguous input (e.g. "++", which older
    normalization silently collapsed to "" and then mis-reported as an
    unknown strategy) and KeyError on a genuinely unknown name."""
    if not isinstance(name, str) or not name.strip():
        raise ValueError(f"empty or non-string strategy name: {name!r}")
    k = name.strip().lower()
    k = STRATEGY_ALIASES.get(k, k)
    if not k or k not in STRATEGIES:
        known = sorted(set(STRATEGIES) | set(STRATEGY_ALIASES))
        raise KeyError(f"unknown strategy {name!r}; known: {known}")
    return STRATEGIES[k]
