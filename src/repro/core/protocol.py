"""Algorithms 1 and 2 of the paper as pure, testable logic.

The same functions drive all three substrates: the real-process runtime
(repro.runtime), the fault-tolerant trainer (repro.train.trainer) and the
discrete-event simulator (repro.sim.cluster). Keeping them pure — cluster
view in, decision out — is what lets the property tests state protocol
invariants directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Set

from .events import (FailureEvent, FailureType, GrowCommand, PromoteCommand,
                     Promotion, RankState, ReinitCommand, Respawn,
                     ShrinkCommand)


@dataclasses.dataclass
class ClusterView:
    """Root's model of the deployment tree (paper Fig. 3)."""
    children: Dict[str, Set[int]]            # daemon -> child ranks
    epoch: int = 0

    @classmethod
    def build(cls, n_nodes: int, ranks_per_node: int,
              spare_nodes: int = 0) -> "ClusterView":
        """Standard deployment: `n_nodes` full nodes plus `spare_nodes`
        empty over-provisioned nodes (for node-failure recovery)."""
        children = {
            f"node{n}": set(range(n * ranks_per_node,
                                  (n + 1) * ranks_per_node))
            for n in range(n_nodes)
        }
        for s in range(spare_nodes):
            children[f"spare{s}"] = set()
        return cls(children=children)

    # ------------------------------------------------------------ queries

    def parent(self, rank: int) -> str:
        for d, cs in self.children.items():
            if rank in cs:
                return d
        raise KeyError(f"rank {rank} not in any daemon")

    def daemons(self) -> list[str]:
        return sorted(self.children)

    def ranks(self) -> list[int]:
        out: list[int] = []
        for cs in self.children.values():
            out.extend(cs)
        return sorted(out)

    def spares(self) -> list[str]:
        """Empty (over-provisioned) daemons — the spare pool of §3.2."""
        return sorted(d for d, cs in self.children.items() if not cs)

    def least_loaded(self, exclude: Iterable[str] = ()) -> str:
        """argmin over |Children(d)| (Algorithm 1), ties broken by name for
        determinism."""
        ex = set(exclude)
        cands = [(len(cs), d) for d, cs in self.children.items()
                 if d not in ex]
        if not cands:
            raise RuntimeError("no surviving daemons")
        return min(cands)[1]


def root_handle_failure(view: ClusterView, failure: FailureEvent
                        ) -> ReinitCommand:
    """Algorithm 1 — Root: HandleFailure.

    Mutates `view` (removing a failed daemon / reassigning ranks) and
    returns the REINIT broadcast. Recovery is *non-shrinking*: every failed
    rank reappears in the command with a chosen parent daemon.
    """
    view.epoch += 1
    if failure.kind is FailureType.NODE:
        dead = failure.node
        assert dead is not None
        lost = sorted(view.children.pop(dead))
        target = view.least_loaded()
        view.children[target].update(lost)
        respawns = tuple(Respawn(daemon=target, rank=c) for c in lost)
    else:
        assert failure.rank is not None
        parent = view.parent(failure.rank)
        respawns = (Respawn(daemon=parent, rank=failure.rank),)
    return ReinitCommand(respawns=respawns, epoch=view.epoch)


def root_handle_failure_shrink(view: ClusterView, failure: FailureEvent
                               ) -> ShrinkCommand:
    """Shrinking recovery (the paper's deferred future work, ReStore-style):
    instead of re-hosting the lost ranks, drop them from the world.

    Mutates `view` (removing the failed daemon / rank, reassigning nothing)
    and returns the SHRINK broadcast: the dropped ranks and the surviving
    world. Survivors roll back to the consistent cut and re-balance the
    batch over the contracted world — no respawn anywhere."""
    view.epoch += 1
    if failure.kind is FailureType.NODE:
        dead = failure.node
        assert dead is not None
        dropped = tuple(sorted(view.children.pop(dead)))
    else:
        assert failure.rank is not None
        parent = view.parent(failure.rank)
        view.children[parent].discard(failure.rank)
        dropped = (failure.rank,)
    world = tuple(view.ranks())
    assert world, "shrink removed the last rank"
    return ShrinkCommand(dropped=dropped, epoch=view.epoch, world=world)


def root_handle_failure_promote(view: ClusterView, failure: FailureEvent,
                                shadows: Dict[int, str]) -> PromoteCommand:
    """Zero-rollback failover: each failed rank is replaced in place by
    its warm shadow, hosted on the shadow's daemon.

    `shadows` maps rank -> daemon hosting that rank's shadow. Mutates
    `view` (the failed rank moves to the shadow's daemon — the world's
    rank *set* never changes) and returns the PROMOTE broadcast.
    Raises KeyError if any failed rank has no warm shadow — the caller
    falls back to Algorithm 1 (respawn) for those.
    """
    if failure.kind is FailureType.NODE:
        dead = failure.node
        assert dead is not None
        lost = sorted(view.children.get(dead, ()))
    else:
        assert failure.rank is not None
        lost = [failure.rank]
    missing = [r for r in lost if r not in shadows]
    if missing:
        raise KeyError(f"no warm shadow for ranks {missing}")
    view.epoch += 1
    if failure.kind is FailureType.NODE:
        view.children.pop(dead, None)
    promotions = []
    for r in lost:
        home = shadows[r]
        if failure.kind is not FailureType.NODE:
            view.children[view.parent(r)].discard(r)
        view.children.setdefault(home, set()).add(r)
        promotions.append(Promotion(rank=r, daemon=home))
    return PromoteCommand(promotions=tuple(promotions), epoch=view.epoch,
                          world=tuple(view.ranks()))


def root_handle_rejoin(view: ClusterView, node: str,
                       ranks: Iterable[int]) -> GrowCommand:
    """Grow-back (the inverse of shrinking recovery): a repaired node's
    daemon re-registered and the admission policy re-admits `ranks` onto
    it. Mutates `view` (the node reappears owning the re-admitted ranks)
    and returns the GROW broadcast. The re-admitted ranks must be outside
    the current world — a rejoin never steals live ranks."""
    added = tuple(sorted(int(r) for r in ranks))
    assert added, "rejoin with no ranks to re-admit"
    live = set(view.ranks())
    assert live.isdisjoint(added), f"rejoin of live ranks {added}"
    assert node not in view.children or not view.children[node], \
        f"rejoined node {node!r} already owns ranks"
    view.epoch += 1
    view.children[node] = set(added)
    return GrowCommand(added=added, epoch=view.epoch,
                       world=tuple(view.ranks()), node=node)


@dataclasses.dataclass
class DaemonActions:
    """What one daemon does upon receiving REINIT (Algorithm 2)."""
    daemon: str
    signal_survivors: tuple[int, ...]       # SIGREINIT -> roll back
    spawn: tuple[int, ...]                  # re-spawned, state=RESTARTED

    def states(self) -> Dict[int, RankState]:
        st = {r: RankState.REINITED for r in self.signal_survivors}
        st.update({r: RankState.RESTARTED for r in self.spawn})
        return st


def daemon_handle_reinit(view: ClusterView, daemon: str,
                         cmd: ReinitCommand) -> DaemonActions:
    """Algorithm 2 — Daemon d̂: HandleReinit.

    Survivors = current children minus the ranks this daemon must spawn.
    """
    spawn = tuple(sorted(r.rank for r in cmd.respawns if r.daemon == daemon))
    children = view.children.get(daemon, set())
    survivors = tuple(sorted(children - set(spawn)))
    return DaemonActions(daemon=daemon, signal_survivors=survivors,
                         spawn=spawn)


def apply_recovery(view: ClusterView, cmd: ReinitCommand
                   ) -> Dict[int, RankState]:
    """Runs Algorithm 2 on every daemon; returns the post-recovery state of
    every rank. Invariants (property-tested):
      - the world is non-shrinking: rank set before == after,
      - every failed rank is RESTARTED exactly once,
      - every survivor is REINITED exactly once.
    """
    states: Dict[int, RankState] = {}
    for d in view.daemons():
        acts = daemon_handle_reinit(view, d, cmd)
        for r, s in acts.states().items():
            assert r not in states, f"rank {r} handled twice"
            states[r] = s
    return states
