"""Batched serving engine: slot-based continuous batching over decode_step.

A fixed pool of `n_slots` sequences shares one jitted decode step (the same
function the decode_* dry-run cells lower). Requests occupy free slots,
prefill writes their prompt KV/SSM state into the slot, and every engine
step decodes one token for all active slots. Per-slot positions + attention
masks make ragged occupancy correct; finished slots are recycled.

Fault tolerance: the engine snapshots (params stay immutable) the decode
state + slot table on demand — `snapshot()`/`restore()` give serving the
same global-restart semantics the trainer has; recovery re-decodes nothing
that already left the engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, *, n_slots: int = 4,
                 max_len: int = 256, greedy: bool = True):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.greedy = greedy
        self.state = model.init_decode_state(n_slots, max_len)
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros(n_slots, np.int32)       # next position per slot
        self.queue: list[Request] = []
        self._decode = jax.jit(model.decode_step)
        self._prefill_cache: dict[int, Any] = {}

    # -------------------------------------------------------------- admin

    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit(self):
        """Prefill queued requests into free slots (one-by-one prefill at
        batch granularity keeps this engine simple; the batch path is the
        decode loop, which dominates serving cost)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            self._prefill_into_slot(slot, req)

    def _prefill_into_slot(self, slot: int, req: Request):
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, st = self.model.prefill(self.params, {"tokens": toks},
                                        max_len=self.max_len)
        # splice the single-sequence state into the slot'th batch lane
        def splice(dst, src):
            # find the batch axis: prefill returns batch=1 states whose
            # shapes match dst with B -> 1 at the same axis position
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.n_slots and src.shape[ax] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return dst.at[tuple(idx)].set(src.astype(dst.dtype))
            raise ValueError(f"no batch axis: {dst.shape} vs {src.shape}")

        self.state = jax.tree.map(splice, self.state, st)
        nxt = int(jnp.argmax(logits[0, -1]))
        req.out.append(nxt)
        self.slots[slot] = req
        self.pos[slot] = len(req.prompt)

    # --------------------------------------------------------------- step

    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        # current token per slot: last emitted (or pad for empty slots)
        cur = np.zeros((self.n_slots, 1), np.int32)
        for i in active:
            cur[i, 0] = self.slots[i].out[-1]
        # single shared position: engine steps advance all slots together;
        # slots admitted at different times are right-aligned by their own
        # pos counter (kv cache positions are per-slot via the mask)
        pos = int(max(self.pos[i] for i in active))
        logits, self.state = self._decode(self.params,
                                          jnp.asarray(cur), self.state,
                                          jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for i in active:
            req = self.slots[i]
            req.out.append(int(nxt[i]))
            self.pos[i] = pos + 1
            if len(req.out) >= req.max_new_tokens or \
                    self.pos[i] >= self.max_len - 1:
                req.done = True
                self.slots[i] = None
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        seen: set[int] = set()
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return done

    # ---------------------------------------------------- fault tolerance

    def snapshot(self) -> dict:
        """Capture the decode state without stalling the decode stream:
        each leaf is copied on device (so the live buffers stay donatable)
        and its D2H transfer is *started*, not awaited — the drain
        overlaps subsequent engine steps, and materialization happens
        only if/when the snapshot is actually restored."""
        def drain(a):
            try:
                c = jnp.copy(a)
                c.copy_to_host_async()
                return c
            except (AttributeError, RuntimeError):
                # non-array leaf or a backend without async transfers:
                # fall back to the synchronous pull
                return np.asarray(a)

        return {
            "state": jax.tree.map(drain, self.state),
            "pos": self.pos.copy(),
            "slots": [(s.rid, list(s.prompt), s.max_new_tokens, list(s.out))
                      if s else None for s in self.slots],
        }

    def restore(self, snap: dict):
        self.state = jax.tree.map(jnp.asarray, snap["state"])
        self.pos = snap["pos"].copy()
        self.slots = [Request(rid=t[0], prompt=t[1], max_new_tokens=t[2],
                              out=t[3]) if t else None
                      for t in snap["slots"]]
