"""Batched serving engine: slot-based continuous batching over decode_step.

A fixed pool of `n_slots` sequences shares one jitted decode step (the same
function the decode_* dry-run cells lower). Requests occupy free slots,
prefill writes their prompt KV/SSM state into the slot, and every engine
step decodes one token for all active slots.

Positions are *per slot*: the engine passes a `(n_slots,)` position vector
into `decode_step`, so each slot writes its KV at its own clock and its
causal mask is built from its own position — ragged occupancy (slots
admitted at different times) decodes exactly like `n_slots` independent
single-sequence streams. A slot's output therefore never depends on what
the other slots are doing, which is also what makes recovery replay
bit-identical regardless of how admission interleaves after a restore.

Admission is batched: queued requests with equal prompt length are
prefilled together, lane-padded to a *fixed* `prefill_batch` width so the
compiled prefill shape (and with it every lane's bitwise result) does not
depend on how many requests happened to be waiting. A small LRU keyed on
the prompt reuses the prefill of repeated prompts.

Emission: tokens leave the engine through the `sink` callback exactly
once, tracked by a per-request `emitted` watermark. A restored engine
whose watermark was advanced to the client's delivered count re-decodes
the gap silently — no token that already left the system is ever
re-delivered (ReStore's property, applied to decode).

Fault tolerance: `snapshot()`/`restore()` capture and reinstate the full
churning state — decode KV/SSM state, slot table, *and* the pending
queue — without stalling the decode stream (device copies + async D2H).
`serve.replicate.ServeReplicator` turns snapshots into BuddyStore delta
frames so replication costs O(dirt), and `serve.cluster.ServeCluster`
drives rank loss + recovery under load.

With `mesh`/`rules` the decode state is sharded over the mesh using the
layouts `sharding.rules` knows (batch over the data axis, heads/kv_seq
over the model axis), params are placed by the same rules, and the decode
step runs under a constraint scope so the model's internal annotations
bind.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.scenarios import hooks


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # emission watermark: #tokens of `out` already delivered to the sink.
    # Recovery sets it to the client's delivered count so replayed tokens
    # are re-decoded but never re-delivered.
    emitted: int = 0

    def to_dict(self) -> dict:
        return {"rid": int(self.rid), "prompt": [int(t) for t in self.prompt],
                "max_new_tokens": int(self.max_new_tokens),
                "out": [int(t) for t in self.out],
                "done": bool(self.done), "emitted": int(self.emitted)}

    @classmethod
    def from_dict(cls, d: dict) -> "Request":
        return cls(rid=d["rid"], prompt=list(d["prompt"]),
                   max_new_tokens=d["max_new_tokens"], out=list(d["out"]),
                   done=d["done"], emitted=d["emitted"])


class ServeEngine:
    def __init__(self, model: Model, params, *, n_slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 prefill_batch: Optional[int] = None,
                 prefill_cache: int = 0,
                 mesh=None, rules=None,
                 sink: Optional[Callable[[int, int, int], None]] = None,
                 name: str = "serve0"):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.greedy = greedy
        self.name = name
        self.sink = sink
        # fixed prefill lane count: groups are padded up to this width so
        # the compiled shape never depends on queue occupancy
        self.prefill_batch = min(n_slots, 4) if prefill_batch is None \
            else max(1, min(prefill_batch, n_slots))
        self.mesh, self.rules = mesh, rules
        if mesh is not None:
            if rules is None:
                raise ValueError("mesh requires sharding rules")
            from repro.sharding.partition import (constraint_scope,
                                                  state_shardings,
                                                  tree_shardings)
            self._scope = lambda: constraint_scope(mesh, rules)
            params = jax.device_put(params,
                                    tree_shardings(mesh, params, rules))
            self._state_shd = self._decode_state_shardings()
        else:
            self._scope = contextlib.nullcontext
            self._state_shd = None
        self.params = params
        self.state = self._place(model.init_decode_state(n_slots, max_len))
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros(n_slots, np.int32)       # next position per slot
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        # the KV/SSM state is the dominant buffer: donate it so the
        # per-slot scatter updates in place instead of doubling it
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self._prefill_fn = jax.jit(
            lambda p, t: model.prefill(p, {"tokens": t},
                                       max_len=self.max_len))
        # repeated-prompt prefill reuse: prompt -> (first token, one-lane
        # host state). A prompt is cached on its *second* miss, so
        # one-shot prompts never pay the host copy.
        self.prefill_cache_size = prefill_cache
        self._prefill_cache: OrderedDict[tuple, tuple] = OrderedDict()
        self._seen_prompts: set[tuple] = set()
        self._tick = 0                     # engine steps taken (monotonic)

    # ----------------------------------------------------------- sharding

    def _decode_state_shardings(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.sharding.partition import _divisible
        specs = self.model.decode_state_specs(self.rules)
        abstract = self.model.init_decode_state(self.n_slots, self.max_len,
                                                abstract=True)
        fixed = jax.tree.map(
            lambda s, leaf: _divisible(s, leaf.shape, self.mesh),
            specs, abstract, is_leaf=lambda s: isinstance(s, P))
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), fixed,
                            is_leaf=lambda s: isinstance(s, P))

    def _place(self, state):
        if self._state_shd is None:
            return state
        return jax.device_put(state, self._state_shd)

    # -------------------------------------------------------------- admin

    def submit(self, req: Request):
        if len(req.prompt) >= self.max_len - 1:
            raise ValueError(f"prompt of {len(req.prompt)} tokens does not "
                             f"fit max_len={self.max_len}")
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _flush(self, req: Request):
        """Deliver every not-yet-emitted token. A watermark ahead of
        `out` (set by recovery) suppresses delivery until decode has
        replayed past it."""
        while req.emitted < len(req.out):
            if self.sink is not None:
                self.sink(req.rid, req.emitted, req.out[req.emitted])
            req.emitted += 1

    def _finish_if_done(self, slot: int, req: Request):
        # the prefill-emitted token is the first *generated* token but
        # does not count toward max_new_tokens: a request gets exactly
        # max_new_tokens decode-step tokens on top of it
        if len(req.out) - 1 >= req.max_new_tokens \
                or self.pos[slot] >= self.max_len - 1:
            req.done = True
            self.completed.append(req)
            self.slots[slot] = None

    # ---------------------------------------------------------- admission

    def _splice(self, slot_idx: list[int], lanes: list[int], src_state):
        """Scatter lanes of a prefilled batch-`g` state into the given
        slots' batch lanes. The batch axis is identified structurally:
        the one axis where dst has n_slots, src has g, and every other
        dim agrees."""
        g = len(set(lanes)) and None     # noqa: F841  (doc: lanes index src)

        def sp(dst, src):
            src = jnp.asarray(src)
            if dst.ndim != src.ndim:
                raise ValueError(f"rank mismatch {dst.shape} vs {src.shape}")
            for ax in range(dst.ndim):
                if dst.shape[ax] != self.n_slots:
                    continue
                if all(dst.shape[a] == src.shape[a]
                       for a in range(dst.ndim) if a != ax):
                    d = jnp.moveaxis(dst, ax, 0)
                    s = jnp.moveaxis(src, ax, 0)[jnp.asarray(lanes)]
                    d = d.at[jnp.asarray(slot_idx)].set(s.astype(dst.dtype))
                    return jnp.moveaxis(d, 0, ax)
            raise ValueError(f"no batch axis: {dst.shape} vs {src.shape}")

        self.state = jax.tree.map(sp, self.state, src_state)

    def _cache_get(self, key: tuple):
        hit = self._prefill_cache.get(key)
        if hit is not None:
            self._prefill_cache.move_to_end(key)
        return hit

    def _cache_put(self, key: tuple, nxt: int, lane_state):
        if self.prefill_cache_size <= 0 or key in self._prefill_cache:
            return
        if key not in self._seen_prompts:
            self._seen_prompts.add(key)          # cache on second sighting
            return
        host = jax.tree.map(np.asarray, lane_state)
        self._prefill_cache[key] = (nxt, host)
        while len(self._prefill_cache) > self.prefill_cache_size:
            self._prefill_cache.popitem(last=False)

    def _lane_state(self, src_state, lane: int):
        """One lane of a batch-G prefill state, lane axis kept (size 1)."""
        def take(src):
            src = jnp.asarray(src)
            for ax in range(src.ndim):
                if src.shape[ax] == self.prefill_batch:
                    idx = [slice(None)] * src.ndim
                    idx[ax] = slice(lane, lane + 1)
                    return src[tuple(idx)]
            raise ValueError(f"no lane axis in {src.shape}")
        return jax.tree.map(take, src_state)

    def _commit_admission(self, slot: int, req: Request, nxt: int):
        req.out.append(int(nxt))
        self.slots[slot] = req
        self.pos[slot] = len(req.prompt)
        self._finish_if_done(slot, req)
        self._flush(req)

    def _admit(self):
        """Prefill queued requests into free slots, in strict FIFO order,
        batching maximal same-prompt-length queue prefixes up to the
        fixed `prefill_batch` width."""
        free = self._free_slots()
        while free and self.queue:
            key = tuple(self.queue[0].prompt)
            hit = self._cache_get(key) if self.prefill_cache_size else None
            if hit is not None:
                nxt, lane_state = hit
                # interruption point: admission decided, nothing committed
                hooks.fire("serve.prefill.mid", engine=self,
                           rids=[self.queue[0].rid])
                req = self.queue.pop(0)
                slot = free.pop(0)
                self._splice([slot], [0], lane_state)
                self._commit_admission(slot, req, nxt)
                continue
            head_len = len(self.queue[0].prompt)
            width = min(len(free), self.prefill_batch)
            take = []
            for r in self.queue:
                if len(take) >= width or len(r.prompt) != head_len:
                    break
                take.append(r)
            # lane-pad to the fixed width: dummy lanes replicate lane 0,
            # and per-lane data independence keeps real lanes bit-exact
            toks = np.tile(np.asarray(take[0].prompt, np.int32),
                           (self.prefill_batch, 1))
            for i, r in enumerate(take):
                toks[i] = np.asarray(r.prompt, np.int32)
            with self._scope():
                logits, st = self._prefill_fn(self.params, jnp.asarray(toks))
            nxts = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int64)
            # interruption point: prefill computed, nothing committed —
            # a kill here loses the compute but neither queue nor slots
            hooks.fire("serve.prefill.mid", engine=self,
                       rids=[r.rid for r in take])
            slots = free[:len(take)]
            free = free[len(take):]
            self._splice(slots, list(range(len(take))), st)
            for lane, (slot, req) in enumerate(zip(slots, take)):
                self.queue.remove(req)
                self._cache_put(tuple(req.prompt), int(nxts[lane]),
                                self._lane_state(st, lane))
                self._commit_admission(slot, req, int(nxts[lane]))

    # --------------------------------------------------------------- step

    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        hooks.fire("serve.decode.step", engine=self, step=self._tick)
        self._tick += 1
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        # current token per slot: last emitted (or pad for empty slots)
        cur = np.zeros((self.n_slots, 1), np.int32)
        for i in active:
            cur[i, 0] = self.slots[i].out[-1]
        # per-slot positions: each slot writes its KV at its own clock
        # and masks from its own position; inactive slots decode padding
        # into lanes that the next admission's prefill fully overwrites
        pos = jnp.asarray(self.pos)
        with self._scope():
            logits, self.state = self._decode(self.params,
                                              jnp.asarray(cur), self.state,
                                              pos)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int64)
        for i in active:
            req = self.slots[i]
            req.out.append(int(nxt[i]))
            self.pos[i] += 1
            self._finish_if_done(i, req)
            self._flush(req)
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        """Step until queue and slots are empty; returns every request
        completed by this engine (including ones finished before the
        call)."""
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return list(self.completed)

    # ---------------------------------------------------- fault tolerance

    def snapshot(self) -> dict:
        """Capture the churning state — decode KV/SSM, slot table, *and*
        pending queue — without stalling the decode stream: each leaf is
        copied on device (so the live buffers stay donatable) and its D2H
        transfer is *started*, not awaited — the drain overlaps subsequent
        engine steps, and materialization happens only if/when the
        snapshot is restored or serialized."""
        def drain(a):
            try:
                c = jnp.copy(a)
                c.copy_to_host_async()
                return c
            except (AttributeError, RuntimeError):
                # non-array leaf or a backend without async transfers:
                # fall back to the synchronous pull
                return np.asarray(a)

        return {
            "state": jax.tree.map(drain, self.state),
            "pos": self.pos.copy(),
            "slots": [s.to_dict() if s else None for s in self.slots],
            "queue": [r.to_dict() for r in self.queue],
            "tick": self._tick,
        }

    def restore(self, snap: dict):
        """Reinstate a snapshot: decode state, per-slot positions, slot
        table (with each request's done flag and emission watermark) and
        the pending queue. The state is copied so restoring the same
        snapshot twice survives the decode step's buffer donation."""
        self.state = self._place(
            jax.tree.map(lambda a: jnp.copy(jnp.asarray(a)), snap["state"]))
        self.pos = np.asarray(snap["pos"], np.int32).copy()
        self.slots = [Request.from_dict(d) if d else None
                      for d in snap["slots"]]
        self.queue = [Request.from_dict(d) for d in snap.get("queue", ())]
        self._tick = int(snap.get("tick", self._tick))

    def live_requests(self) -> list[Request]:
        """Every request the engine still owns (slots + queue)."""
        return [s for s in self.slots if s is not None] + list(self.queue)
