"""Delta-frame replication of a serving engine's churning state.

Params are immutable, so the only state a serving rank can lose is the
churn: the decode KV/SSM caches, the slot table (which request sits
where, how far it has decoded, what was already emitted) and the pending
queue. `ServeReplicator` turns an engine snapshot into a serde frame —
a tile-range *delta* against the previous frame whenever the chain
allows it — and pushes it into a BuddyStore, exactly the fabric the
training workers replicate through. One decode step dirties one KV
position per layer per active slot, so the per-step frame costs O(dirt),
not O(state); the `FramePublisher` cadence inserts full-frame anchors so
a chain is always composable from the retention window.

The subscribe side is symmetric: `compose()` folds the held frames back
into an engine snapshot that `ServeEngine.restore()` accepts. Both
recovery strategies ride this stream:

* reinit  — a respawned rank composes its buddy's held frames once,
            restores, and replays (emission-suppressed) to the fault
            point;
* replica — a warm standby applies *every* frame as it is published, so
            promotion is a pointer swap with nothing to compose.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from repro.checkpoint import serde
from repro.checkpoint.manifest import flatten_state, unflatten_state


class ServeReplicator:
    """Publish side of one serving rank's state stream.

    `store` is anything with `save(step, payload)` — in production the
    rank's BuddyStore (which pushes a copy to its ring buddy), in tests a
    plain recorder. Snapshot meta (slot table, queue, positions, tick)
    rides in the frame's JSON header; only the decode state contributes
    bulk bytes.
    """

    def __init__(self, store, *, base_every: int = 4,
                 max_dirty: float = 0.5, start_step: int = 0):
        self.store = store
        self._pub = serde.FramePublisher(base_every, max_dirty,
                                         contiguous=True)
        # `start_step` lets a respawned incarnation continue the step
        # numbering past its predecessor's chain, so the buddy's stale
        # held frames age out of the retention window instead of
        # shadowing the new chain as "newest composable"
        self.next_step = start_step
        self.frames_published = 0
        self.bytes_published = 0
        self.last_kind: Optional[str] = None

    def publish(self, engine) -> int:
        """Snapshot `engine` and push one frame; returns the frame step.
        Frame steps are a contiguous counter (0, 1, 2, ...) independent
        of the engine tick — the BuddyStore retention walk and the
        `contiguous` chain policy assume step-1 parents, and the engine
        tick advances by the publish cadence, not by 1. The tick rides in
        the frame meta instead. The snapshot's async D2H drain overlaps
        the flatten; `flatten_state` materializes each leaf on host."""
        snap = engine.snapshot()
        step = self.next_step
        self.next_step += 1
        flat = flatten_state(snap["state"])
        meta = {"pos": [int(p) for p in snap["pos"]],
                "slots": snap["slots"], "queue": snap["queue"],
                "tick": int(snap["tick"])}
        payload = self._pub.publish(flat, step, extra={"serve": meta})
        self.store.save(step, payload)
        self.frames_published += 1
        self.bytes_published += len(payload)
        self.last_kind = self._pub.last_kind
        return step

    def rebase(self):
        """Force the next frame full — the buddy holding this stream's
        history died, so a delta would chain to frames nobody holds."""
        self._pub.rebase()

    @staticmethod
    def compose(frames: Dict[int, bytes], step: Optional[int] = None
                ) -> Dict[str, Any]:
        """Fold a frame map (e.g. `BuddyStore.held_map(origin)`) into an
        engine snapshot at `step` (default: newest composable step).
        Raises KeyError if no composable step exists."""
        if step is None:
            steps = serde.composable_steps(frames)
            if not steps:
                raise KeyError("no composable step in frame map")
            step = steps[-1]
        extra, flat = serde.compose(frames, step)
        meta = extra["serve"]
        return {
            "state": unflatten_state(
                {k: np.array(v) for k, v in flat.items()}),
            "pos": np.asarray(meta["pos"], np.int32),
            "slots": meta["slots"],
            "queue": meta["queue"],
            "tick": int(meta["tick"]),
        }
