from .engine import ServeEngine, Request
from .replicate import ServeReplicator
from .cluster import LoadGen, RankKilled, ServeCluster, TokenSink

__all__ = ["ServeEngine", "Request", "ServeReplicator", "LoadGen",
           "RankKilled", "ServeCluster", "TokenSink"]
