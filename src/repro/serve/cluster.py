"""In-process fault-tolerant serving cluster.

`ServeCluster` runs `world` serving ranks — each a `ServeEngine` over the
same immutable params, serving its own slice of the request stream — and
replicates each rank's churning state into its ring buddy's BuddyStore
as delta frames (`ServeReplicator`). A deterministic open-loop load
generator (`LoadGen`) keeps traffic flowing regardless of completions,
and a `TokenSink` ledger receives every emitted token exactly once,
raising on any duplicate or gap.

Faults are injected through the process-global `scenarios.hooks`
registry: the engine fires `serve.decode.step` / `serve.prefill.mid` at
its interruption points and the cluster's injector raises `RankKilled`
there, which the round loop catches — the rank's engine, local store and
unpublished progress are gone, exactly like a process loss.

Recovery strategies (same menu the training scenarios measure):

* ``reinit``  — the rank respawns after `respawn_delay` rounds, composes
  its buddy's held frames, restores, and replays forward. Tokens the
  clients already hold are re-decoded but suppressed by each request's
  emission watermark (set to the sink's delivered count), so nothing is
  re-delivered and nothing is lost.
* ``replica`` — every published frame is eagerly composed into a warm
  standby snapshot on the buddy; promotion restores from it in the same
  round with nothing to compose and (at `publish_every=1`) at most one
  step to replay.

The headline metric is **tokens-to-first-recovered-token**: how many
tokens the surviving ranks deliver between the kill and the first new
token from a request the dead rank owned — the serving analogue of the
paper's recovery-latency measurements.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.checkpoint.memory_ckpt import BuddyStore
from repro.scenarios import hooks

from .engine import Request, ServeEngine
from .replicate import ServeReplicator


class RankKilled(Exception):
    def __init__(self, rank: int):
        super().__init__(f"rank {rank} killed")
        self.rank = rank


class TokenSink:
    """Delivery ledger: the system-of-record for what clients received.

    `__call__(rid, idx, tok)` accepts token `idx` of request `rid`.
    A redelivery must be byte-identical to what the client already holds
    (else it raises — the zero-re-emission property failed); an index gap
    means a token was lost. Both are hard failures, not warnings."""

    def __init__(self):
        self.tokens: Dict[int, List[int]] = {}
        self.order: List[int] = []       # rid per delivery, arrival order

    def __call__(self, rid: int, idx: int, tok: int):
        got = self.tokens.setdefault(rid, [])
        if idx < len(got):
            raise AssertionError(
                f"duplicate delivery rid={rid} idx={idx}")
        if idx > len(got):
            raise AssertionError(
                f"delivery gap rid={rid}: got idx={idx}, "
                f"expected {len(got)}")
        got.append(int(tok))
        self.order.append(rid)

    def delivered(self, rid: int) -> int:
        return len(self.tokens.get(rid, ()))


@dataclasses.dataclass
class Arrival:
    rid: int
    rank: int
    round: int
    prompt: List[int]
    max_new_tokens: int

    def expected_tokens(self, max_len: int) -> int:
        # prefill emits one token, decode adds max_new, truncated by the
        # engine's max_len guard (slot freed at pos == max_len-1)
        return min(self.max_new_tokens + 1,
                   max_len - len(self.prompt))

    def request(self) -> Request:
        return Request(rid=self.rid, prompt=list(self.prompt),
                       max_new_tokens=self.max_new_tokens)


class LoadGen:
    """Seeded open-loop load: the arrival schedule is fixed up front and
    never reacts to completions (requests keep landing while a rank is
    down — that is the point). Round-robin rank assignment by rid."""

    def __init__(self, *, world: int, rounds: int, per_round: int = 1,
                 prompt_lens=(4, 4, 6), max_new: int = 5,
                 vocab: int = 64, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.arrivals: List[Arrival] = []
        rid = 0
        for rnd in range(rounds):
            for _ in range(per_round):
                plen = int(prompt_lens[rid % len(prompt_lens)])
                prompt = [int(t) for t in rng.integers(1, vocab, plen)]
                self.arrivals.append(Arrival(
                    rid=rid, rank=rid % world, round=rnd,
                    prompt=prompt, max_new_tokens=max_new))
                rid += 1

    def due(self, rnd: int, rank: int) -> List[Arrival]:
        return [a for a in self.arrivals
                if a.round == rnd and a.rank == rank]

    def for_rank(self, rank: int) -> List[Arrival]:
        return [a for a in self.arrivals if a.rank == rank]


class ServeCluster:
    def __init__(self, model, params, *, world: int = 2, n_slots: int = 4,
                 max_len: int = 64, strategy: str = "reinit",
                 publish_every: int = 2, respawn_delay: int = 2,
                 base_every: int = 4, prefill_batch: Optional[int] = None,
                 engine_kw: Optional[dict] = None):
        if strategy not in ("reinit", "replica"):
            raise ValueError(strategy)
        self.model, self.params = model, params
        self.world, self.n_slots, self.max_len = world, n_slots, max_len
        self.strategy = strategy
        # a replica stream must carry every step or promotion would
        # silently become a replay strategy
        self.publish_every = 1 if strategy == "replica" else publish_every
        self.respawn_delay = 0 if strategy == "replica" else respawn_delay
        self.base_every = base_every
        self.prefill_batch = prefill_batch
        self.engine_kw = dict(engine_kw or {})
        self.sink = TokenSink()
        self.stores: Dict[int, BuddyStore] = {}
        self.engines: Dict[int, Optional[ServeEngine]] = {}
        self.reps: Dict[int, ServeReplicator] = {}
        self.standby: Dict[int, dict] = {}     # origin -> warm snapshot
        self.alive = [True] * world
        self.down_until: Dict[int, int] = {}
        self.submitted: Dict[int, Dict[int, Arrival]] = {
            r: {} for r in range(world)}
        self.metrics: Dict[str, Any] = {"kills": []}
        for r in range(world):
            self.stores[r] = BuddyStore(r, world,
                                        push_remote=self._push_remote(r))
            self.engines[r] = self._new_engine(r)
            self.reps[r] = ServeReplicator(self.stores[r],
                                           base_every=base_every)

    # ------------------------------------------------------------ fabric

    def _push_remote(self, origin: int):
        def push(buddy: int, step: int, payload: bytes):
            # dead buddies drop the push, like a refused TCP connect
            if self.alive[buddy]:
                self.stores[buddy].hold(origin, step, payload)
                if self.strategy == "replica":
                    # eager apply: the standby snapshot is always the
                    # newest composable state of the origin
                    self.standby[origin] = ServeReplicator.compose(
                        self.stores[buddy].held_map(origin))
        return push

    def _buddy_of(self, rank: int) -> int:
        return (rank + 1) % self.world

    def _new_engine(self, rank: int) -> ServeEngine:
        return ServeEngine(self.model, self.params, n_slots=self.n_slots,
                           max_len=self.max_len, sink=self.sink,
                           prefill_batch=self.prefill_batch,
                           name=f"rank{rank}", **self.engine_kw)

    # -------------------------------------------------------------- run

    def run(self, load: LoadGen, *, rounds: int,
            fault: Optional[dict] = None,
            drain_rounds: int = 400) -> Dict[str, Any]:
        """Drive the cluster: `rounds` of open-loop arrivals, then drain.
        `fault`: {"round": r, "rank": k, "point": <serve hook point>} —
        installed through the scenarios hook registry for the duration
        of the run. Returns the metrics dict; the sink holds the
        transcripts."""
        self._round = 0
        prev = hooks.active()
        if fault is not None:
            hooks.install(self._injector(fault))
        try:
            total = rounds + drain_rounds
            for rnd in range(total):
                self._round = rnd
                self._revive_due(rnd)
                for rank in range(self.world):
                    for a in load.due(rnd, rank):
                        self.submitted[rank][a.rid] = a
                        if self.alive[rank]:
                            self.engines[rank].submit(a.request())
                        # a down rank's arrivals wait in `submitted`
                        # and are replayed into the respawned engine
                for rank in range(self.world):
                    if not self.alive[rank]:
                        continue
                    try:
                        self.engines[rank].step()
                    except RankKilled as k:
                        self._on_kill(k.rank, rnd)
                        continue
                    if rnd % self.publish_every == 0:
                        self.reps[rank].publish(self.engines[rank])
                if rnd >= rounds and self._drained(load):
                    break
            return self._finalize(load)
        finally:
            hooks.clear()
            if prev is not None:
                hooks.install(prev)

    def _injector(self, fault: dict):
        tgt_point, tgt_rank = fault["point"], fault["rank"]
        tgt_round = fault["round"]
        fired = [False]

        def inject(point: str, **ctx):
            if fired[0] or point != tgt_point:
                return
            eng = ctx.get("engine")
            if eng is None or eng.name != f"rank{tgt_rank}":
                return
            if self._round < tgt_round:
                return
            fired[0] = True
            raise RankKilled(tgt_rank)

        return inject

    # --------------------------------------------------------- recovery

    def _on_kill(self, rank: int, rnd: int):
        self.alive[rank] = False
        self.engines[rank] = None
        self.metrics["kills"].append(
            {"rank": rank, "round": rnd, "strategy": self.strategy,
             "sink_mark": len(self.sink.order)})
        self.down_until[rank] = rnd + self.respawn_delay
        # local store and unpublished frames die with the process; the
        # buddy's held copies are what recovery composes from
        self.stores[rank] = BuddyStore(rank, self.world,
                                       push_remote=self._push_remote(rank))
        # the dead rank held its predecessors' frame history: every rank
        # whose buddy just vanished re-anchors its stream (next frame
        # full) so no delta ever chains to frames nobody holds
        for r in range(self.world):
            if r != rank and self._buddy_of(r) == rank:
                self.reps[r].rebase()

    def _revive_due(self, rnd: int):
        for rank, due in list(self.down_until.items()):
            if rnd >= due:
                del self.down_until[rank]
                self._recover(rank, rnd)

    def _recover(self, rank: int, rnd: int):
        if self.strategy == "replica" and rank in self.standby:
            snap = self.standby[rank]
        else:
            held = self.stores[self._buddy_of(rank)].held_map(rank)
            try:
                snap = ServeReplicator.compose(held)
            except KeyError:
                snap = None      # died before the first publish: cold
                                 # start, every request re-submits
        eng = self._new_engine(rank)
        if snap is not None:
            eng.restore(snap)
        replay = 0
        # watermarks: anything the clients already hold must be
        # re-decoded silently, never re-delivered
        for req in eng.live_requests():
            d = self.sink.delivered(req.rid)
            replay += max(0, d - req.emitted)
            req.emitted = max(req.emitted, d)
        live = {r.rid for r in eng.live_requests()}
        done_in_snap = {s["rid"] for s in (snap["slots"] if snap else [])
                        if s and s["done"]}
        # re-submit what the snapshot never saw (arrived after the
        # frame) or what it had already retired but the clients had not
        # fully received; dedupe by rid
        for rid, a in sorted(self.submitted[rank].items()):
            if a.round > rnd or rid in live or rid in done_in_snap:
                continue
            exp = a.expected_tokens(self.max_len)
            if self.sink.delivered(rid) >= exp:
                continue
            req = a.request()
            req.emitted = self.sink.delivered(rid)
            eng.submit(req)
        self.engines[rank] = eng
        # continue the step numbering past the dead incarnation's chain
        # so stale held frames on the buddy age out of the window
        self.reps[rank] = ServeReplicator(self.stores[rank],
                                          base_every=self.base_every,
                                          start_step=self.reps[rank]
                                          .next_step)
        self.alive[rank] = True
        self.metrics["kills"][-1].update(
            {"recovered_round": rnd, "rounds_down": rnd -
             self.metrics["kills"][-1]["round"], "replayed_tokens": replay})

    # --------------------------------------------------------- plumbing

    def _drained(self, load: LoadGen) -> bool:
        if not all(self.alive):
            return False
        for rank in range(self.world):
            eng = self.engines[rank]
            if eng.queue or any(s is not None for s in eng.slots):
                return False
        return True

    def _finalize(self, load: LoadGen) -> Dict[str, Any]:
        dropped = []
        for a in load.arrivals:
            if self.sink.delivered(a.rid) < a.expected_tokens(self.max_len):
                dropped.append(a.rid)
        self.metrics["requests_dropped"] = len(dropped)
        self.metrics["dropped_rids"] = dropped
        self.metrics["tokens_delivered"] = len(self.sink.order)
        for kill in self.metrics["kills"]:
            owned = {a.rid for a in load.for_rank(kill["rank"])}
            mark = kill["sink_mark"]
            first = next((i for i, rid in
                          enumerate(self.sink.order[mark:])
                          if rid in owned), None)
            kill["tokens_to_first_recovered_token"] = first
        return self.metrics

    def transcripts(self) -> Dict[int, List[int]]:
        """rid -> delivered tokens, the client-visible ground truth."""
        return {rid: list(t) for rid, t in self.sink.tokens.items()}
