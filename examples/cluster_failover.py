"""Real-process cluster failover — the paper's runtime, live.

Deploys a root → 2 daemons (+1 spare) → 4 workers tree of actual POSIX
processes on this machine, SIGKILLs a node mid-run, and prints the
measured recovery timeline (Algorithm 1 + 2 + buddy/file checkpoint
restore + rejoin barrier with rollback consensus).

    PYTHONPATH=src python examples/cluster_failover.py
"""
import json
import os
import subprocess
import sys
import tempfile

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def run(mode: str, kind: str, tmp: str) -> dict:
    report = os.path.join(tmp, f"{mode}_{kind}.json")
    ckpt = os.path.join(tmp, f"ck_{mode}_{kind}")
    os.makedirs(ckpt, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.runtime.root",
           "--nodes", "2", "--ranks-per-node", "2", "--spares", "1",
           "--steps", "8", "--dim", "1024", "--ckpt-dir", ckpt,
           "--mode", mode, "--fail-step", "4", "--fail-rank", "1",
           "--fail-kind", kind, "--report", report]
    env = dict(os.environ, PYTHONPATH=SRC)
    subprocess.run(cmd, env=env, check=True, capture_output=True,
                   timeout=120)
    with open(report) as f:
        return json.load(f)


def main():
    with tempfile.TemporaryDirectory() as tmp:
        for mode in ["reinit", "cr"]:
            for kind in ["process", "node"]:
                rep = run(mode, kind, tmp)
                ev = rep["events"][-1]
                print(f"{mode:7s} {kind:8s} failure: "
                      f"mpi_recovery={ev['mpi_recovery_s']:.2f}s "
                      f"resume_step={ev.get('resume_step')} "
                      f"total={rep['total_s']:.2f}s")
        print("\nReinit++ recovers in place (survivors roll back via "
              "SIGREINIT,\nfailed ranks re-spawn — on the spare node for "
              "node failures);\nCR tears the whole tree down and "
              "re-deploys from file checkpoints.")


if __name__ == "__main__":
    main()
