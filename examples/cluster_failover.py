"""Real-process cluster failover — the paper's runtime, live.

Drives declarative failure scenarios (repro.scenarios) through the
event-driven root -> daemons (+spare) -> workers tree of actual POSIX
processes: SIGKILLs a rank behind the deterministic FENCE barrier, takes
a whole node down, kills mid-checkpoint-write, and cascades a second
failure into an in-flight recovery — then prints each measured recovery
timeline (Algorithm 1 + 2, pipelined respawn/restore, rollback
consensus) and checks the recovered state is bit-identical to the
fault-free run.

    PYTHONPATH=src python examples/cluster_failover.py

Set REPRO_DRYRUN=1 to replay the same scenario definitions through the
calibrated discrete-event simulator instead of spawning processes.
"""
import os
import sys
import tempfile

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.scenarios import engine                      # noqa: E402
from repro.scenarios.catalog import (fault_free,        # noqa: E402
                                     get_scenario)

SHOWCASE = ["proc-sigkill-midstep", "node-sigkill", "ckpt-midwrite-kill",
            "cascade-respawn-dies"]

DRYRUN = os.environ.get("REPRO_DRYRUN", "") == "1"


def main():
    if DRYRUN:
        print("== dry run: same scenarios, simulator substrate ==\n")
        for name in SHOWCASE:
            sc = get_scenario(name)
            print(engine.describe(sc))
            for strat in sc.strategies:
                out = engine.run_sim(sc, strat)
                print(f"    {strat:7s} recovery "
                      f"{out.total_s * 1e3:8.1f} ms "
                      f"({out.n_recoveries} recovery event(s))")
            print()
        return

    with tempfile.TemporaryDirectory() as tmp:
        ref = engine.run_real(fault_free(get_scenario(SHOWCASE[0]).topology),
                              "reinit", os.path.join(tmp, "ff"))
        print(f"fault-free reference: total={ref.total_s:.2f}s\n")
        for name in SHOWCASE:
            sc = get_scenario(name)
            strat = engine.real_strategies(sc)[0]
            out = engine.run_real(sc, strat, os.path.join(tmp, name))
            ev = out.detail["events"][-1] if out.detail["events"] else {}
            bit = "bit-identical" if out.checksums == ref.checksums \
                else "DIVERGED"
            print(f"{name:22s} [{strat}] "
                  f"recoveries={out.n_recoveries} "
                  f"resume={out.resume_steps or ['-']} "
                  f"mpi={ev.get('mpi_recovery_s', float('nan')):.2f}s "
                  f"-> {bit}")
        print("\nReinit++ recovers in place (survivors roll back via "
              "SIGREINIT,\nfailed ranks re-spawn — on the spare node for "
              "node failures);\nevery scenario's consistent cut matches "
              "the schema's declarative oracle.")


if __name__ == "__main__":
    main()
