"""Compare CR / ULFM / Reinit++ end to end — the paper's experiment, small.

Runs the same fault-injected training job under all three recovery
strategies (identical failure, identical data), prints each strategy's
recovery breakdown, and then shows the large-scale picture from the
calibrated simulator (Figures 4/6 reproduction at 16-1024 ranks).

    PYTHONPATH=src python examples/compare_strategies.py

Set REPRO_DRYRUN=1 to print only the calibrated-simulator comparison
(no training).
"""
import os
import tempfile

import jax

from repro.checkpoint.manifest import tree_digest
from repro.configs import get_config, reduced
from repro.core import FailureType, FaultInjector
from repro.models.model import Model
from repro.sim import APPS, recovery_time, simulate_run
from repro.train import AdamWConfig, TokenPipeline, TrainConfig, Trainer


def main():
    if os.environ.get("REPRO_DRYRUN", "") == "1":
        print("=== dry run: calibrated simulation only ===")
        print(f"{'ranks':>6} {'CR':>8} {'Reinit++':>9} {'ULFM':>8}")
        for n in [16, 64, 256, 1024]:
            ts = [recovery_time(s, n, 'process')['mpi_recovery_s']
                  for s in ('cr', 'reinit', 'ulfm')]
            print(f"{n:>6} {ts[0]:>8.2f} {ts[1]:>9.2f} {ts[2]:>8.2f}")
        return

    cfg = reduced(get_config("paper-demo"))
    model = Model(cfg)
    data = TokenPipeline(cfg.vocab_size, 4, 64, seed=0)
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=20)

    print("=== this machine: one failure, three recoveries ===")
    digests = {}
    for strategy in ["reinit", "ulfm", "cr"]:
        with tempfile.TemporaryDirectory() as d:
            inj = FaultInjector(n_ranks=8, n_steps=20,
                                kind=FailureType.PROCESS, seed=7)
            tr = Trainer(model, data, opt,
                         TrainConfig(total_steps=20, ckpt_dir=d,
                                     strategy=strategy), injector=inj)
            tr.run()
            rep = tr.reports[0]
            digests[strategy] = tree_digest(
                jax.device_get(tr.state["params"]))
            print(f"{rep.strategy:9s} recovery {rep.total_s * 1e3:7.1f} ms"
                  f"  (mpi {rep.mpi_recovery_s * 1e3:6.1f} ms, "
                  f"ckpt {rep.ckpt_read_s * 1e3:6.1f} ms, "
                  f"ckpt kind: "
                  f"{tr.strategy.checkpoint_kind(rep.failure.kind)})")
    assert len(set(digests.values())) == 1, "strategies diverged!"
    print("all three strategies converge to the same params ✓")

    print("\n=== calibrated simulation: MPI recovery vs ranks (Fig 6) ===")
    print(f"{'ranks':>6} {'CR':>8} {'Reinit++':>9} {'ULFM':>8}")
    for n in [16, 64, 256, 1024]:
        ts = [recovery_time(s, n, 'process')['mpi_recovery_s']
              for s in ('cr', 'reinit', 'ulfm')]
        print(f"{n:>6} {ts[0]:>8.2f} {ts[1]:>9.2f} {ts[2]:>8.2f}")

    print("\n=== total time with checkpointing, CoMD proxy (Fig 4) ===")
    for n in [16, 1024]:
        row = [f"{simulate_run(APPS['comd'], n, s).total_s:7.1f}s"
               for s in ("cr", "reinit", "ulfm")]
        print(f"n={n:<5} CR={row[0]} Reinit++={row[1]} ULFM={row[2]}")


if __name__ == "__main__":
    main()
