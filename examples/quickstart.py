"""Quickstart: train a small LM with Reinit++ fault tolerance.

Trains the paper-demo transformer for 30 steps, SIGKILL-emulates a random
rank failure mid-run (fault injection, paper §4), watches Reinit++ recover
from the buddy memory checkpoint, and verifies the final parameters are
bit-identical to an uninterrupted run.

    PYTHONPATH=src python examples/quickstart.py

Set REPRO_DRYRUN=1 to print the run plan (config + drawn fault) without
training.
"""
import os
import tempfile

import jax

from repro.checkpoint.manifest import tree_digest
from repro.configs import get_config, reduced
from repro.core import FailureType, FaultInjector
from repro.models.model import Model
from repro.train import AdamWConfig, TokenPipeline, TrainConfig, Trainer


def main():
    cfg = reduced(get_config("paper-demo"))
    if os.environ.get("REPRO_DRYRUN", "") == "1":
        inj = FaultInjector(n_ranks=8, n_steps=30,
                            kind=FailureType.PROCESS, seed=42)
        print(f"dry run: {cfg.name}, 30 steps, strategy=reinit")
        print(f"drawn fault: rank {inj.fail_rank} SIGKILL @step "
              f"{inj.fail_step} (scenario: "
              f"{inj.scenario.faults[0].point}/"
              f"{inj.scenario.faults[0].how})")
        return
    model = Model(cfg)
    data = TokenPipeline(cfg.vocab_size, global_batch=4, seq_len=64, seed=0)
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30)

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        print("=== reference run (no failures) ===")
        ref = Trainer(model, data, opt,
                      TrainConfig(total_steps=30, ckpt_dir=d1,
                                  strategy="reinit", log_every=10))
        ref_out = ref.run()

        print("\n=== fault-injected run (Reinit++ recovery) ===")
        inj = FaultInjector(n_ranks=8, n_steps=30,
                            kind=FailureType.PROCESS, seed=42)
        tr = Trainer(model, data, opt,
                     TrainConfig(total_steps=30, ckpt_dir=d2,
                                 strategy="reinit", log_every=10),
                     injector=inj)
        out = tr.run()

        rep = out["reports"][0]
        print(f"\nfailure injected @step {inj.fail_step} (rank "
              f"{inj.fail_rank}); recovered in {rep.total_s * 1e3:.1f} ms "
              f"(detect {rep.detect_s * 1e3:.1f} + mpi "
              f"{rep.mpi_recovery_s * 1e3:.1f} + ckpt "
              f"{rep.ckpt_read_s * 1e3:.1f})")
        d_ref = tree_digest(jax.device_get(ref.state["params"]))
        d_ft = tree_digest(jax.device_get(tr.state["params"]))
        print(f"reference params digest: {d_ref}")
        print(f"recovered params digest: {d_ft}")
        assert d_ref == d_ft, "recovery diverged!"
        print("recovery is BIT-IDENTICAL to the uninterrupted run ✓")
        print(f"loss: {ref_out['losses'][0]:.3f} -> "
              f"{ref_out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
