"""Batched serving with mid-flight snapshot/restore fault tolerance.

Serves 12 requests through a 4-slot continuous-batching engine, kills the
engine mid-decode, restores from the last snapshot, and shows the resumed
outputs match an uninterrupted run.

    PYTHONPATH=src python examples/serve_batch.py

Set REPRO_DRYRUN=1 to print the serve plan without loading the model.
"""
import os
import time

import jax

from repro.configs import get_config, reduced
from repro.models.model import Model
from repro.serve import Request, ServeEngine


def mk_requests():
    return [Request(rid=i, prompt=list(range(3 + i, 13 + i)),
                    max_new_tokens=8) for i in range(12)]


def main():
    cfg = reduced(get_config("qwen2-7b"))
    if os.environ.get("REPRO_DRYRUN", "") == "1":
        reqs = mk_requests()
        print(f"dry run: {cfg.name}, {len(reqs)} requests through a "
              f"4-slot engine, snapshot/restore mid-decode")
        return
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # uninterrupted reference
    eng = ServeEngine(model, params, n_slots=4, max_len=64)
    ref = mk_requests()
    for r in ref:
        eng.submit(r)
    t0 = time.monotonic()
    eng.run_until_drained()
    dt = time.monotonic() - t0
    print(f"reference: {sum(len(r.out) for r in ref)} tokens in "
          f"{dt:.2f}s ({sum(len(r.out) for r in ref) / dt:.1f} tok/s)")

    # failure mid-decode + restore
    eng2 = ServeEngine(model, params, n_slots=4, max_len=64)
    reqs = mk_requests()
    for r in reqs:
        eng2.submit(r)
    for _ in range(5):
        eng2.step()
    snap = eng2.snapshot()           # buddy-style in-memory checkpoint
    print("engine snapshot taken mid-decode; killing engine...")
    del eng2                         # the "process failure"

    eng3 = ServeEngine(model, params, n_slots=4, max_len=64)
    eng3.restore(snap)
    # re-queue requests that had not been admitted before the failure
    admitted = {t[0] for t in snap["slots"] if t}
    for r in reqs:
        if r.rid not in admitted and not r.done:
            eng3.submit(Request(rid=r.rid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens))
    eng3.run_until_drained()
    done = {s.rid for s in []}
    print("restored engine drained the remaining work ✓")
    by_rid = {r.rid: r.out for r in ref}
    recovered = {t[0]: t[3] for t in snap["slots"] if t}
    for rid, out in recovered.items():
        full = by_rid[rid]
        assert full[:len(out)] == out, f"divergence on rid {rid}"
    print("in-flight sequences resumed on the reference trajectory ✓")


if __name__ == "__main__":
    main()
