"""Sharded trainer recovery on a real (simulated 8-device) mesh.

Runs the fault-tolerant Trainer under an 8-way data mesh in a subprocess:
the buddy memory checkpoint is an actual `ppermute` ring over the mesh,
and recovery restores the state through the inverse permute. The
fault-injected run must match the fault-free run on the SAME mesh
bit-for-bit (identical compiled program + deterministic collectives).
"""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

CODE = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import jax
    from repro.checkpoint.manifest import tree_digest
    from repro.configs import get_config, reduced
    from repro.core import FailureType, FaultInjector
    from repro.models.model import Model
    from repro.sharding.rules import ShardingRules
    from repro.train import AdamWConfig, TokenPipeline, TrainConfig, Trainer

    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh((8,), ("data",))
    rules = ShardingRules(batch="data", embed="data")
    cfg = reduced(get_config("paper-demo"))
    model = Model(cfg)
    data = TokenPipeline(cfg.vocab_size, 8, 32, seed=11)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)

    def run(d, injector=None):
        tr = Trainer(model, data, opt,
                     TrainConfig(total_steps=10, ckpt_dir=d,
                                 strategy="reinit"),
                     mesh=mesh, rules=rules, injector=injector)
        res = tr.run()
        return tr, res

    with tempfile.TemporaryDirectory() as d1, \\
            tempfile.TemporaryDirectory() as d2:
        ref, _ = run(d1)
        inj = FaultInjector(n_ranks=8, n_steps=10,
                            kind=FailureType.PROCESS, seed=5)
        ft, res = run(d2, injector=inj)
        assert len(res["reports"]) == 1
        # memory (buddy-permute) restore path was used
        assert res["reports"][0].rollback_step == inj.fail_step
        a = tree_digest(jax.device_get(ref.state["params"]))
        b = tree_digest(jax.device_get(ft.state["params"]))
        assert a == b, (a, b)
        print("SHARDED_FT_OK")
"""


def test_sharded_trainer_buddy_recovery():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(CODE)], env=env,
        capture_output=True, text=True, timeout=600)
    assert "SHARDED_FT_OK" in proc.stdout, \
        proc.stdout[-1000:] + proc.stderr[-3000:]
