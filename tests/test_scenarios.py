"""Deterministic failure-scenario matrix.

Layers under test:
  schema/catalog   the declarative Scenario vocabulary and the >=12-entry
                   catalog (who fails x when x how x strategy)
  injector/hooks   the generalized fault-injection engine (ScenarioInjector
                   + the process-global interruption-point registry)
  sim executor     every catalog scenario x strategy through the
                   discrete-event simulator over the real Algorithm-1/2
                   protocol (cheap: runs on every test invocation)
  crash atomicity  FileCheckpointer killed (real SIGKILL, subprocess) at
                   its named interruption points — previous step must
                   stay loadable, orphan tmp reaped by the next GC
  real runtime     the same scenario definitions on live root/daemon/
                   worker process trees. The `scenario_fast` subset runs
                   by default; the full matrix, 3-consecutive-run
                   stability proof and 3-node topologies are opt-in via
                   `-m scenario_slow` (CI's scheduled job).

Recovered runs are asserted BIT-IDENTICAL to a fault-free run of the same
topology wherever the strategy guarantees it, and the observed rollback
consensus is checked against the schema's declarative consistent-cut
oracle (expected_resume_step).
"""
import dataclasses
import os
import signal
import subprocess
import sys
import textwrap

import pytest

from repro.core.events import FailureType
from repro.core.failure import FaultInjector, ScenarioInjector
from repro.scenarios import (Fault, GRAY_HOWS, Scenario, Topology,
                             expected_resume_step, expected_resume_steps,
                             hooks)
from repro.scenarios import engine
from repro.scenarios.catalog import (BY_NAME, CATALOG, T22, T22S0, T32,
                                     fault_free)
from repro.sim.cluster import simulate_scenario

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


# --------------------------------------------------------------- schema

def test_schema_roundtrip_all_catalog():
    for sc in CATALOG:
        back = Scenario.from_json(sc.to_json())
        assert back == sc, sc.name


@pytest.mark.parametrize("bad", [
    dict(faults=(Fault("gpu", 0, 3),)),                  # unknown target
    dict(faults=(Fault("rank", 9, 3),)),                 # rank >= world
    dict(faults=(Fault("rank", 1, 9),)),                 # step >= steps
    dict(faults=(Fault("rank", 1, None,                  # cascade first
                       point="worker.recovery.pulled"),)),
    dict(faults=(Fault("rank", 1, 3, how="hang"),)),     # hang, no watchdog
    dict(faults=(Fault("node", 1, 3, how="hang"),),      # hang a node
         stall_timeout_s=5.0),
    dict(faults=(Fault("root", step=3, how="hang"),),    # hang the root
         stall_timeout_s=5.0),
    dict(faults=(Fault("node", 1, 3,                     # ckpt fault on node
                       point="worker.ckpt.mid_write"),)),
    dict(faults=(Fault("rank", 1, 3, factor=2.0),)),     # factor on fail-stop
    dict(faults=(Fault("rank", 1, 3, how="slow"),)),     # gray needs factor>1
    dict(faults=(Fault("rank", 1, 3, how="slow",         # factor not a
                       factor=1.0),)),                   # degradation
    dict(faults=(Fault("rank", 1, 1, how="slow",         # no healthy baseline
                       factor=6.0),)),
    dict(faults=(Fault("root", step=3, how="slow",       # root runs no BSP
                       factor=6.0),)),
    dict(faults=(Fault("rank", 1, 3, how="lossy",        # gray is @step only
                       factor=6.0,
                       point="worker.ckpt.mid_write"),)),
    dict(faults=(Fault("rank", 1, 3),),                  # mitigate w/o gray
         mitigate=True, strategies=("shrink",)),
    dict(faults=(Fault("rank", 1, 3, how="slow",         # mitigate needs
                       factor=6.0),),                    # the elastic mode
         mitigate=True, strategies=("reinit",)),
    dict(faults=(Fault("rank", 1, 4, how="slow",         # drain cut leaves
                       factor=6.0),),                    # no post-drain step
         mitigate=True, strategies=("shrink",)),
])
def test_schema_rejects(bad):
    with pytest.raises(ValueError):
        Scenario(name="bad", topology=T22, steps=6, **bad)


def test_expected_resume_oracle_elastic():
    """Strategy-aware oracle: under the elastic strategy a repair adds a
    grow entry whose cut is the shrink it reverses; everywhere else
    repairs are invisible."""
    from repro.scenarios import Repair, elastic_transitions
    t220 = Topology(nodes=2, ranks_per_node=2, spares=0)
    sc = Scenario(name="gb", topology=t220, steps=7,
                  faults=(Fault("node", 2, 2),), repairs=(Repair(2, 4),),
                  strategies=("shrink", "reinit"))
    assert expected_resume_steps(sc) == [2]
    assert expected_resume_steps(sc, "reinit") == [2]
    assert expected_resume_steps(sc, "cr") == [2]
    assert expected_resume_steps(sc, "shrink") == [2, 2]
    kinds = [k for k, _, _ in elastic_transitions(sc)]
    assert kinds == ["shrink", "grow"]
    # spare-absorbed first loss, shrink second, grow reverses the second
    sc2 = Scenario(name="gb3", topology=T32, steps=9,
                   faults=(Fault("node", 2, 2), Fault("node", 4, 4)),
                   repairs=(Repair(4, 6),), strategies=("shrink",))
    assert expected_resume_steps(sc2, "shrink") == [2, 4, 4]
    assert [k for k, _, _ in elastic_transitions(sc2)] == \
        ["respawn", "shrink", "grow"]
    # a repair with a full world is a spare grant: no oracle entry
    sc3 = Scenario(name="sp", topology=T22, steps=7,
                   faults=(Fault("node", 2, 2),), repairs=(Repair(2, 4),),
                   strategies=("shrink",))
    assert expected_resume_steps(sc3, "shrink") == [2]
    assert [k for k, _, _ in elastic_transitions(sc3)] == \
        ["respawn", "spare"]
    # the min_data_parallel floor turns a would-be shrink into respawn
    sc4 = Scenario(name="fl", topology=t220, steps=7,
                   faults=(Fault("node", 2, 2),), min_data_parallel=2,
                   strategies=("shrink",))
    assert [k for k, _, _ in elastic_transitions(sc4)] == ["respawn"]


def test_expected_resume_oracle():
    mk = lambda f: Scenario(name="x", topology=T22, steps=6, faults=(f,))
    assert expected_resume_step(mk(Fault("rank", 1, 3))) == 3
    assert expected_resume_step(
        mk(Fault("rank", 1, 3, point="worker.ckpt.mid_write"))) == 2
    assert expected_resume_step(
        mk(Fault("rank", 1, 3, point="worker.ckpt.pre_push"))) == 3
    assert expected_resume_step(mk(Fault("root", step=3))) is None
    casc = Scenario(name="c", topology=T22, steps=6, faults=(
        Fault("rank", 1, 3),
        Fault("rank", 1, None, point="worker.recovery.pulled")))
    assert expected_resume_step(casc) == 3       # primary fault's cut
    # cascades add no entry of their own
    assert expected_resume_steps(casc) == [3]
    # sequential primary faults each get their own consensus entry
    seq = Scenario(name="s", topology=T32, steps=6, faults=(
        Fault("node", 2, 2), Fault("node", 4, 4)))
    assert expected_resume_steps(seq) == [2, 4]


def test_catalog_breadth():
    assert len(CATALOG) >= 12
    assert len(BY_NAME) == len(CATALOG)          # unique names
    targets = {f.target for s in CATALOG for f in s.faults}
    hows = {f.how for s in CATALOG for f in s.faults}
    points = {f.point for s in CATALOG for f in s.faults}
    assert targets == {"rank", "node", "root", "shadow"}
    assert hows == {"sigkill", "channel_break", "hang", "slow", "lossy"}
    assert {"step", "worker.ckpt.mid_write", "worker.ckpt.pre_push",
            "worker.recovery.pulled", "worker.recovery.enter",
            "worker.recovery.compose"} <= points
    assert any(s.topology.nodes >= 3 for s in CATALOG)   # 3-node coverage
    assert any(s.is_cascading for s in CATALOG)
    strategies = {st for s in CATALOG for st in s.strategies}
    assert strategies == {"reinit", "cr", "ulfm", "shrink", "replica"}
    # elastic coverage: multi-node-loss cells exist, and at least one
    # exhausts the spare pool (more node faults than spares)
    multi = [s for s in CATALOG
             if sum(1 for f in s.faults if f.target == "node") >= 2]
    assert multi
    assert any(sum(1 for f in s.faults if f.target == "node")
               > s.topology.spares for s in multi)
    # a hang cell detected by the heartbeat ring, not the watchdog
    assert any(s.heartbeat_period_s > 0 and s.stall_timeout_s == 0
               and any(f.how == "hang" for f in s.faults) for s in CATALOG)
    # full elastic lifecycle coverage: grow-back cells (repairs), a
    # process-level shrink cell, and a daemon-hang (node-level
    # heartbeat) cell
    assert any(s.repairs for s in CATALOG)
    assert any(s.repairs and s.is_cascading for s in CATALOG)
    assert any(not s.topology.spares
               and any(f.target == "rank" for f in s.faults)
               and "shrink" in s.strategies for s in CATALOG)
    assert any(any(f.how == "hang" and f.target == "node"
                   for f in s.faults) for s in CATALOG)
    # zero-rollback replica coverage: a straight promote cell, a
    # shadow-stream loss (degraded cover -> reinit fallback), a
    # promotion-window death (cascade on the promoted shadow), and a
    # root loss recovered by the warm standby under the replica mode
    replica = [s for s in CATALOG if "replica" in s.strategies]
    assert any(any(f.target == "rank" for f in s.faults) and
               not s.is_cascading for s in replica)
    assert any(any(f.target == "shadow" for f in s.faults)
               for s in replica)
    assert any(s.is_cascading for s in replica)
    assert any(any(f.target == "root" for f in s.faults) for s in replica)
    # gray-failure coverage: both degradation mechanisms, each with a
    # tolerate (mitigate=off) and a drain (mitigate=on) cell, a
    # node-level drain that grows back, and flapping-node cells — one
    # with a re-fail inside the open rejoin-consensus window
    gray = [s for s in CATALOG
            if any(f.how in GRAY_HOWS for f in s.faults)]
    assert {f.how for s in gray for f in s.faults} == set(GRAY_HOWS)
    for how in GRAY_HOWS:
        assert any(not s.mitigate for s in gray
                   if any(f.how == how for f in s.faults))
        assert any(s.mitigate for s in gray
                   if any(f.how == how for f in s.faults))
    assert any(s.mitigate and s.repairs
               and any(f.target == "node" for f in s.faults) for s in gray)
    flap = [s for s in CATALOG if "flap" in s.tags]
    assert any(len(s.faults) == len(s.repairs) == 2 for s in flap)
    assert any(s.is_cascading and any(
        f.point == "worker.recovery.pulled" for f in s.faults)
        for s in flap)
    # every scenario is executable on the real runtime or sim-only by
    # explicit choice (ulfm) — none is silently dead
    for s in CATALOG:
        assert engine.real_strategies(s) or s.strategies == ("ulfm",)


# ------------------------------------------------------------- injector

def test_scenario_injector_fires_each_fault_once():
    sc = Scenario(name="two", topology=T22, steps=8,
                  faults=(Fault("rank", 1, 3), Fault("node", 2, 5)),
                  strategies=("reinit",))
    from repro.core.protocol import ClusterView
    view = ClusterView.build(2, 2, 1)
    inj = ScenarioInjector(sc)
    assert inj.check(2) is None
    ev = inj.check(3, view)
    assert ev.kind is FailureType.PROCESS and ev.rank == 1
    assert inj.check(3, view) is None            # fired exactly once
    ev = inj.check(5, view)
    assert ev.kind is FailureType.NODE and ev.node == "node1"
    assert inj.check(5, view) is None
    inj.reset()
    assert inj.check(3, view) is not None


def test_fault_injector_is_scenario_backed_and_stable():
    a = FaultInjector(n_ranks=64, n_steps=100, seed=9)
    b = FaultInjector(n_ranks=64, n_steps=100, seed=9)
    assert (a.fail_step, a.fail_rank) == (b.fail_step, b.fail_rank)
    assert a.scenario.faults[0].rank == a.fail_rank
    assert a.scenario.faults[0].step == a.fail_step
    ev = a.check(a.fail_step)
    assert ev is not None and ev.rank == a.fail_rank
    assert a.check(a.fail_step) is None          # single failure (§4)


def test_hooks_install_fire_clear():
    seen = []
    hooks.install(lambda point, **ctx: seen.append((point, ctx)))
    try:
        hooks.fire("step", step=4)
    finally:
        hooks.clear()
    hooks.fire("step", step=5)                   # cleared: no-op
    assert seen == [("step", {"step": 4})]


# ----------------------------------------------------------- sim matrix

SIM_MATRIX = [(s.name, st) for s in CATALOG for st in s.strategies]


@pytest.mark.parametrize("name,strategy", SIM_MATRIX)
def test_sim_matrix(name, strategy):
    sc = BY_NAME[name]
    out = engine.run_sim(sc, strategy)
    rows = out.detail["rows"]
    # every fault is charged exactly one recovery row; the elastic
    # strategy may add grow rows for node repairs on top
    fault_rows = [r for r in rows if not r.get("grow")]
    assert len(fault_rows) == len(sc.faults)
    grows = [r for r in rows if r.get("grow")]
    if strategy != "shrink" or not sc.repairs:
        assert not grows
    assert out.total_s > 0
    assert out.resume_consistent, \
        f"{name}/{strategy}: {out.resume_steps} != {out.expected_resume}"
    # cascades may be re-ordered around a grow (a cascade on a dropped
    # rank fires at the grow that re-admits it) but never lost
    assert sorted(r["cascade"] for r in fault_rows) == \
        sorted(f.point.startswith("worker.recovery.") for f in sc.faults)
    for r in rows:
        if r.get("tolerated"):
            # tolerated gray fault: nothing detects, nothing recovers —
            # the whole cost is the degraded throughput to the end
            assert r["mpi_recovery_s"] == 0 and r["degraded_s"] > 0
            continue
        assert r["detect_s"] > 0 and r["mpi_recovery_s"] > 0


def test_sim_detection_mechanisms_ordered():
    """Detection latency must reflect the mechanism: silent hangs pay the
    stall timeout, SIGCHLD is fastest, ULFM's heartbeat beats the
    watchdog on hangs (its fault-free tax is charged elsewhere)."""
    hang = simulate_scenario(BY_NAME["proc-hang"], "reinit")
    kill = simulate_scenario(BY_NAME["proc-sigkill-midstep"], "reinit")
    node = simulate_scenario(BY_NAME["node-sigkill"], "reinit")
    assert hang.rows[0]["detect_s"] > BY_NAME["proc-hang"].stall_timeout_s
    assert kill.rows[0]["detect_s"] < node.rows[0]["detect_s"]
    ulfm_hang = simulate_scenario(BY_NAME["proc-hang"], "ulfm")
    assert ulfm_hang.rows[0]["detect_s"] < hang.rows[0]["detect_s"]


def test_sim_reinit_beats_cr_on_process_failure():
    sc = BY_NAME["proc-sigkill-midstep"]
    r = simulate_scenario(sc, "reinit").rows[0]["mpi_recovery_s"]
    c = simulate_scenario(sc, "cr").rows[0]["mpi_recovery_s"]
    assert r < c


def test_sim_cascade_charges_two_recoveries():
    out = simulate_scenario(BY_NAME["cascade-respawn-dies"], "reinit")
    assert len(out.rows) == 2 and out.rows[1]["cascade"]
    single = simulate_scenario(BY_NAME["proc-sigkill-midstep"], "reinit")
    assert out.total_recovery_s > single.total_recovery_s


# ------------------------------------------------- elastic / shrink sim

ELASTIC_CELLS = ["double-node-loss", "spare-pool-exhaustion",
                 "shrink-after-cascade", "proc-loss-shrink",
                 "shrink-then-growback", "growback-mid-cascade",
                 "shrink-then-growback-3node"]


@pytest.mark.parametrize("name", ELASTIC_CELLS)
@pytest.mark.parametrize("strategy", ["reinit", "cr", "ulfm", "shrink"])
def test_sim_elastic_matrix(name, strategy):
    """Every elastic cell through every strategy — including the ones the
    cell itself does not list, so the sim coverage is the full x4 grid.
    Under the elastic strategy the executed shrink/grow transitions must
    match the schema's declarative `elastic_transitions` replay — two
    independent derivations of the same membership policy."""
    from repro.scenarios import elastic_transitions
    sc = BY_NAME[name]
    out = engine.run_sim(sc, strategy)
    rows = out.detail["rows"]
    fault_rows = [r for r in rows if not r.get("grow")]
    assert len(fault_rows) == len(sc.faults)
    assert out.resume_consistent, \
        f"{name}/{strategy}: {out.resume_steps} != {out.expected_resume}"
    if strategy == "shrink":
        exp = elastic_transitions(sc)
        primary = [e for e in exp
                   if e[0] in ("respawn", "shrink", "restart")]
        primary_rows = [r for r in fault_rows if not r["cascade"]]
        assert [r["shrink"] for r in primary_rows] == \
            [k == "shrink" for k, _, _ in primary], (name, primary_rows)
        grows = [r for r in rows if r.get("grow")]
        assert len(grows) == sum(1 for k, _, _ in exp if k == "grow")
    else:
        assert not any(r["shrink"] or r.get("grow") for r in rows)


def test_sim_shrink_cheaper_than_node_respawn():
    """The mechanism's point: no spawn term on the shrink path. The
    exhausted-pool recovery must be cheaper than the spare-respawn one
    in the same scenario, and it restores from survivor memory, not
    the shared filesystem."""
    out = simulate_scenario(BY_NAME["spare-pool-exhaustion"], "shrink")
    respawned, shrunk = out.rows
    assert not respawned["shrink"] and shrunk["shrink"]
    assert shrunk["mpi_recovery_s"] < respawned["mpi_recovery_s"]
    assert shrunk["ckpt_read_s"] < respawned["ckpt_read_s"]


def test_sim_growback_reexpands_world():
    """The grow row's structure: after shrink-then-growback the sim must
    show one shrink row and one grow row, the grow re-admitting exactly
    the dropped ranks with a bumped mesh epoch and a consensus landing
    on the pinned pre-shrink cut."""
    out = simulate_scenario(BY_NAME["shrink-then-growback"], "shrink")
    shrunk = [r for r in out.rows if r["shrink"]]
    grows = [r for r in out.rows if r["grow"]]
    assert len(shrunk) == 1 and len(grows) == 1
    assert grows[0]["added"] == [2, 3]
    assert grows[0]["mesh_epoch"] == 2        # shrink bumped, grow bumped
    assert out.resume_steps == [2, 2]         # shrink cut, then grow cut
    assert out.world_consistent
    # non-elastic strategies never grow
    for st in ("reinit", "cr", "ulfm"):
        assert not any(r["grow"] for r in
                       simulate_scenario(BY_NAME["shrink-then-growback"],
                                         st).rows)


def test_sim_process_shrink_uneven_groups():
    """Process-level shrink: a single-rank loss with no spares drops one
    rank (uneven groups), restores from survivor memory, and is cheaper
    than the respawn the non-elastic strategies pay."""
    sc = BY_NAME["proc-loss-shrink"]
    out = simulate_scenario(sc, "shrink")
    assert out.rows[0]["shrink"] and not out.rows[0]["cascade"]
    assert out.resume_steps == [3]
    respawn = simulate_scenario(sc, "reinit")
    assert out.rows[0]["mpi_recovery_s"] < respawn.rows[0]["mpi_recovery_s"]


def test_sim_growback_cascade_defers_to_grow():
    """A cascade on a dropped rank cannot fire while the rank is out of
    the world: the sim defers it to the grow that re-admits it (exactly
    when its next incarnation first runs), and the consensus still
    lands on the shrink cut."""
    out = simulate_scenario(BY_NAME["growback-mid-cascade"], "shrink")
    kinds = [("grow" if r["grow"] else
              "cascade" if r["cascade"] else
              "shrink" if r["shrink"] else "respawn") for r in out.rows]
    assert kinds == ["shrink", "grow", "cascade"]
    assert out.resume_steps == [2, 2]
    # under reinit the rank is respawned immediately, so the cascade
    # fires during the first recovery, before any repair
    out_r = simulate_scenario(BY_NAME["growback-mid-cascade"], "reinit")
    assert [r["cascade"] for r in out_r.rows] == [False, True]


def test_sim_min_data_parallel_floor_blocks_shrink():
    """Surfaced floor knob: the same cell with min_data_parallel raised
    to the node count refuses to shrink and over-subscribes instead."""
    from repro.scenarios import Fault as F, Scenario as S, Topology as T
    base = S(name="floor0", topology=T(2, 2, 0), steps=6,
             faults=(F("node", 2, 2),), strategies=("shrink",),
             expect_bit_identical=False)
    floored = S(name="floor2", topology=T(2, 2, 0), steps=6,
                faults=(F("node", 2, 2),), min_data_parallel=2,
                strategies=("shrink",))
    assert simulate_scenario(base, "shrink").rows[0]["shrink"]
    assert not simulate_scenario(floored, "shrink").rows[0]["shrink"]


def test_sim_node_hang_detected_by_daemon_ring():
    """Node-hang detection cost: the daemon ring pays its timeout plus
    the channel-EOF term — far below the rank-hang watchdog window, and
    with no stall watchdog armed at all in the cell."""
    sc = BY_NAME["node-hang-heartbeat"]
    assert sc.stall_timeout_s == 0
    out = simulate_scenario(sc, "reinit")
    assert out.rows[0]["detect_s"] > sc.heartbeat_timeout_s
    watchdog = simulate_scenario(BY_NAME["proc-hang"], "reinit")
    assert out.rows[0]["detect_s"] < watchdog.rows[0]["detect_s"]


def test_sim_heartbeat_ring_beats_watchdog_on_hangs():
    """The ring pays its timeout, the watchdog its stall window — the
    ring's window is chosen far tighter, and both exceed one period."""
    ring = simulate_scenario(BY_NAME["proc-hang-heartbeat"], "reinit")
    watchdog = simulate_scenario(BY_NAME["proc-hang"], "reinit")
    hb = BY_NAME["proc-hang-heartbeat"]
    assert ring.rows[0]["detect_s"] > hb.heartbeat_timeout_s
    assert ring.rows[0]["detect_s"] < watchdog.rows[0]["detect_s"]


# ------------------------------------------------ gray failures, policy

GRAY_CELLS = [s.name for s in CATALOG
              if any(f.how in GRAY_HOWS for f in s.faults)]


def _policy_variants(sc):
    """Both policy arms of one gray catalog cell, as (tolerate, drain).
    The cell carries one arm; the other is derived by flipping
    `mitigate` — same fault, same oracle (`expected_resume_steps`)."""
    if sc.mitigate:
        off = dataclasses.replace(
            sc, name=sc.name + "-off", mitigate=False, repairs=(),
            expect_bit_identical=True)
        return off, sc
    on = dataclasses.replace(
        sc, name=sc.name + "-on", mitigate=True, topology=T22S0,
        steps=max(sc.steps, 7), strategies=("shrink",),
        expect_bit_identical=False)
    return sc, on


@pytest.mark.parametrize("name", GRAY_CELLS)
def test_sim_gray_policy_matrix(name):
    """Every gray cell through BOTH policies on the sim substrate,
    against the shared oracle: mitigation off tolerates (no recovery
    row, no consensus entry, the whole cost is degraded throughput);
    mitigation on drains through an ordinary shrink at the withheld
    barrier's cut."""
    off, on = _policy_variants(BY_NAME[name])
    for strategy in off.strategies:
        out = engine.run_sim(off, strategy)
        assert out.expected_resume == [] and out.resume_steps == []
        tol = [r for r in out.detail["rows"] if r.get("gray")]
        assert tol and all(r["tolerated"] for r in tol)
        assert not any(r["shrink"] or r.get("grow") for r in tol)
    out = engine.run_sim(on, "shrink")
    exp = expected_resume_steps(on, "shrink")
    assert exp and out.resume_steps == exp
    drained = [r for r in out.detail["rows"] if r.get("gray")]
    assert drained
    for r in drained:
        assert r["shrink"] and not r["tolerated"]
        assert r["detect_s"] > 0 and r["mpi_recovery_s"] > 0
    # the policies' cost structure: draining pays only the detection
    # window at degraded pace, tolerating pays it to the end of the run
    tol = [r for r in engine.run_sim(off, "shrink").detail["rows"]
           if r.get("gray")]
    assert all(d["degraded_s"] < t["degraded_s"]
               for d, t in zip(drained, tol))


def test_sim_rehost_break_even_oracle():
    """The tolerate-vs-rehost oracle: BSP couples the job to its slowest
    member, so tolerating taxes every remaining step — re-hosting wins
    for severe degradation, loses for mild degradation or runs that are
    nearly over, and the break-even factor moves accordingly."""
    from repro.sim import APPS, ClusterCosts, rehost_break_even
    costs = ClusterCosts()
    assert costs.degraded_step_s(1.0, 6.0) == 6.0
    assert costs.degraded_step_s(1.0, 0.5) == 1.0   # never below healthy
    app = APPS["comd"]
    severe = rehost_break_even(app, 64, slow_factor=6.0, fail_step=5)
    assert severe["rehost_wins"]
    assert severe["rehost_extra_s"] < severe["tolerate_extra_s"]
    mild = rehost_break_even(app, 64, slow_factor=1.01, fail_step=5)
    assert not mild["rehost_wins"]
    # the crossover itself: fixed drain costs don't depend on the factor
    assert mild["break_even_factor"] == severe["break_even_factor"] > 1.0
    assert mild["break_even_factor"] > 1.01
    assert severe["break_even_factor"] < 6.0
    # failing near the end leaves little slowdown to win back
    late = rehost_break_even(app, 64, slow_factor=6.0,
                             fail_step=app.n_steps - 4)
    assert late["break_even_factor"] > severe["break_even_factor"]
    # a repairable host adds grow-back costs but caps the shrunk tax
    rep = rehost_break_even(app, 64, slow_factor=6.0, fail_step=5,
                            repair_after=4)
    assert rep["rehost_wins"]
    assert rep["break_even_factor"] > severe["break_even_factor"]


# ------------------------------------------------------ crash atomicity

_CRASH_SCRIPT = textwrap.dedent("""
    import os, sys
    import numpy as np
    from repro.checkpoint import FileCheckpointer
    from repro.scenarios import hooks

    d, point = sys.argv[1], sys.argv[2]
    ck = FileCheckpointer(d, keep=4, n_shards=2)
    rng = np.random.default_rng(0)
    s1 = {"a": rng.standard_normal(4000).astype(np.float32),
          "b": rng.standard_normal(500).astype(np.float32)}
    ck.save(1, s1)

    def killer(p, **ctx):
        if p == point and ctx.get("step") == 2:
            os.kill(os.getpid(), 9)
    hooks.install(killer)
    ck.save(2, {k: v * 2.0 for k, v in s1.items()})
    print("UNREACHABLE")
""")


@pytest.mark.parametrize("point", ["ckpt.file.shard",
                                   "ckpt.file.pre_commit"])
def test_crash_atomicity_mid_write(tmp_path, point):
    """SIGKILL (the real signal, in a subprocess) at a write-path
    interruption point: step 1 must still load and manifest-verify, the
    crashed step must be invisible, and the orphaned tmp dir must be
    GC'd by the next writer."""
    d = str(tmp_path / "ck")
    proc = subprocess.run(
        [sys.executable, "-c", _CRASH_SCRIPT, d, point],
        env=dict(os.environ, PYTHONPATH=SRC), capture_output=True,
        text=True, timeout=120)
    assert proc.returncode == -signal.SIGKILL, proc.stdout + proc.stderr
    assert "UNREACHABLE" not in proc.stdout

    import numpy as np
    from repro.checkpoint import FileCheckpointer
    orphans = [n for n in os.listdir(d) if n.startswith("tmp_")]
    assert orphans, "crash should have left a tmp dir behind"
    ck = FileCheckpointer(d, keep=4, n_shards=2)
    assert ck.steps() == [1]                     # step 2 never visible
    man, loaded = ck.load(1)                     # verify=True: manifest OK
    rng = np.random.default_rng(0)
    assert np.array_equal(loaded["a"],
                          rng.standard_normal(4000).astype(np.float32))
    ck.save(3, loaded)                           # next save GCs the orphan
    assert ck.steps() == [1, 3]
    assert not [n for n in os.listdir(d) if n.startswith("tmp_")]


def test_compose_hook_fires_on_delta_load(tmp_path):
    import numpy as np
    from repro.checkpoint import FileCheckpointer
    ck = FileCheckpointer(str(tmp_path), delta_every=4)
    state = {"w": np.arange(30000, dtype=np.float32)}
    ck.save(1, state)
    state = {"w": np.array(state["w"])}
    state["w"][7] += 1.0
    ck.save(2, state)
    fired = []
    hooks.install(lambda p, **ctx: fired.append((p, ctx.get("step"))))
    try:
        ck.load(2)
    finally:
        hooks.clear()
    assert ("ckpt.file.compose", 2) in fired


# ------------------------------------------------- bench: spill counters

def test_runtime_bench_spill_counters_move():
    """ROADMAP satellite: BuddyStore's spilled/resident counters must
    move under retention pressure, and runtime_bench surfaces them."""
    if ROOT not in sys.path:
        sys.path.insert(0, ROOT)
    from benchmarks.runtime_bench import bench_buddy_spill
    rows = []
    out = bench_buddy_spill(report=rows.append, n_steps=12, payload_kb=64,
                            retain=6, hot_steps=2)
    assert out["spilled_bytes"] > 0          # the cold tail hit disk
    assert out["resident_bytes"] > 0         # the hot set stayed in memory
    assert 0.0 < out["spill_frac"] < 1.0
    assert any(r.startswith("buddy_spilled_bytes,") for r in rows)
    assert any(r.startswith("buddy_resident_bytes,") for r in rows)


# -------------------------------------------------- real-runtime matrix

FAST = [s for s in CATALOG if "fast" in s.tags]
SLOW_MATRIX = [(s.name, st) for s in CATALOG
               for st in engine.real_strategies(s)]


def _ff_checksums(cache, tmp_path_factory, sc):
    """Fault-free reference checksums per (topology, run shape) —
    shared across the module: one real run per distinct reference."""
    topo = sc.topology
    key = (topo.nodes, topo.ranks_per_node, topo.spares, sc.steps, sc.dim)
    if key not in cache:
        wd = str(tmp_path_factory.mktemp(f"ff{topo.nodes}"))
        out = engine.run_real(fault_free(topo, steps=sc.steps, dim=sc.dim),
                              "reinit", wd, timeout=240)
        assert out.n_recoveries == 0
        cache[key] = out.checksums
    return cache[key]


@pytest.fixture(scope="module")
def ff_cache():
    return {}


def _assert_outcome(sc, out, ff):
    tolerated = not sc.mitigate and \
        all(f.how in GRAY_HOWS for f in sc.faults)
    if tolerated:
        # tolerate policy: the degradation must NOT trigger recovery
        assert out.n_recoveries == 0, \
            f"{sc.name}: tolerated gray fault triggered a recovery"
    else:
        assert out.n_recoveries >= 1, f"{sc.name}: no recovery happened"
    assert out.resume_consistent, \
        f"{sc.name}: resume {out.resume_steps} != {out.expected_resume}"
    if sc.expect_bit_identical:
        assert out.checksums == ff, \
            f"{sc.name}/{out.strategy}: recovered state diverged"


def test_heartbeat_detects_hung_neighbour(tmp_path):
    """Tentpole unit check, on the live process tree: a hung rank is
    SUSPECTed by its ring observer within the heartbeat window — the
    stall watchdog is DISARMED (stall_timeout_s == 0), so nothing else
    could have detected it."""
    sc = BY_NAME["proc-hang-heartbeat"]
    assert sc.stall_timeout_s == 0
    out = engine.run_real(sc, "reinit", str(tmp_path), timeout=240)
    events = out.detail["events"]
    assert len(events) == 1
    ev = events[0]
    assert ev["detected_by"] == "heartbeat"
    # detection within k periods past the timeout (scheduling slack on a
    # loaded host included) — nowhere near any watchdog-scale constant
    k = 5
    assert ev["detect_latency_s"] <= \
        sc.heartbeat_timeout_s + k * sc.heartbeat_period_s + 1.0
    assert out.resume_consistent
    assert out.resume_steps == [sc.faults[0].step]


def test_daemon_heartbeat_detects_hung_node(tmp_path):
    """Satellite unit check, on the live process tree: a hung *daemon*
    (whole-node hang: children muted, control channel open, nothing
    relayed) is SUSPECT_NODEd by its ring-successor daemon within the
    heartbeat window — the stall watchdog is DISARMED, and rank-level
    observation cannot see through a daemon that relays nothing."""
    sc = BY_NAME["node-hang-heartbeat"]
    assert sc.stall_timeout_s == 0
    out = engine.run_real(sc, "reinit", str(tmp_path), timeout=240)
    events = out.detail["events"]
    assert len(events) == 1
    ev = events[0]
    assert ev["kind"] == "node"
    assert ev["detected_by"] == "heartbeat"
    # detection within k periods past the timeout (scheduling slack on a
    # loaded host included) — nowhere near any watchdog-scale constant
    k = 5
    assert ev["detect_latency_s"] <= \
        sc.heartbeat_timeout_s + k * sc.heartbeat_period_s + 1.0
    assert out.resume_consistent
    assert out.resume_steps == [sc.faults[0].step]


@pytest.mark.scenario_fast
def test_real_growback_world_reexpands(tmp_path, tmp_path_factory,
                                       ff_cache):
    """The acceptance-criterion cell, checked in mechanism detail on the
    live process tree: the node loss shrinks 4->2 at the cut, the
    repaired node's REJOIN grows the world back to its pre-fault size at
    a checkpoint boundary (bumped mesh epoch), the consensus lands on
    the pinned pre-shrink cut, and the re-expanded run finishes
    bit-identically to fault-free."""
    sc = BY_NAME["shrink-then-growback"]
    ff = _ff_checksums(ff_cache, tmp_path_factory, sc)
    out = engine.run_real(sc, "shrink", str(tmp_path), timeout=240)
    events = out.detail["events"]
    assert [bool(ev.get("shrink")) for ev in events] == [True, False]
    assert [bool(ev.get("grow")) for ev in events] == [False, True]
    shrunk, grown = events
    assert shrunk["world_after"] == 2 and shrunk["dropped"] == [2, 3]
    assert grown["added"] == [2, 3]
    assert grown["world_after"] == 4          # pre-fault size restored
    assert grown["mesh_epoch"] > shrunk["mesh_epoch"]
    assert grown["detected_by"] == "rejoin"
    assert out.resume_steps == [2, 2]         # both land on the cut
    assert out.resume_consistent
    assert len(out.checksums) == 4            # the full world reports
    assert out.checksums == ff                # bit-identical continuation


@pytest.mark.scenario_fast
def test_real_process_shrink_uneven_groups(tmp_path):
    """Process-level shrink on the live tree: a single-rank loss with an
    empty pool drops that rank (uneven groups: 2+1), survivors
    re-balance and resume at the oracle cut."""
    sc = BY_NAME["proc-loss-shrink"]
    out = engine.run_real(sc, "shrink", str(tmp_path), timeout=240)
    events = out.detail["events"]
    assert len(events) == 1
    ev = events[0]
    assert ev["shrink"] and ev["dropped"] == [1]
    assert ev["world_after"] == 3
    assert ev["mesh_epoch"] is not None
    assert len(out.checksums) == 3            # survivors only
    assert out.resume_consistent, \
        (out.resume_steps, out.expected_resume)


@pytest.mark.scenario_fast
def test_real_shrink_world_contracts(tmp_path):
    """The scenario_fast shrink cell, checked in mechanism detail: the
    first node loss is absorbed by the spare (no shrink), the second
    finds the pool empty and drops that node's ranks — survivors
    re-balance, resume at the oracle cut, and only they report DONE."""
    sc = BY_NAME["spare-pool-exhaustion"]
    out = engine.run_real(sc, "shrink", str(tmp_path), timeout=240)
    events = out.detail["events"]
    assert [bool(ev.get("shrink")) for ev in events] == [False, True]
    shrunk = events[1]
    assert shrunk["world_after"] == 4
    assert len(shrunk["dropped"]) == sc.topology.ranks_per_node
    assert shrunk["mesh_epoch"] is not None
    assert len(out.checksums) == 4          # survivors only
    assert out.resume_consistent, \
        (out.resume_steps, out.expected_resume)


@pytest.mark.scenario_fast
def test_real_replica_zero_rollback(tmp_path, tmp_path_factory, ff_cache):
    """The tentpole property, on the live process tree: a fenced rank
    kill at step N under the replica mode is recovered by PROMOTE — the
    resume step IS the failure step (no rollback, no recomputed steps),
    no epoch bump reaches the survivors, and the run finishes
    bit-identical to fault-free."""
    sc = BY_NAME["replica-promote"]
    ff = _ff_checksums(ff_cache, tmp_path_factory, sc)
    out = engine.run_real(sc, "replica", str(tmp_path), timeout=240)
    events = out.detail["events"]
    assert len(events) == 1
    ev = events[0]
    assert ev["promote"] is True
    assert ev["promoted"] == [sc.faults[0].rank]
    assert ev["resume_step"] == sc.faults[0].step      # zero rollback
    assert ev["promote_complete_s"] > 0
    # promote-and-reform: no respawn happened, so no cascade counter
    assert not ev.get("cascades")
    assert out.resume_consistent
    assert out.checksums == ff


@pytest.mark.scenario_fast
def test_real_replica_promotion_window_merge(tmp_path, tmp_path_factory,
                                             ff_cache):
    """A shadow dying inside the promotion window (after PROMOTE, before
    its barrier arrival completes the stalled cut) must MERGE into the
    recovery in flight — one consensus entry, a reinit fallback on the
    SAME event, never a deadlocked barrier or a double promote."""
    sc = BY_NAME["replica-promote-cascade"]
    ff = _ff_checksums(ff_cache, tmp_path_factory, sc)
    out = engine.run_real(sc, "replica", str(tmp_path), timeout=240)
    events = out.detail["events"]
    assert len(events) == 1                            # merged, not a 2nd
    ev = events[0]
    assert ev["promote_window_death"] == [sc.faults[0].rank]
    assert ev["promote"] is False                      # promotion voided
    assert out.resume_steps == [sc.faults[0].step]
    assert out.resume_consistent
    assert out.checksums == ff


@pytest.mark.scenario_fast
def test_real_replica_shadow_loss_falls_back(tmp_path, tmp_path_factory,
                                             ff_cache):
    """Losing the shadow first degrades cover: the later primary kill
    finds no warm shadow and falls back to the reinit path — recorded as
    a shadow_lost event plus a non-promote recovery at the reinit cut."""
    sc = BY_NAME["replica-shadow-loss"]
    ff = _ff_checksums(ff_cache, tmp_path_factory, sc)
    out = engine.run_real(sc, "replica", str(tmp_path), timeout=240)
    events = out.detail["events"]
    final = events[-1]
    assert final["promote"] is False          # no warm shadow survived
    assert final["resume_step"] == sc.faults[1].step
    if len(events) == 2:
        # cover loss detected before the primary kill: a shadow_lost
        # entry (no consensus of its own), then the reinit fallback
        assert events[0].get("shadow_lost") == sc.faults[0].rank
        assert events[0].get("resume_step") is None
    else:
        # the shadow's SIGCHLD raced the primary's fenced kill: the root
        # promoted a corpse and the promotion-window merge voided it on
        # the same event — still one consensus, still no deadlock
        assert final.get("promote_window_death") == [sc.faults[1].rank]
    assert out.resume_consistent
    assert out.checksums == ff


@pytest.mark.scenario_fast
def test_real_replica_root_loss_standby_takeover(tmp_path,
                                                 tmp_path_factory,
                                                 ff_cache):
    """Root (HNP) loss under the replica mode: the warm standby takes
    over — daemons re-home, in-flight sync messages are resent on
    RESYNC, the run finishes with the full world reporting, and no
    external relaunch happens (the engine would have recorded one)."""
    sc = BY_NAME["replica-root-loss-standby"]
    ff = _ff_checksums(ff_cache, tmp_path_factory, sc)
    out = engine.run_real(sc, "replica", str(tmp_path), timeout=240)
    events = out.detail["events"]
    assert any(ev.get("standby_takeover") for ev in events)
    assert out.detail["relaunches"] == 0
    assert len(out.checksums) == sc.topology.world
    assert out.resume_consistent
    assert out.checksums == ff


@pytest.mark.scenario_fast
@pytest.mark.parametrize("name", GRAY_CELLS)
def test_real_gray_policy_flip(name, tmp_path, tmp_path_factory,
                               ff_cache):
    """The OTHER policy arm of each gray catalog cell on the live
    process tree (the catalog's own arm runs in the fast matrix below):
    a tolerate arm must finish with ZERO recoveries bit-identical to
    fault-free; a drain arm must be flagged by the root's straggler
    tracker and resume from the drain cut the oracle names."""
    base = BY_NAME[name]
    off, on = _policy_variants(base)
    flipped = off if base.mitigate else on
    out = engine.run_real(flipped, "shrink", str(tmp_path), timeout=240)
    if flipped is off:
        assert out.n_recoveries == 0 and out.resume_steps == []
        ff = _ff_checksums(ff_cache, tmp_path_factory, flipped)
        assert out.checksums == ff
    else:
        exp = expected_resume_steps(flipped, "shrink")
        assert exp and out.resume_steps == exp
        ev = out.detail["events"][0]
        assert ev["detected_by"] == "straggler"
        assert ev.get("detect_latency_s", 0) > 0
        assert ev.get("shrink") and ev.get("dropped")
    assert out.resume_consistent


@pytest.mark.scenario_fast
def test_real_slow_node_drain_grows_back(tmp_path, tmp_path_factory,
                                         ff_cache):
    """The sick-host lifecycle in mechanism detail on the live tree:
    every rank on the degraded node turns persistently late, the
    straggler tracker attributes the lateness to exactly that node's
    ranks, the drain is an ordinary node shrink at the withheld cut,
    and the repaired node's rejoin re-expands the world — finishing
    bit-identical to fault-free."""
    sc = BY_NAME["slow-node-drain-growback"]
    ff = _ff_checksums(ff_cache, tmp_path_factory, sc)
    out = engine.run_real(sc, "shrink", str(tmp_path), timeout=240)
    events = out.detail["events"]
    assert [bool(ev.get("shrink")) for ev in events] == [True, False]
    assert [bool(ev.get("grow")) for ev in events] == [False, True]
    drained, grown = events
    assert drained["kind"] == "node"
    assert drained["detected_by"] == "straggler"
    assert sorted(drained["dropped"]) == [2, 3]    # the sick node only
    assert grown["added"] == [2, 3]
    assert grown["world_after"] == 4
    assert out.resume_steps == [4, 4]
    assert out.resume_consistent
    assert out.checksums == ff                     # full world, bit-equal


@pytest.mark.scenario_fast
def test_real_flap_node_twice(tmp_path, tmp_path_factory, ff_cache):
    """A flapping node on the live tree: two full shrink -> grow-back
    round-trips in one run, each landing on its own pinned cut, the
    world restored to full size, bit-identical finish."""
    sc = BY_NAME["flap-node-twice"]
    ff = _ff_checksums(ff_cache, tmp_path_factory, sc)
    out = engine.run_real(sc, "shrink", str(tmp_path), timeout=240)
    events = out.detail["events"]
    assert [bool(ev.get("shrink")) for ev in events] == \
        [True, False, True, False]
    assert [bool(ev.get("grow")) for ev in events] == \
        [False, True, False, True]
    assert events[-1]["world_after"] == 4
    assert out.resume_steps == [2, 2, 5, 5]
    assert out.resume_consistent
    assert out.checksums == ff


@pytest.mark.scenario_fast
def test_real_flap_refail_in_rejoin_regression(tmp_path, tmp_path_factory,
                                               ff_cache):
    """Dedicated regression for the rejoin-consensus window: node1 dies
    and is dropped; its repair rejoins, and a re-admitted rank dies
    again right after pulling its frames — while the grow's JOIN window
    is still open. The death must merge into the in-flight grow
    recovery (a respawn within the SAME consensus — no third entry),
    the held barrier must release, and the full world finishes
    bit-identical to fault-free."""
    sc = BY_NAME["flap-refail-in-rejoin"]
    ff = _ff_checksums(ff_cache, tmp_path_factory, sc)
    out = engine.run_real(sc, "shrink", str(tmp_path), timeout=240)
    events = out.detail["events"]
    shrinks = [ev for ev in events if ev.get("shrink")]
    grows = [ev for ev in events if ev.get("grow")]
    assert len(shrinks) == 1 and len(grows) == 1
    assert grows[0]["world_after"] == 4
    assert out.resume_steps == [2, 2]              # no third consensus
    assert out.resume_consistent
    assert len(out.checksums) == 4
    assert out.checksums == ff


@pytest.mark.scenario_fast
@pytest.mark.parametrize("name", [s.name for s in FAST])
def test_real_scenario_fast(name, tmp_path, tmp_path_factory, ff_cache):
    sc = BY_NAME[name]
    ff = _ff_checksums(ff_cache, tmp_path_factory, sc)
    strategy = engine.real_strategies(sc)[0]
    out = engine.run_real(sc, strategy, str(tmp_path), timeout=240)
    _assert_outcome(sc, out, ff)


@pytest.mark.scenario_slow
@pytest.mark.parametrize("name,strategy", SLOW_MATRIX)
def test_real_scenario_matrix_3x_stable(name, strategy, tmp_path,
                                        tmp_path_factory, ff_cache):
    """The no-flake proof: every real-runtime scenario x strategy passes
    three consecutive runs with identical assertions."""
    sc = BY_NAME[name]
    ff = _ff_checksums(ff_cache, tmp_path_factory, sc)
    for attempt in range(3):
        out = engine.run_real(sc, strategy,
                              str(tmp_path / f"run{attempt}"), timeout=300)
        _assert_outcome(sc, out, ff)
