"""Property tests for Algorithms 1 & 2 (the paper's §3.1 recovery logic)."""
import pytest
from _hyp import given, settings, st

from repro.core import (ClusterView, FailureEvent, FailureType, RankState,
                        apply_recovery, daemon_handle_reinit,
                        root_handle_failure)


@st.composite
def clusters(draw):
    n_nodes = draw(st.integers(1, 8))
    rpn = draw(st.integers(1, 16))
    spares = draw(st.integers(0, 2))
    return ClusterView.build(n_nodes, rpn, spares), n_nodes, rpn


@given(clusters(), st.data())
@settings(max_examples=50, deadline=None)
def test_process_failure_invariants(cluster, data):
    view, n_nodes, rpn = cluster
    ranks = view.ranks()
    victim = data.draw(st.sampled_from(ranks))
    before = set(ranks)
    cmd = root_handle_failure(
        view, FailureEvent(kind=FailureType.PROCESS, rank=victim))
    states = apply_recovery(view, cmd)
    # non-shrinking: world preserved
    assert set(states) == before
    # exactly the victim is RESTARTED; everyone else REINITED
    restarted = {r for r, s in states.items() if s is RankState.RESTARTED}
    assert restarted == {victim}
    assert all(s is RankState.REINITED for r, s in states.items()
               if r != victim)
    # victim re-spawned on its original node
    assert cmd.respawns[0].daemon == view.parent(victim)


@given(clusters(), st.data())
@settings(max_examples=50, deadline=None)
def test_node_failure_invariants(cluster, data):
    view, n_nodes, rpn = cluster
    if n_nodes < 2:
        return
    dead = data.draw(st.sampled_from(
        [d for d in view.daemons() if view.children[d]]))
    lost = set(view.children[dead])
    before = set(view.ranks())
    loads_before = {d: len(c) for d, c in view.children.items()
                    if d != dead}
    least = min((n, d) for d, n in loads_before.items())[1]
    cmd = root_handle_failure(
        view, FailureEvent(kind=FailureType.NODE, node=dead))
    states = apply_recovery(view, cmd)
    assert set(states) == before                      # non-shrinking
    restarted = {r for r, s in states.items() if s is RankState.RESTARTED}
    assert restarted == lost
    # Algorithm 1: all lost ranks land on the least-loaded surviving node
    assert {r.daemon for r in cmd.respawns} == {least}
    assert dead not in view.children


def test_each_rank_handled_exactly_once():
    view = ClusterView.build(3, 4, 1)
    cmd = root_handle_failure(
        view, FailureEvent(kind=FailureType.PROCESS, rank=5))
    seen = []
    for d in view.daemons():
        acts = daemon_handle_reinit(view, d, cmd)
        seen += list(acts.signal_survivors) + list(acts.spawn)
    assert sorted(seen) == view.ranks()


def test_epoch_monotonic():
    view = ClusterView.build(2, 4, 1)
    e0 = view.epoch
    root_handle_failure(view, FailureEvent(kind=FailureType.PROCESS, rank=0))
    e1 = view.epoch
    root_handle_failure(view, FailureEvent(kind=FailureType.PROCESS, rank=1))
    assert view.epoch > e1 > e0


def test_no_survivors_raises():
    view = ClusterView.build(1, 4)
    with pytest.raises(RuntimeError):
        root_handle_failure(
            view, FailureEvent(kind=FailureType.NODE, node="node0"))
