"""Property tests for Algorithms 1 & 2 (the paper's §3.1 recovery logic)."""
import pytest
from _hyp import given, settings, st

from repro.core import (ClusterView, FailureEvent, FailureType, RankState,
                        apply_recovery, daemon_handle_reinit,
                        root_handle_failure)


@st.composite
def clusters(draw):
    n_nodes = draw(st.integers(1, 8))
    rpn = draw(st.integers(1, 16))
    spares = draw(st.integers(0, 2))
    return ClusterView.build(n_nodes, rpn, spares), n_nodes, rpn


@given(clusters(), st.data())
@settings(max_examples=50, deadline=None)
def test_process_failure_invariants(cluster, data):
    view, n_nodes, rpn = cluster
    ranks = view.ranks()
    victim = data.draw(st.sampled_from(ranks))
    before = set(ranks)
    cmd = root_handle_failure(
        view, FailureEvent(kind=FailureType.PROCESS, rank=victim))
    states = apply_recovery(view, cmd)
    # non-shrinking: world preserved
    assert set(states) == before
    # exactly the victim is RESTARTED; everyone else REINITED
    restarted = {r for r, s in states.items() if s is RankState.RESTARTED}
    assert restarted == {victim}
    assert all(s is RankState.REINITED for r, s in states.items()
               if r != victim)
    # victim re-spawned on its original node
    assert cmd.respawns[0].daemon == view.parent(victim)


@given(clusters(), st.data())
@settings(max_examples=50, deadline=None)
def test_node_failure_invariants(cluster, data):
    view, n_nodes, rpn = cluster
    if n_nodes < 2:
        return
    dead = data.draw(st.sampled_from(
        [d for d in view.daemons() if view.children[d]]))
    lost = set(view.children[dead])
    before = set(view.ranks())
    loads_before = {d: len(c) for d, c in view.children.items()
                    if d != dead}
    least = min((n, d) for d, n in loads_before.items())[1]
    cmd = root_handle_failure(
        view, FailureEvent(kind=FailureType.NODE, node=dead))
    states = apply_recovery(view, cmd)
    assert set(states) == before                      # non-shrinking
    restarted = {r for r, s in states.items() if s is RankState.RESTARTED}
    assert restarted == lost
    # Algorithm 1: all lost ranks land on the least-loaded surviving node
    assert {r.daemon for r in cmd.respawns} == {least}
    assert dead not in view.children


def test_each_rank_handled_exactly_once():
    view = ClusterView.build(3, 4, 1)
    cmd = root_handle_failure(
        view, FailureEvent(kind=FailureType.PROCESS, rank=5))
    seen = []
    for d in view.daemons():
        acts = daemon_handle_reinit(view, d, cmd)
        seen += list(acts.signal_survivors) + list(acts.spawn)
    assert sorted(seen) == view.ranks()


def test_epoch_monotonic():
    view = ClusterView.build(2, 4, 1)
    e0 = view.epoch
    root_handle_failure(view, FailureEvent(kind=FailureType.PROCESS, rank=0))
    e1 = view.epoch
    root_handle_failure(view, FailureEvent(kind=FailureType.PROCESS, rank=1))
    assert view.epoch > e1 > e0


def test_no_survivors_raises():
    view = ClusterView.build(1, 4)
    with pytest.raises(RuntimeError):
        root_handle_failure(
            view, FailureEvent(kind=FailureType.NODE, node="node0"))


# ------------------------------------------------ elastic / shrink path

@given(clusters(), st.data())
@settings(max_examples=50, deadline=None)
def test_shrink_node_failure_invariants(cluster, data):
    from repro.core import root_handle_failure_shrink
    view, n_nodes, rpn = cluster
    if n_nodes < 2:
        return                        # shrinking away the last node is
                                      # illegal by construction
    ranks = view.ranks()
    victim = data.draw(st.sampled_from(ranks))
    dead = view.parent(victim)
    lost = set(view.children[dead])
    before = set(ranks)
    e0 = view.epoch
    cmd = root_handle_failure_shrink(
        view, FailureEvent(kind=FailureType.NODE, rank=victim, node=dead))
    # the world shrinks by exactly the dead node's ranks, nothing respawns
    assert set(cmd.dropped) == lost
    assert set(cmd.world) == before - lost
    assert set(view.ranks()) == before - lost
    assert dead not in view.children
    assert cmd.epoch == view.epoch > e0


def test_elastic_decide_consults_spare_pool():
    from repro.core import ElasticManager, MeshEpoch
    view = ClusterView.build(2, 2, 1)
    em = ElasticManager(view, MeshEpoch(epoch=0, data_parallel=2,
                                        model_parallel=2))
    node_f = FailureEvent(kind=FailureType.NODE, rank=2, node="node1")
    proc_f = FailureEvent(kind=FailureType.PROCESS, rank=1)
    # any failure respawns while a spare slot remains (process failures
    # respawn in place; node failures re-host onto the spare)
    assert em.decide(proc_f) == "respawn"
    assert em.decide(node_f) == "respawn"
    # Algorithm 1 re-hosts onto the spare, emptying the pool
    root_handle_failure(view, node_f)
    assert em.spares() == []
    # pool exhausted: both node and single-rank losses now shrink...
    live_node = FailureEvent(kind=FailureType.NODE, rank=0, node="node0")
    assert em.decide(live_node) == "shrink"
    assert em.decide(proc_f) == "shrink"
    # ...but never below the min_data_parallel world floor
    em.min_data_parallel = 2          # floor = 2 groups * 2 ranks = world
    assert em.decide(live_node) == "respawn"
    assert em.decide(proc_f) == "respawn"


def test_membership_rejoin_grows_back():
    """The bidirectional lifecycle at the protocol level: shrink a node
    out of the world, rejoin it, and the grow restores exactly the
    pre-shrink membership with strictly monotonic mesh epochs."""
    from repro.core import ElasticManager, MeshEpoch
    view = ClusterView.build(2, 2, 0)
    em = ElasticManager(view, MeshEpoch(epoch=0, data_parallel=2,
                                        model_parallel=2))
    before = set(view.ranks())
    cmd = em.shrink(FailureEvent(kind=FailureType.NODE, rank=2,
                                 node="node1"))
    assert set(cmd.dropped) == {2, 3} and em.dropped == [2, 3]
    assert em.mesh.epoch == 1 and em.mesh.data_parallel == 1
    # a rejoin with a shrunk world is admitted as a grow
    assert em.admit("node1") == "grow"
    grow = em.grow("node1")
    assert set(grow.added) == {2, 3}
    assert set(grow.world) == before and set(view.ranks()) == before
    assert em.dropped == []
    assert grow.mesh_epoch == em.mesh.epoch == 2
    assert em.mesh.data_parallel == 2
    # a rejoin with a full world joins the spare pool instead
    assert em.admit("late-node") == "spare"
    em.grant_spare("late-node")
    assert em.spares() == ["late-node"]
    # process-level shrink leaves uneven groups, still above the floor
    cmd = em.shrink(FailureEvent(kind=FailureType.PROCESS, rank=1))
    assert cmd.dropped == (1,) and em.dropped == [1]
    assert em.mesh.epoch == 3
    em.check_invariants()


def test_shrink_contracts_and_bumps_epoch():
    from repro.core import ElasticManager, MeshEpoch
    view = ClusterView.build(3, 2, 0)
    em = ElasticManager(view, MeshEpoch(epoch=0, data_parallel=3,
                                        model_parallel=2))
    em.shrink(FailureEvent(kind=FailureType.NODE, rank=4, node="node2"))
    assert em.mesh.data_parallel == 2 and em.mesh.epoch == 1
    em.shrink(FailureEvent(kind=FailureType.NODE, rank=2, node="node1"))
    assert em.mesh.data_parallel == 1 and em.mesh.epoch == 2
    # at the floor: shrink refused, caller falls back to global restart
    last = FailureEvent(kind=FailureType.NODE, rank=0, node="node0")
    assert em.decide(last) == "respawn"
    em.check_invariants()
