"""End-to-end behaviour: the launchers run and produce coherent reports."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _launch(mod, *args, timeout=300):
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-m", mod] + list(args),
                          env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    return proc.stdout


def test_train_launcher_with_failure(tmp_path):
    report = str(tmp_path / "r.json")
    _launch("repro.launch.train", "--arch", "paper-demo", "--reduced",
            "--steps", "8", "--batch", "2", "--seq", "32",
            "--strategy", "reinit", "--fail-kind", "process",
            "--ckpt-dir", str(tmp_path / "ck"), "--report", report)
    with open(report) as f:
        rep = json.load(f)
    assert rep["final_step"] == 8
    assert len(rep["recoveries"]) == 1
    assert rep["recoveries"][0]["strategy"] == "Reinit++"


def test_serve_launcher(tmp_path):
    out = _launch("repro.launch.serve", "--arch", "paper-demo",
                  "--reduced", "--requests", "4", "--max-new", "4",
                  "--slots", "2", "--max-len", "64",
                  "--snapshot-every", "2")
    rep = json.loads(out[out.index("{"):])
    assert rep["requests"] == 4 and rep["snapshot_taken"]


def test_dryrun_single_cell_smoke(tmp_path):
    """The multi-pod dry-run entry point works end to end on the smallest
    assigned arch/shape (full 80-cell sweep runs via benchmarks)."""
    out = _launch("repro.launch.dryrun", "--arch", "seamless-m4t-medium",
                  "--shape", "train_4k", "--mesh", "pod",
                  "--microbatches", "4",
                  "--out", str(tmp_path), timeout=900)
    assert "OK" in out
    path = os.path.join(str(tmp_path),
                        "seamless-m4t-medium__train_4k__pod.json")
    with open(path) as f:
        art = json.load(f)
    assert art["collective_bytes"]["total"] > 0
    assert art["memory"]["argument_bytes"] > 0
    assert art["analytic"]["flops_total"] > 0
