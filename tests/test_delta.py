"""Delta checkpoints: tile digests, delta frames, chain compose, tiering.

The load-bearing property: base + N delta frames restores a state
BIT-EXACTLY equal to a full snapshot — across dtype-boundary leaves
(bf16/f16/i8), partial trailing tiles, scalars and empties — enforced
both at the serde layer and through FileCheckpointer's manifest-verified
composed loads.
"""
import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import FileCheckpointer, serde
from repro.checkpoint.manifest import tree_digest
from repro.checkpoint.memory_ckpt import BuddyStore
from repro.kernels.checksum.ref import (TILE_BYTES, checksum_words_ref,
                                        scalar_from_tiles,
                                        tile_checksums_ref)

BF16 = np.dtype(ml_dtypes.bfloat16)


def _bit_equal(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return (str(a.dtype) == str(b.dtype) and a.shape == b.shape
            and np.ascontiguousarray(a).reshape(-1).view(np.uint8).tobytes()
            == np.ascontiguousarray(b).reshape(-1).view(np.uint8).tobytes())


# ------------------------------------------------------------ tile digests

def test_tile_digests_fold_to_scalar_checksum():
    rng = np.random.default_rng(3)
    for arr in [rng.standard_normal(5).astype(np.float32),
                rng.standard_normal(TILE_BYTES // 4).astype(np.float32),
                rng.standard_normal(TILE_BYTES // 4 + 1).astype(np.float32),
                rng.standard_normal(3000).astype(BF16),
                rng.integers(0, 255, 3 * TILE_BYTES + 7).astype(np.uint8),
                np.zeros((0,), np.float32),
                np.float64(2.5).reshape(())]:
        tiles = tile_checksums_ref(arr)
        assert scalar_from_tiles(tiles) == checksum_words_ref(arr)


def test_tile_digest_localizes_change():
    a = np.zeros(4 * TILE_BYTES // 4, np.float32)     # 4 exact tiles
    b = a.copy()
    b[TILE_BYTES // 4 + 3] = 1.0                      # dirty tile 1 only
    ta, tb = tile_checksums_ref(a), tile_checksums_ref(b)
    changed = np.any(ta != tb, axis=1)
    assert list(changed) == [False, True, False, False]


def test_tile_digest_device_parity():
    from repro.kernels.checksum.ops import tile_checksums
    rng = np.random.default_rng(5)
    for arr in [rng.standard_normal(2048).astype(np.float32),
                rng.standard_normal(513).astype(np.float16)]:
        assert np.array_equal(tile_checksums(jnp.asarray(arr)),
                              tile_checksums_ref(arr))


def test_tile_digest_pallas_interpret_parity():
    from repro.kernels.checksum.kernel import tile_checksum_kernel
    from repro.kernels.checksum.ops import _device_words
    rng = np.random.default_rng(6)
    arr = rng.standard_normal(3 * TILE_BYTES // 4 + 11).astype(np.float32)
    words = _device_words(jnp.asarray(arr))
    got = np.asarray(tile_checksum_kernel(words, interpret=True))
    assert np.array_equal(got, tile_checksums_ref(arr))


# ------------------------------------------------------------ serde deltas

def _mutate(flat, rng, n_edits=3):
    """Randomly mutate a few scattered elements of a few leaves."""
    out = {k: np.array(v) for k, v in flat.items()}
    keys = [k for k in out if out[k].size]
    for k in rng.choice(keys, size=min(n_edits, len(keys)),
                        replace=False) if keys else []:
        v = out[k].reshape(-1)
        idx = rng.integers(0, v.size)
        v[idx] = v[idx] + np.asarray(1, dtype=v.dtype) \
            if v.dtype != np.bool_ else ~v[idx]
    return out


@st.composite
def boundary_leaves(draw):
    dtype = draw(st.sampled_from(
        [np.float32, np.float16, np.int8, np.uint64, BF16]))
    # sizes straddling word/tile boundaries, incl. partial trailing tiles
    n = draw(st.sampled_from(
        [0, 1, 3, 7, TILE_BYTES // 4 - 1, TILE_BYTES // 4,
         TILE_BYTES // 4 + 1, 2 * TILE_BYTES // 4 + 13]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return rng.standard_normal(n).astype(dtype)


@given(st.dictionaries(st.text(alphabet="abcd", min_size=1, max_size=4),
                       boundary_leaves(), min_size=1, max_size=5),
       st.integers(1, 4), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_base_plus_n_deltas_bit_exact(flat, n_deltas, seed):
    """base + N chained delta frames == the full snapshot, bit for bit."""
    rng = np.random.default_rng(seed)
    frames = {0: serde.to_bytes(flat, {"step": 0})}
    tiles = serde.tile_digests(flat)
    cur = flat
    for step in range(1, n_deltas + 1):
        cur = _mutate(cur, rng)
        plan = serde.delta_plan(cur, tiles)
        frames[step] = serde.to_delta_bytes(cur, plan, base_step=step - 1,
                                            extra={"step": step})
        tiles = plan.new_tiles
    assert serde.composable_steps(frames) == list(range(n_deltas + 1))
    extra, got = serde.compose(frames, n_deltas)
    assert extra == {"step": n_deltas}
    want = serde.from_bytes(serde.to_bytes(cur))[1]   # full-snapshot oracle
    assert set(got) == set(want)
    for k in want:
        assert _bit_equal(want[k], got[k]), k


def test_delta_plan_marks_new_and_reshaped_leaves_full():
    a = {"x": np.arange(100, dtype=np.float32)}
    tiles = serde.tile_digests(a)
    b = {"x": np.arange(50, dtype=np.float32),       # reshaped
         "y": np.ones(10, np.float32)}               # new
    plan = serde.delta_plan(b, tiles)
    assert plan.entries["x"] is None and plan.entries["y"] is None


def test_delta_plan_marks_same_bytes_reshape_full():
    """Identical bytes under a different shape/dtype must not be treated
    as a clean leaf — the composed state would keep the stale shape."""
    a = {"x": np.arange(1024, dtype=np.float32).reshape(2, 512)}
    tiles = serde.tile_digests(a)
    b = {"x": np.asarray(a["x"]).reshape(1024)}        # same bytes
    plan = serde.delta_plan(b, tiles)
    assert plan.entries["x"] is None                   # full leaf
    c = {"x": np.asarray(a["x"]).view(np.int32)}       # same bytes, recast
    plan = serde.delta_plan(c, tiles)
    assert plan.entries["x"] is None


def test_delta_plan_infeasible_on_removed_leaf():
    a = {"x": np.ones(4, np.float32), "y": np.ones(4, np.float32)}
    tiles = serde.tile_digests(a)
    plan = serde.delta_plan({"x": np.ones(4, np.float32)}, tiles)
    assert not plan.feasible and plan.dirty_fraction == 1.0


def test_clean_snapshot_delta_is_header_only():
    flat = {"x": np.arange(5000, dtype=np.float32)}
    tiles = serde.tile_digests(flat)
    plan = serde.delta_plan(flat, tiles)
    buf = serde.to_delta_bytes(flat, plan, base_step=1)
    assert len(buf) < 256
    _, _, out = serde.apply_delta(serde.from_bytes(
        serde.to_bytes(flat))[1], buf)
    assert _bit_equal(out["x"], flat["x"])


def test_broken_chain_not_composable():
    flat = {"x": np.arange(64, dtype=np.float32)}
    tiles = serde.tile_digests(flat)
    plan = serde.delta_plan(flat, tiles)
    d = serde.to_delta_bytes(flat, plan, base_step=1)
    assert serde.composable_steps({2: d}) == []
    with pytest.raises(KeyError):
        serde.compose({2: d}, 2)


@st.composite
def dirty_mask_edits(draw):
    """A random sparse edit plan: (leaf_index, start_frac, run_len) runs
    to dirty — exercises arbitrary tile masks, not just single elements."""
    n_runs = draw(st.integers(0, 4))
    return [(draw(st.integers(0, 7)),
             draw(st.floats(0.0, 1.0)),
             draw(st.integers(1, 600)))
            for _ in range(n_runs)]


def _apply_edits(flat, edits, rng):
    out = {k: np.array(v) for k, v in flat.items()}
    keys = sorted(out)
    for leaf_i, start_frac, run in edits:
        k = keys[leaf_i % len(keys)]
        v = out[k].reshape(-1)
        if not v.size:
            continue
        lo = int(start_frac * (v.size - 1))
        hi = min(v.size, lo + run)
        v[lo:hi] = rng.standard_normal(hi - lo).astype(v.dtype) \
            if v.dtype != np.bool_ else ~v[lo:hi]
    return out


def _check_dirty_mask_chains(seed, retain, edit_plans):
    """Random dirty masks x random chain lengths: every frame the
    retention window keeps must compose bit-exactly, and the window's
    chain walk must never reference a pruned (GC'd) base — the
    BuddyStore-prune + composable_steps contract under arbitrary
    dirtiness."""
    rng = np.random.default_rng(seed)
    flat = {"a": rng.standard_normal(2500).astype(np.float32),
            "b": rng.standard_normal(700).astype(BF16),
            "c": rng.integers(0, 255, 3 * TILE_BYTES + 7).astype(np.uint8)}
    store = BuddyStore(0, 2, retain=retain)
    store.save(1, serde.to_bytes(flat, {"step": 1}))
    tiles = serde.tile_digests(flat)
    oracle = {1: flat}
    cur = flat
    for i, edits in enumerate(edit_plans):
        step = i + 2
        cur = _apply_edits(cur, edits, rng)
        plan = serde.delta_plan(cur, tiles)
        if plan.feasible and i % 3 != 2:          # random-ish chain breaks
            frame = serde.to_delta_bytes(cur, plan, base_step=step - 1,
                                         extra={"step": step})
        else:
            frame = serde.to_bytes(cur, {"step": step})
        store.save(step, frame)
        tiles = plan.new_tiles
        oracle[step] = cur
        held = store.local_map()
        comp = serde.composable_steps(held)
        # the newest step always composes, and nothing composable chains
        # through a pruned frame (chain_steps would KeyError -> excluded)
        assert step in comp
        for s in comp:
            assert set(serde.chain_steps(held, s)) <= set(held)
            extra, got = serde.compose(held, s)
            assert extra["step"] == s
            for k in oracle[s]:
                assert _bit_equal(got[k], oracle[s][k]), (s, k)


@given(st.integers(0, 2**31 - 1), st.integers(1, 6),
       st.lists(dirty_mask_edits(), min_size=1, max_size=6))
@settings(max_examples=20, deadline=None)
def test_random_dirty_masks_compose_bit_exact(seed, retain, edit_plans):
    _check_dirty_mask_chains(seed, retain, edit_plans)


def test_random_dirty_masks_compose_bit_exact_seeded():
    """Deterministic replay of the property above for environments
    without hypothesis — same invariant, pre-drawn plans."""
    for seed in (0, 7, 1234):
        rng = np.random.default_rng(seed ^ 0x5EED)
        plans = [[(int(rng.integers(0, 8)), float(rng.uniform()),
                   int(rng.integers(1, 600)))
                  for _ in range(rng.integers(0, 5))]
                 for _ in range(rng.integers(1, 7))]
        _check_dirty_mask_chains(seed, int(rng.integers(1, 7)), plans)


def _check_file_ckpt_chains(seed, delta_every, keep, n_saves):
    """FileCheckpointer under random dirtiness and chain lengths: every
    committed step loads bit-exactly and the GC'd directory still
    contains every base its surviving delta chains reference."""
    import tempfile
    from repro.checkpoint.manifest import tree_digest as td
    rng = np.random.default_rng(seed)
    d = tempfile.mkdtemp()
    try:
        ck = FileCheckpointer(d, keep=keep, n_shards=2,
                              delta_every=delta_every)
        state = {"w": rng.standard_normal(20000).astype(np.float32),
                 "b": rng.standard_normal(300).astype(np.float32)}
        digests = {}
        for step in range(1, n_saves + 1):
            state = {k: np.array(v) for k, v in state.items()}
            frac = rng.uniform(0.001, 0.9)        # sometimes > max_dirty
            n = max(1, int(frac * state["w"].size))
            lo = rng.integers(0, state["w"].size - n + 1)
            state["w"][lo:lo + n] += 1.0
            ck.save(step, state)
            digests[step] = td(state)
        steps = ck.steps()
        assert steps[-1] == n_saves
        # chain closure of everything kept is fully on disk
        assert ck._chain_closure(steps) <= set(steps)
        for s in steps:
            _, loaded = ck.load(s)
            assert td(loaded) == digests[s], s
    finally:
        import shutil
        shutil.rmtree(d, ignore_errors=True)


@given(st.integers(0, 2**31 - 1), st.integers(2, 5), st.integers(2, 4),
       st.integers(4, 9))
@settings(max_examples=10, deadline=None)
def test_file_ckpt_random_chains_never_lose_anchor(seed, delta_every,
                                                   keep, n_saves):
    _check_file_ckpt_chains(seed, delta_every, keep, n_saves)


def test_file_ckpt_random_chains_never_lose_anchor_seeded():
    for seed, de, keep, n in [(1, 2, 2, 6), (2, 3, 2, 8), (3, 4, 3, 9),
                              (4, 5, 4, 7)]:
        _check_file_ckpt_chains(seed, de, keep, n)


# --------------------------------------------------------- FileCheckpointer

def test_file_ckpt_delta_chain_roundtrip(tmp_path):
    ck = FileCheckpointer(str(tmp_path), keep=4, n_shards=3, delta_every=4)
    rng = np.random.default_rng(0)
    state = {"a": rng.standard_normal(30000).astype(np.float32),
             "nest": {"b": rng.standard_normal((64, 9)).astype(np.float32)},
             "step": np.int32(0)}
    digests = {}
    for step in range(1, 7):
        state = {"a": np.array(state["a"]),
                 "nest": {"b": np.array(state["nest"]["b"])},
                 "step": np.int32(step)}
        state["a"][step * 31:step * 31 + 40] += 1.0
        ck.save(step, state)
        digests[step] = tree_digest(state)
        kind = ck._manifest(step).kind
        assert kind == ("full" if step in (1, 5) else "delta"), step
    for step in ck.steps():
        man, loaded = ck.load(step)
        assert tree_digest(loaded) == digests[step], step


def test_file_ckpt_gc_keeps_chain_anchor(tmp_path):
    ck = FileCheckpointer(str(tmp_path), keep=2, n_shards=1, delta_every=4)
    state = {"w": np.arange(20000, dtype=np.float32)}
    for step in range(1, 4):
        state = {"w": np.array(state["w"])}
        state["w"][step] += 1.0
        ck.save(step, state)
    # keep=2 would drop step 1, but 2..3 are deltas chained to base 1
    assert ck.steps() == [1, 2, 3]
    _, loaded = ck.load(3)
    assert _bit_equal(loaded["w"], state["w"])


def test_file_ckpt_delta_degrades_to_full_on_big_change(tmp_path):
    ck = FileCheckpointer(str(tmp_path), delta_every=4)
    state = {"w": np.arange(30000, dtype=np.float32)}
    ck.save(1, state)
    state = {"w": state["w"] * 2.0}                    # 100% dirty
    ck.save(2, state)
    assert ck._manifest(2).kind == "full"


def test_file_ckpt_delta_corruption_detected(tmp_path):
    """A byte flipped in a *delta* frame fails the composed-state verify."""
    ck = FileCheckpointer(str(tmp_path), delta_every=4)
    state = {"w": np.arange(30000, dtype=np.float32)}
    ck.save(1, state)
    state = {"w": np.array(state["w"])}
    state["w"][7] += 1.0
    ck.save(2, state)
    assert ck._manifest(2).kind == "delta"
    shard = os.path.join(str(tmp_path), "step_0000000002", "shard_00000.bin")
    with open(shard, "r+b") as f:
        f.seek(os.path.getsize(shard) - 1)             # last data byte
        old = f.read(1)
        f.seek(os.path.getsize(shard) - 1)
        f.write(bytes([old[0] ^ 0x01]))
    with pytest.raises(IOError, match="corrupt"):
        ck.load(2)


def test_file_ckpt_async_delta_bit_exact(tmp_path):
    ck = FileCheckpointer(str(tmp_path), n_shards=2, delta_every=3)
    s1 = {"w": jnp.arange(20000.0)}
    ck.save(1, s1, async_=True)
    s2 = {"w": jnp.arange(20000.0).at[77].set(-5.0)}
    ck.save(2, s2, async_=True)
    ck.wait()
    assert ck._manifest(2).kind == "delta"
    _, loaded = ck.load(2)
    assert tree_digest(loaded) == tree_digest(jax.device_get(s2))


# ------------------------------------------------------- BuddyStore tiering

def test_buddy_store_spills_cold_steps(tmp_path):
    s = BuddyStore(0, 4, retain=3, spill_dir=str(tmp_path), hot_steps=1)
    for step in range(1, 8):
        s.save(step, bytes([step]) * 256)
    m = s.local_map()
    assert sorted(m) == [4, 5, 6, 7]
    assert all(m[k] == bytes([k]) * 256 for k in m)
    assert s.spilled_bytes == 3 * 256           # 4,5,6 cold
    assert s.resident_bytes() == 256            # only 7 hot
    assert len(os.listdir(str(tmp_path))) == 3


def test_buddy_store_spill_eviction_deletes_files(tmp_path):
    s = BuddyStore(0, 2, retain=1, spill_dir=str(tmp_path), hot_steps=1)
    s.hold(1, 1, b"a" * 64)
    s.hold(1, 2, b"b" * 64)
    s.hold(1, 9, b"c" * 64)                     # window slides past 1, 2
    assert sorted(s.held_map(1)) == [9]
    assert s.spilled_bytes == 0
    assert os.listdir(str(tmp_path)) == []


def test_buddy_store_spilled_delta_chain_stays_composable(tmp_path):
    """The spill tier keeps a delta's whole chain alive and composable
    even when the chain's base has slid out of the retention window."""
    base = {"x": np.arange(3000, dtype=np.float32)}
    s = BuddyStore(0, 4, retain=1, spill_dir=str(tmp_path), hot_steps=1)
    s.save(1, serde.to_bytes(base, {"step": 1}))
    tiles = serde.tile_digests(base)
    cur = base
    for step in range(2, 6):
        cur = {"x": np.array(cur["x"])}
        cur["x"][step] += 1.0
        plan = serde.delta_plan(cur, tiles)
        s.save(step, serde.to_delta_bytes(cur, plan, base_step=step - 1,
                                          extra={"step": step}))
        tiles = plan.new_tiles
    m = s.local_map()
    comp = serde.composable_steps(m)
    assert 5 in comp and 4 in comp
    extra, flat = serde.compose(m, 5)
    assert extra == {"step": 5}
    assert _bit_equal(flat["x"], cur["x"])
