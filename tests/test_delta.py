"""Delta checkpoints: tile digests, delta frames, chain compose, tiering.

The load-bearing property: base + N delta frames restores a state
BIT-EXACTLY equal to a full snapshot — across dtype-boundary leaves
(bf16/f16/i8), partial trailing tiles, scalars and empties — enforced
both at the serde layer and through FileCheckpointer's manifest-verified
composed loads.
"""
import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import FileCheckpointer, serde
from repro.checkpoint.manifest import tree_digest
from repro.checkpoint.memory_ckpt import BuddyStore
from repro.kernels.checksum.ref import (TILE_BYTES, checksum_words_ref,
                                        scalar_from_tiles,
                                        tile_checksums_ref)

BF16 = np.dtype(ml_dtypes.bfloat16)


def _bit_equal(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return (str(a.dtype) == str(b.dtype) and a.shape == b.shape
            and np.ascontiguousarray(a).reshape(-1).view(np.uint8).tobytes()
            == np.ascontiguousarray(b).reshape(-1).view(np.uint8).tobytes())


# ------------------------------------------------------------ tile digests

def test_tile_digests_fold_to_scalar_checksum():
    rng = np.random.default_rng(3)
    for arr in [rng.standard_normal(5).astype(np.float32),
                rng.standard_normal(TILE_BYTES // 4).astype(np.float32),
                rng.standard_normal(TILE_BYTES // 4 + 1).astype(np.float32),
                rng.standard_normal(3000).astype(BF16),
                rng.integers(0, 255, 3 * TILE_BYTES + 7).astype(np.uint8),
                np.zeros((0,), np.float32),
                np.float64(2.5).reshape(())]:
        tiles = tile_checksums_ref(arr)
        assert scalar_from_tiles(tiles) == checksum_words_ref(arr)


def test_tile_digest_localizes_change():
    a = np.zeros(4 * TILE_BYTES // 4, np.float32)     # 4 exact tiles
    b = a.copy()
    b[TILE_BYTES // 4 + 3] = 1.0                      # dirty tile 1 only
    ta, tb = tile_checksums_ref(a), tile_checksums_ref(b)
    changed = np.any(ta != tb, axis=1)
    assert list(changed) == [False, True, False, False]


def test_tile_digest_device_parity():
    from repro.kernels.checksum.ops import tile_checksums
    rng = np.random.default_rng(5)
    for arr in [rng.standard_normal(2048).astype(np.float32),
                rng.standard_normal(513).astype(np.float16)]:
        assert np.array_equal(tile_checksums(jnp.asarray(arr)),
                              tile_checksums_ref(arr))


def test_tile_digest_pallas_interpret_parity():
    from repro.kernels.checksum.kernel import tile_checksum_kernel
    from repro.kernels.checksum.ops import _device_words
    rng = np.random.default_rng(6)
    arr = rng.standard_normal(3 * TILE_BYTES // 4 + 11).astype(np.float32)
    words = _device_words(jnp.asarray(arr))
    got = np.asarray(tile_checksum_kernel(words, interpret=True))
    assert np.array_equal(got, tile_checksums_ref(arr))


# ------------------------------------------------------------ serde deltas

def _mutate(flat, rng, n_edits=3):
    """Randomly mutate a few scattered elements of a few leaves."""
    out = {k: np.array(v) for k, v in flat.items()}
    keys = [k for k in out if out[k].size]
    for k in rng.choice(keys, size=min(n_edits, len(keys)),
                        replace=False) if keys else []:
        v = out[k].reshape(-1)
        idx = rng.integers(0, v.size)
        v[idx] = v[idx] + np.asarray(1, dtype=v.dtype) \
            if v.dtype != np.bool_ else ~v[idx]
    return out


@st.composite
def boundary_leaves(draw):
    dtype = draw(st.sampled_from(
        [np.float32, np.float16, np.int8, np.uint64, BF16]))
    # sizes straddling word/tile boundaries, incl. partial trailing tiles
    n = draw(st.sampled_from(
        [0, 1, 3, 7, TILE_BYTES // 4 - 1, TILE_BYTES // 4,
         TILE_BYTES // 4 + 1, 2 * TILE_BYTES // 4 + 13]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return rng.standard_normal(n).astype(dtype)


@given(st.dictionaries(st.text(alphabet="abcd", min_size=1, max_size=4),
                       boundary_leaves(), min_size=1, max_size=5),
       st.integers(1, 4), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_base_plus_n_deltas_bit_exact(flat, n_deltas, seed):
    """base + N chained delta frames == the full snapshot, bit for bit."""
    rng = np.random.default_rng(seed)
    frames = {0: serde.to_bytes(flat, {"step": 0})}
    tiles = serde.tile_digests(flat)
    cur = flat
    for step in range(1, n_deltas + 1):
        cur = _mutate(cur, rng)
        plan = serde.delta_plan(cur, tiles)
        frames[step] = serde.to_delta_bytes(cur, plan, base_step=step - 1,
                                            extra={"step": step})
        tiles = plan.new_tiles
    assert serde.composable_steps(frames) == list(range(n_deltas + 1))
    extra, got = serde.compose(frames, n_deltas)
    assert extra == {"step": n_deltas}
    want = serde.from_bytes(serde.to_bytes(cur))[1]   # full-snapshot oracle
    assert set(got) == set(want)
    for k in want:
        assert _bit_equal(want[k], got[k]), k


def test_delta_plan_marks_new_and_reshaped_leaves_full():
    a = {"x": np.arange(100, dtype=np.float32)}
    tiles = serde.tile_digests(a)
    b = {"x": np.arange(50, dtype=np.float32),       # reshaped
         "y": np.ones(10, np.float32)}               # new
    plan = serde.delta_plan(b, tiles)
    assert plan.entries["x"] is None and plan.entries["y"] is None


def test_delta_plan_marks_same_bytes_reshape_full():
    """Identical bytes under a different shape/dtype must not be treated
    as a clean leaf — the composed state would keep the stale shape."""
    a = {"x": np.arange(1024, dtype=np.float32).reshape(2, 512)}
    tiles = serde.tile_digests(a)
    b = {"x": np.asarray(a["x"]).reshape(1024)}        # same bytes
    plan = serde.delta_plan(b, tiles)
    assert plan.entries["x"] is None                   # full leaf
    c = {"x": np.asarray(a["x"]).view(np.int32)}       # same bytes, recast
    plan = serde.delta_plan(c, tiles)
    assert plan.entries["x"] is None


def test_delta_plan_infeasible_on_removed_leaf():
    a = {"x": np.ones(4, np.float32), "y": np.ones(4, np.float32)}
    tiles = serde.tile_digests(a)
    plan = serde.delta_plan({"x": np.ones(4, np.float32)}, tiles)
    assert not plan.feasible and plan.dirty_fraction == 1.0


def test_clean_snapshot_delta_is_header_only():
    flat = {"x": np.arange(5000, dtype=np.float32)}
    tiles = serde.tile_digests(flat)
    plan = serde.delta_plan(flat, tiles)
    buf = serde.to_delta_bytes(flat, plan, base_step=1)
    assert len(buf) < 256
    _, _, out = serde.apply_delta(serde.from_bytes(
        serde.to_bytes(flat))[1], buf)
    assert _bit_equal(out["x"], flat["x"])


def test_broken_chain_not_composable():
    flat = {"x": np.arange(64, dtype=np.float32)}
    tiles = serde.tile_digests(flat)
    plan = serde.delta_plan(flat, tiles)
    d = serde.to_delta_bytes(flat, plan, base_step=1)
    assert serde.composable_steps({2: d}) == []
    with pytest.raises(KeyError):
        serde.compose({2: d}, 2)


# --------------------------------------------------------- FileCheckpointer

def test_file_ckpt_delta_chain_roundtrip(tmp_path):
    ck = FileCheckpointer(str(tmp_path), keep=4, n_shards=3, delta_every=4)
    rng = np.random.default_rng(0)
    state = {"a": rng.standard_normal(30000).astype(np.float32),
             "nest": {"b": rng.standard_normal((64, 9)).astype(np.float32)},
             "step": np.int32(0)}
    digests = {}
    for step in range(1, 7):
        state = {"a": np.array(state["a"]),
                 "nest": {"b": np.array(state["nest"]["b"])},
                 "step": np.int32(step)}
        state["a"][step * 31:step * 31 + 40] += 1.0
        ck.save(step, state)
        digests[step] = tree_digest(state)
        kind = ck._manifest(step).kind
        assert kind == ("full" if step in (1, 5) else "delta"), step
    for step in ck.steps():
        man, loaded = ck.load(step)
        assert tree_digest(loaded) == digests[step], step


def test_file_ckpt_gc_keeps_chain_anchor(tmp_path):
    ck = FileCheckpointer(str(tmp_path), keep=2, n_shards=1, delta_every=4)
    state = {"w": np.arange(20000, dtype=np.float32)}
    for step in range(1, 4):
        state = {"w": np.array(state["w"])}
        state["w"][step] += 1.0
        ck.save(step, state)
    # keep=2 would drop step 1, but 2..3 are deltas chained to base 1
    assert ck.steps() == [1, 2, 3]
    _, loaded = ck.load(3)
    assert _bit_equal(loaded["w"], state["w"])


def test_file_ckpt_delta_degrades_to_full_on_big_change(tmp_path):
    ck = FileCheckpointer(str(tmp_path), delta_every=4)
    state = {"w": np.arange(30000, dtype=np.float32)}
    ck.save(1, state)
    state = {"w": state["w"] * 2.0}                    # 100% dirty
    ck.save(2, state)
    assert ck._manifest(2).kind == "full"


def test_file_ckpt_delta_corruption_detected(tmp_path):
    """A byte flipped in a *delta* frame fails the composed-state verify."""
    ck = FileCheckpointer(str(tmp_path), delta_every=4)
    state = {"w": np.arange(30000, dtype=np.float32)}
    ck.save(1, state)
    state = {"w": np.array(state["w"])}
    state["w"][7] += 1.0
    ck.save(2, state)
    assert ck._manifest(2).kind == "delta"
    shard = os.path.join(str(tmp_path), "step_0000000002", "shard_00000.bin")
    with open(shard, "r+b") as f:
        f.seek(os.path.getsize(shard) - 1)             # last data byte
        old = f.read(1)
        f.seek(os.path.getsize(shard) - 1)
        f.write(bytes([old[0] ^ 0x01]))
    with pytest.raises(IOError, match="corrupt"):
        ck.load(2)


def test_file_ckpt_async_delta_bit_exact(tmp_path):
    ck = FileCheckpointer(str(tmp_path), n_shards=2, delta_every=3)
    s1 = {"w": jnp.arange(20000.0)}
    ck.save(1, s1, async_=True)
    s2 = {"w": jnp.arange(20000.0).at[77].set(-5.0)}
    ck.save(2, s2, async_=True)
    ck.wait()
    assert ck._manifest(2).kind == "delta"
    _, loaded = ck.load(2)
    assert tree_digest(loaded) == tree_digest(jax.device_get(s2))


# ------------------------------------------------------- BuddyStore tiering

def test_buddy_store_spills_cold_steps(tmp_path):
    s = BuddyStore(0, 4, retain=3, spill_dir=str(tmp_path), hot_steps=1)
    for step in range(1, 8):
        s.save(step, bytes([step]) * 256)
    m = s.local_map()
    assert sorted(m) == [4, 5, 6, 7]
    assert all(m[k] == bytes([k]) * 256 for k in m)
    assert s.spilled_bytes == 3 * 256           # 4,5,6 cold
    assert s.resident_bytes() == 256            # only 7 hot
    assert len(os.listdir(str(tmp_path))) == 3


def test_buddy_store_spill_eviction_deletes_files(tmp_path):
    s = BuddyStore(0, 2, retain=1, spill_dir=str(tmp_path), hot_steps=1)
    s.hold(1, 1, b"a" * 64)
    s.hold(1, 2, b"b" * 64)
    s.hold(1, 9, b"c" * 64)                     # window slides past 1, 2
    assert sorted(s.held_map(1)) == [9]
    assert s.spilled_bytes == 0
    assert os.listdir(str(tmp_path)) == []


def test_buddy_store_spilled_delta_chain_stays_composable(tmp_path):
    """The spill tier keeps a delta's whole chain alive and composable
    even when the chain's base has slid out of the retention window."""
    base = {"x": np.arange(3000, dtype=np.float32)}
    s = BuddyStore(0, 4, retain=1, spill_dir=str(tmp_path), hot_steps=1)
    s.save(1, serde.to_bytes(base, {"step": 1}))
    tiles = serde.tile_digests(base)
    cur = base
    for step in range(2, 6):
        cur = {"x": np.array(cur["x"])}
        cur["x"][step] += 1.0
        plan = serde.delta_plan(cur, tiles)
        s.save(step, serde.to_delta_bytes(cur, plan, base_step=step - 1,
                                          extra={"step": step}))
        tiles = plan.new_tiles
    m = s.local_map()
    comp = serde.composable_steps(m)
    assert 5 in comp and 4 in comp
    extra, flat = serde.compose(m, 5)
    assert extra == {"step": 5}
    assert _bit_equal(flat["x"], cur["x"])
