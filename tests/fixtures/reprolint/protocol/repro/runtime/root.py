# reprolint fixture: handles the healthy tag plus one nobody sends
def dispatch(msg):
    t = msg["type"]
    if t == "BARRIER":
        return "arrive"
    if t in ("NEVER_SENT", "BARRIER"):
        return "dead arm"
    return None
