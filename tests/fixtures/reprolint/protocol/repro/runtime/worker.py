# reprolint fixture: sends one healthy tag, one orphan nobody handles
from .transport import send_msg


def run(sock, step):
    send_msg(sock, {"type": "BARRIER", "step": step})
    send_msg(sock, {"type": "ORPHAN_TAG", "step": step})
