# reprolint fixture: one guarded field touched outside its lock
import threading


class Daemon:
    def __init__(self):
        self.lock = threading.Lock()
        self.workers = {}            # guarded-by: lock

    def spawn(self, rank, proc):
        with self.lock:
            self.workers[rank] = proc

    def reap(self):
        return list(self.workers)    # unguarded: the seeded violation

    def _prune(self):                # holds-lock: lock
        self.workers.clear()
