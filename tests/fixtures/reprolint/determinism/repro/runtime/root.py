# reprolint fixture: wall-clock, unseeded RNG, and set iteration in a
# replay path
import random
import time


class Root:
    def __init__(self, world):
        self.world_ranks = set(range(world))

    def stamp(self):
        return time.time()                     # wall-clock

    def pick(self):
        return random.random()                 # process-global RNG

    def release_order(self):
        return [r for r in self.world_ranks]   # set iteration

    def release_order_ok(self):
        return sorted(self.world_ranks)
