# reprolint fixture: one typo'd fire point, one kwarg-drift pair
from repro.scenarios import hooks


def loop(step):
    hooks.fire("step", step=step)
    hooks.fire("worker.ckpt.midwrite", step=step)      # typo'd point
    hooks.fire("worker.ckpt.mid_write", step=step)
    hooks.fire("serve.decode.step", step=step)


def other(step):
    hooks.fire("worker.ckpt.mid_write")                # kwarg drift
