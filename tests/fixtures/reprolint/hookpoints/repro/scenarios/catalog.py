# reprolint fixture: a catalog cell targeting a point with no fire site
from repro.scenarios.schema import Fault, Scenario, ServeScenario

CATALOG = (
    Scenario(name="ok-cell", faults=(Fault("rank", 1, 3),)),
    Scenario(name="never-fires",
             faults=(Fault("rank", 1, 3, point="ckpt.file.shard"),)),
)
SERVE_CATALOG = (
    ServeScenario(name="serve-ok", fault_point="serve.decode.step"),
)
