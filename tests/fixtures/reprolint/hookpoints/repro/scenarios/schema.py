# reprolint fixture: hook-point registry with a point nothing fires
POINTS = (
    "step",
    "worker.ckpt.mid_write",
    "never.fired.point",
)
SERVE_POINTS = ("serve.decode.step",)
