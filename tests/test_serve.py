"""Serving engine: slot batching, recycling, snapshot/restore.

Semantics pinned here:
  * a request gets exactly max_new_tokens decode-step tokens on top of
    the one token its prefill emits (out has max_new+1 entries);
  * ragged slot occupancy (per-slot positions) decodes bit-identically
    to the same engine serving each request alone;
  * snapshot/restore round-trips the whole churn — state, slot table
    (done flags, emission watermarks) and the pending queue;
  * the emission watermark delivers each token exactly once, and a
    watermark ahead of `out` (recovery) suppresses re-delivery;
  * repeated prompts reuse their prefill through the LRU.
"""
import jax
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen2-7b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_batched_requests_complete(setup):
    model, params = setup
    eng = ServeEngine(model, params, n_slots=3, max_len=64)
    reqs = [Request(rid=i, prompt=list(range(3, 13)), max_new_tokens=5)
            for i in range(7)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert all(r.done for r in reqs)
    # prefill emits one token, decode adds exactly max_new_tokens
    assert all(len(r.out) == 6 for r in reqs)
    # the drained list is the completed requests, not an empty husk
    assert sorted(r.rid for r in done) == list(range(7))


def test_slot_recycling_more_requests_than_slots(setup):
    model, params = setup
    eng = ServeEngine(model, params, n_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new_tokens=3)
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert len(done) == 6


def test_ragged_occupancy_matches_solo_decode(setup):
    """Slots admitted at staggered steps each produce exactly what the
    same engine produces serving that request alone — the per-slot
    position regression harness."""
    model, params = setup
    solo = {}
    for rid in range(3):
        eng = ServeEngine(model, params, n_slots=3, max_len=64)
        eng.submit(Request(rid=rid, prompt=[10 + rid] * 4,
                           max_new_tokens=6))
        r, = eng.run_until_drained()
        solo[rid] = r.out

    eng = ServeEngine(model, params, n_slots=3, max_len=64,
                      prefill_batch=1)
    eng.submit(Request(rid=0, prompt=[10] * 4, max_new_tokens=6))
    eng.step(); eng.step()
    eng.submit(Request(rid=1, prompt=[11] * 4, max_new_tokens=6))
    eng.step()
    eng.submit(Request(rid=2, prompt=[12] * 4, max_new_tokens=6))
    for r in eng.run_until_drained():
        assert r.out == solo[r.rid], f"rid {r.rid} diverged under raggedness"


def test_batched_prefill_matches_solo_admission(setup):
    """Co-admitted same-length prompts (one prefill call, lane-padded to
    the fixed width) decode identically to solo admission."""
    model, params = setup
    solo = {}
    for rid in range(3):
        eng = ServeEngine(model, params, n_slots=4, max_len=64,
                          prefill_batch=1)
        eng.submit(Request(rid=rid, prompt=[20 + rid] * 5,
                           max_new_tokens=4))
        r, = eng.run_until_drained()
        solo[rid] = r.out

    eng = ServeEngine(model, params, n_slots=4, max_len=64,
                      prefill_batch=4)
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=[20 + rid] * 5,
                           max_new_tokens=4))
    for r in eng.run_until_drained():
        assert r.out == solo[r.rid]


def test_snapshot_restore_resumes_identically(setup):
    model, params = setup
    eng = ServeEngine(model, params, n_slots=2, max_len=64)
    r = Request(rid=0, prompt=list(range(1, 9)), max_new_tokens=8)
    eng.submit(r)
    eng.step(); eng.step()
    snap = eng.snapshot()
    eng.step(); eng.step()
    expected = [s.out for s in eng.slots if s][0]

    eng2 = ServeEngine(model, params, n_slots=2, max_len=64)
    eng2.restore(snap)
    eng2.step(); eng2.step()
    resumed = [s.out for s in eng2.slots if s][0]
    assert resumed == expected


def test_snapshot_mutate_restore_bit_identity(setup):
    """snapshot -> keep decoding -> restore must replay the exact same
    tokens, with the pending queue and done flags intact."""
    model, params = setup
    eng = ServeEngine(model, params, n_slots=2, max_len=64)
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=[3, 4, 5], max_new_tokens=6))
    eng.step(); eng.step(); eng.step()
    snap = eng.snapshot()
    queued_at_snap = [r.rid for r in eng.queue]
    assert queued_at_snap, "test needs a non-empty pending queue"

    expected = {r.rid: list(r.out) for r in eng.run_until_drained()}
    assert len(expected) == 5

    eng.restore(snap)
    assert [r.rid for r in eng.queue] == queued_at_snap
    eng.completed = []
    replayed = {r.rid: list(r.out) for r in eng.run_until_drained()}
    assert replayed == {k: expected[k] for k in replayed}
    assert sorted(replayed) == list(range(5))
    # a second restore from the same snapshot must survive the decode
    # step's buffer donation (the snapshot owns its own copies)
    eng.restore(snap)
    eng.completed = []
    again = {r.rid: list(r.out) for r in eng.run_until_drained()}
    assert again == replayed


def test_restore_roundtrips_done_flag(setup):
    model, params = setup
    eng = ServeEngine(model, params, n_slots=2, max_len=64)
    eng.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=1))
    eng.run_until_drained()
    snap = eng.snapshot()
    assert snap["slots"] == [None, None]      # finished slots were freed
    done_req = eng.completed[0]
    assert done_req.done

    r = Request.from_dict(done_req.to_dict())
    assert r.done and r.out == done_req.out and r.emitted == done_req.emitted


def test_emission_watermark_exactly_once(setup):
    """Every token reaches the sink exactly once, in order; a watermark
    ahead of `out` (what recovery sets) suppresses re-delivery of
    replayed tokens."""
    model, params = setup
    got = []
    eng = ServeEngine(model, params, n_slots=2, max_len=64,
                      sink=lambda rid, idx, tok: got.append((rid, idx, tok)))
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=[7, 8, 9], max_new_tokens=4))
    done = eng.run_until_drained()
    per: dict = {}
    for rid, idx, tok in got:
        assert idx == len(per.setdefault(rid, []))   # in order, no gap
        per[rid].append(tok)
    for r in done:
        assert per[r.rid] == r.out             # every token exactly once

    # replay with the watermark pre-advanced: decode happens, the sink
    # stays silent until the watermark is passed
    replay = []
    eng2 = ServeEngine(model, params, n_slots=2, max_len=64,
                       sink=lambda rid, idx, tok: replay.append((idx, tok)))
    req = Request(rid=0, prompt=[7, 8, 9], max_new_tokens=4)
    req.emitted = 3                            # client already holds 3
    eng2.submit(req)
    eng2.run_until_drained()
    assert [i for i, _ in replay] == [3, 4]    # only the tail delivered
    assert [t for _, t in replay] == per[0][3:]


def test_prefill_cache_reuses_repeated_prompts(setup):
    """The prefill LRU kicks in on a prompt's second repeat: the third
    identical submission admits without a model prefill call, and its
    output is unchanged."""
    model, params = setup
    eng = ServeEngine(model, params, n_slots=2, max_len=64,
                      prefill_cache=4)
    calls = []
    real = eng._prefill_fn
    eng._prefill_fn = lambda p, t: (calls.append(1), real(p, t))[1]
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=[9, 9, 9], max_new_tokens=3))
        eng.run_until_drained()
    outs = [r.out for r in eng.completed]
    assert outs[0] == outs[1] == outs[2]
    assert len(calls) == 2                    # third admission hit the LRU


def test_max_len_truncates_generation(setup):
    model, params = setup
    eng = ServeEngine(model, params, n_slots=1, max_len=16)
    eng.submit(Request(rid=0, prompt=[1] * 10, max_new_tokens=50))
    r, = eng.run_until_drained()
    assert r.done
    assert len(r.out) == 16 - 10              # max_len - len(prompt)


def test_submit_rejects_oversized_prompt(setup):
    model, params = setup
    eng = ServeEngine(model, params, n_slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=[1] * 15, max_new_tokens=1))


def test_same_prompt_same_output_determinism(setup):
    model, params = setup
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params, n_slots=1, max_len=64)
        r = Request(rid=0, prompt=[5, 6, 7, 8], max_new_tokens=6)
        eng.submit(r)
        eng.run_until_drained()
        outs.append(tuple(r.out))
    assert outs[0] == outs[1]
