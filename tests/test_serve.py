"""Serving engine: slot batching, recycling, snapshot/restore."""
import jax
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen2-7b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_batched_requests_complete(setup):
    model, params = setup
    eng = ServeEngine(model, params, n_slots=3, max_len=64)
    reqs = [Request(rid=i, prompt=list(range(3, 13)), max_new_tokens=5)
            for i in range(7)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)


def test_slot_recycling_more_requests_than_slots(setup):
    model, params = setup
    eng = ServeEngine(model, params, n_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new_tokens=3)
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)


def test_snapshot_restore_resumes_identically(setup):
    model, params = setup
    eng = ServeEngine(model, params, n_slots=2, max_len=64)
    r = Request(rid=0, prompt=list(range(8)), max_new_tokens=8)
    eng.submit(r)
    eng.step(); eng.step()
    snap = eng.snapshot()
    eng.step(); eng.step()
    expected = [s.out for s in eng.slots if s][0]

    eng2 = ServeEngine(model, params, n_slots=2, max_len=64)
    eng2.restore(snap)
    eng2.step(); eng2.step()
    resumed = [s.out for s in eng2.slots if s][0]
    assert resumed == expected


def test_same_prompt_same_output_determinism(setup):
    model, params = setup
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params, n_slots=1, max_len=64)
        r = Request(rid=0, prompt=[5, 6, 7, 8], max_new_tokens=6)
        eng.submit(r)
        eng.run_until_drained()
        outs.append(tuple(r.out))
    assert outs[0] == outs[1]
