"""Fault-tolerant serving: cluster scenario cells, delta replication,
sharded decode state.

The serving invariants every cell asserts (the serving analogue of the
training matrices' bit-identity oracle):

  * zero requests dropped — every arrival completes to its expected
    token count even when its rank died mid-decode;
  * zero duplicate and zero lost tokens — the TokenSink ledger raises
    on either, so a passing run IS the proof;
  * transcripts bit-identical to the fault-free run of the same load —
    recovery replays suppressed, it never re-delivers and never skews
    a single token.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model
from repro.scenarios.catalog import SERVE_CATALOG
from repro.serve import LoadGen, Request, ServeCluster, ServeEngine
from repro.serve.replicate import ServeReplicator

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

FAST_CELLS = [s for s in SERVE_CATALOG if "fast" in s.tags]
NIGHTLY_CELLS = [s for s in SERVE_CATALOG if "nightly" in s.tags]


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen2-7b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _load_for(sc):
    return LoadGen(world=sc.world, rounds=sc.rounds,
                   per_round=sc.per_round, max_new=sc.max_new_tokens,
                   seed=sc.seed)


_REF_CACHE: dict = {}


def _reference(model, params, sc):
    """Fault-free transcripts for the cell's load, cached per load
    signature (cells sharing a load share the reference)."""
    key = (sc.world, sc.n_slots, sc.max_len, sc.rounds, sc.per_round,
           sc.max_new_tokens, sc.seed)
    if key not in _REF_CACHE:
        c = ServeCluster(model, params, world=sc.world,
                         n_slots=sc.n_slots, max_len=sc.max_len)
        m = c.run(_load_for(sc), rounds=sc.rounds)
        assert m["requests_dropped"] == 0
        _REF_CACHE[key] = c.transcripts()
    return _REF_CACHE[key]


def _run_cell(model, params, sc):
    c = ServeCluster(model, params, world=sc.world, n_slots=sc.n_slots,
                     max_len=sc.max_len, strategy=sc.strategy,
                     publish_every=sc.publish_every,
                     respawn_delay=sc.respawn_delay)
    m = c.run(_load_for(sc), rounds=sc.rounds, fault=sc.fault())
    return c, m


def _assert_cell(model, params, sc):
    ref = _reference(model, params, sc)
    c, m = _run_cell(model, params, sc)
    assert m["kills"], "the fault never fired"
    assert m["requests_dropped"] == 0, m["dropped_rids"]
    if sc.expect_bit_identical:
        got = c.transcripts()
        diff = {rid for rid in ref if got.get(rid) != ref[rid]}
        assert not diff, f"{sc.name}: transcripts diverged for {diff}"
    k = m["kills"][0]
    assert k["tokens_to_first_recovered_token"] is not None, \
        "the failed rank never delivered another token"


@pytest.mark.scenario_fast
@pytest.mark.parametrize("sc", FAST_CELLS, ids=lambda s: s.name)
def test_serve_cell_recovers_lossless(setup, sc):
    model, params = setup
    _assert_cell(model, params, sc)


@pytest.mark.scenario_fast
def test_replica_promotes_faster_than_reinit(setup):
    """The headline comparison: a warm standby's first recovered token
    arrives after strictly fewer foreign tokens than a reinit respawn's
    (the serving analogue of the paper's recovery-latency gap)."""
    model, params = setup
    by_name = {s.name: s for s in SERVE_CATALOG}
    ttfrt = {}
    for name in ("serve-rank-loss", "serve-replica-promote"):
        sc = by_name[name]
        _, m = _run_cell(model, params, sc)
        assert m["requests_dropped"] == 0
        ttfrt[sc.strategy] = m["kills"][0]["tokens_to_first_recovered_token"]
    assert ttfrt["replica"] < ttfrt["reinit"], ttfrt


@pytest.mark.scenario_slow
@pytest.mark.parametrize("sc", NIGHTLY_CELLS, ids=lambda s: s.name)
def test_serve_cell_nightly(setup, sc):
    model, params = setup
    _assert_cell(model, params, sc)


# ----------------------------------------------------------- replication


class _Recorder:
    def __init__(self):
        self.frames: dict = {}

    def save(self, step, payload):
        self.frames[step] = payload


def test_replicator_delta_frames_cost_o_dirt(setup):
    """Between publishes, a decode step dirties one KV position per
    layer per active slot — the delta frame must be a small fraction of
    the full state frame."""
    model, params = setup
    eng = ServeEngine(model, params, n_slots=4, max_len=128)
    for rid in range(2):
        eng.submit(Request(rid=rid, prompt=[4, 5, 6], max_new_tokens=40))
    rec = _Recorder()
    rep = ServeReplicator(rec, base_every=8)
    eng.step()
    rep.publish(eng)
    assert rep.last_kind == "full"
    base_size = len(rec.frames[0])
    for _ in range(3):
        eng.step(); eng.step()
        rep.publish(eng)
        assert rep.last_kind == "delta"
    delta_sizes = [len(rec.frames[s]) for s in (1, 2, 3)]
    assert max(delta_sizes) < base_size / 4, (delta_sizes, base_size)


def test_replicator_compose_restores_exact_engine(setup):
    """publish -> compose -> restore lands an engine that decodes
    bit-identically to the original continuing uninterrupted."""
    model, params = setup
    eng = ServeEngine(model, params, n_slots=2, max_len=64)
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=[8, 9], max_new_tokens=6))
    rec = _Recorder()
    rep = ServeReplicator(rec, base_every=4)
    for _ in range(4):
        eng.step()
        rep.publish(eng)
    expected = {r.rid: list(r.out) for r in eng.run_until_drained()}

    snap = ServeReplicator.compose(rec.frames)
    eng2 = ServeEngine(model, params, n_slots=2, max_len=64)
    eng2.restore(snap)
    got = {r.rid: list(r.out) for r in eng2.run_until_drained()}
    assert got == {k: expected[k] for k in got}
    assert sorted(got) == sorted(expected)


def test_mid_prefill_kill_loses_no_requests(setup):
    """A kill at serve.prefill.mid fires before the admission commit:
    the about-to-be-admitted requests are still in the snapshot's queue
    and replay completely."""
    model, params = setup
    from repro.scenarios.catalog import get_serve_scenario
    sc = get_serve_scenario("serve-mid-prefill")
    ref = _reference(model, params, sc)
    c, m = _run_cell(model, params, sc)
    assert m["requests_dropped"] == 0
    assert c.transcripts() == ref


# -------------------------------------------------------------- sharding


def test_sharded_engine_multi_device():
    """8 simulated CPU devices: the decode state is placed by the
    pod_serve rules (batch over data, kv_seq over model), the engine
    serves under a constraint scope, snapshot/restore round-trips the
    sharded state, and outputs are deterministic."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.configs import get_config, reduced
        from repro.launch.mesh import make_host_mesh
        from repro.models.model import Model
        from repro.serve import Request, ServeEngine
        from repro.sharding.rules import PRESETS

        cfg = reduced(get_config("qwen2-7b"))
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = make_host_mesh((2, 4), ("data", "model"))
        rules = PRESETS["pod_serve"]

        def run():
            eng = ServeEngine(model, params, n_slots=4, max_len=64,
                              mesh=mesh, rules=rules)
            for rid in range(6):
                eng.submit(Request(rid=rid, prompt=[3 + rid] * 4,
                                   max_new_tokens=5))
            for _ in range(3):
                eng.step()
            snap = eng.snapshot()
            eng.restore(snap)         # sharded restore: device_put back
            done = eng.run_until_drained()
            return eng, {r.rid: tuple(r.out) for r in done}

        eng, out1 = run()
        # the KV cache really is distributed: batch dim carries "data"
        k = eng.state["k"]
        spec = k.sharding.spec
        assert "data" in str(spec), spec
        assert len(k.sharding.device_set) == 8, k.sharding
        _, out2 = run()
        assert out1 == out2 and len(out1) == 6
        print("SERVE_SHARD_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert "SERVE_SHARD_OK" in proc.stdout, proc.stderr[-2000:]
