"""Property tests for the elastic membership state machine.

Random interleavings of {node loss, rank loss, rejoin, spare grant}
driven through `MembershipMachine` must never violate its invariants:

  * world size stays within [min_data_parallel * ranks_per_node, initial]
  * the mesh epoch is strictly monotonic across re-meshing transitions
  * the world always equals (initial world - dropped ranks); in
    particular a shrink -> grow -> shrink round-trip restores exactly
    the pre-shrink membership (the consistent cut the survivors pin)

Hypothesis drives the interleavings when installed; the seeded fallback
replays pre-drawn random op sequences so the suite asserts the same
invariants in hypothesis-free environments (see tests/_hyp.py).
"""
import random

import pytest

from _hyp import HAS_HYPOTHESIS, given, settings, st

from repro.core import (ClusterView, ElasticManager, FailureEvent,
                        FailureType, MembershipMachine, MeshEpoch)

OPS = ("node_loss", "rank_loss", "rejoin", "spare_grant")


def _build(n_nodes, rpn, spares, min_dp):
    view = ClusterView.build(n_nodes, rpn, spares)
    return MembershipMachine(
        view, MeshEpoch(epoch=0, data_parallel=n_nodes,
                        model_parallel=rpn),
        min_data_parallel=min_dp)


def _live_nodes(m):
    return sorted(d for d, cs in m.view.children.items() if cs)


def _drive(m, ops, choices):
    """Apply an op sequence through the machine's public transitions,
    the way the root does: decide() then respawn()/shrink(); rejoin ->
    admit() then grow()/grant_spare(). `choices` picks victims
    deterministically. Returns the transition log length actually
    executed (unexecutable ops are skipped, like a root that has no
    matching event to react to)."""
    rng = random.Random(choices)
    rejoin_serial = 0
    for op in ops:
        world = list(m.world())
        if op == "node_loss":
            nodes = _live_nodes(m)
            # a respawn needs a surviving daemon to re-host onto
            if len(m.view.daemons()) < 2 or not nodes:
                continue
            node = nodes[rng.randrange(len(nodes))]
            victim = sorted(m.view.children[node])[0]
            f = FailureEvent(kind=FailureType.NODE, rank=victim, node=node)
            if m.decide(f) == "shrink":
                m.shrink(f)
            else:
                m.respawn(f)
        elif op == "rank_loss":
            if not world:
                continue
            f = FailureEvent(kind=FailureType.PROCESS,
                             rank=world[rng.randrange(len(world))])
            if m.decide(f) == "shrink":
                m.shrink(f)
            else:
                m.respawn(f)
        elif op == "rejoin":
            rejoin_serial += 1
            node = f"repair{rejoin_serial}"
            if m.admit(node) == "grow":
                m.grow(node)
            else:
                m.grant_spare(node)
        else:                       # spare_grant (operator adds capacity)
            rejoin_serial += 1
            m.grant_spare(f"extra{rejoin_serial}")
    return len(m.log)


def _assert_invariants(m):
    # every transition already ran check_invariants(); re-assert the
    # external statements on the final state explicitly
    world = set(m.world())
    assert m.floor_world <= len(world) <= len(m.initial_world)
    assert world == set(m.initial_world) - set(m.dropped)
    remesh = [t.mesh_epoch for t in m.log
              if t.kind in ("shrink", "grow")
              or (t.kind == "respawn" and t.trigger == "node_loss")]
    assert all(a < b for a, b in zip(remesh, remesh[1:])), \
        "mesh epoch not strictly monotonic across re-meshing"
    m.check_invariants()


def _check_interleaving(n_nodes, rpn, spares, min_dp, ops, choices):
    m = _build(n_nodes, rpn, spares, min_dp)
    _drive(m, ops, choices)
    _assert_invariants(m)


@given(st.integers(2, 5), st.integers(1, 4), st.integers(0, 2),
       st.integers(1, 2),
       st.lists(st.sampled_from(OPS), min_size=1, max_size=40),
       st.integers(0, 2**31 - 1))
@settings(max_examples=120, deadline=None)
def test_membership_random_interleavings(n_nodes, rpn, spares, min_dp,
                                         ops, choices):
    if min_dp > n_nodes:
        return
    _check_interleaving(n_nodes, rpn, spares, min_dp, ops, choices)


def test_membership_random_interleavings_seeded():
    """Deterministic replay of the property above for environments
    without hypothesis — same invariants, pre-drawn op sequences."""
    for seed in range(40):
        rng = random.Random(seed ^ 0xE1A5)
        n_nodes = rng.randint(2, 5)
        rpn = rng.randint(1, 4)
        spares = rng.randint(0, 2)
        min_dp = rng.randint(1, n_nodes)
        ops = [rng.choice(OPS) for _ in range(rng.randint(1, 40))]
        _check_interleaving(n_nodes, rpn, spares, min_dp, ops, seed)


def test_shrink_grow_shrink_round_trip():
    """The round-trip invariant stated directly: shrink a node out, grow
    it back, shrink again — each grow restores exactly the membership
    the preceding shrink removed (the consistent cut is recoverable),
    and mesh epochs strictly increase through the whole sequence."""
    m = _build(3, 2, 0, 1)
    initial = set(m.world())
    f1 = FailureEvent(kind=FailureType.NODE, rank=2, node="node1")
    cmd1 = m.shrink(f1)
    assert set(m.world()) == initial - set(cmd1.dropped)
    g1 = m.grow("node1")
    assert set(g1.added) == set(cmd1.dropped)
    assert set(m.world()) == initial          # round trip restored
    f2 = FailureEvent(kind=FailureType.NODE, rank=4, node="node2")
    cmd2 = m.shrink(f2)
    assert set(m.world()) == initial - set(cmd2.dropped)
    g2 = m.grow("node2")
    assert set(g2.added) == set(cmd2.dropped)
    assert set(m.world()) == initial
    epochs = [t.mesh_epoch for t in m.log]
    assert epochs == sorted(epochs) and len(set(epochs)) == len(epochs)
    _assert_invariants(m)


def test_grow_readmits_most_recent_drop_first():
    """LIFO re-admission: the survivors hold the *latest* shrink's cut
    pinned, so a single-node repair must re-admit the most recently
    dropped group, not the oldest."""
    m = _build(4, 2, 0, 1)
    m.shrink(FailureEvent(kind=FailureType.NODE, rank=2, node="node1"))
    m.shrink(FailureEvent(kind=FailureType.NODE, rank=6, node="node3"))
    g = m.grow("node3")
    assert set(g.added) == {6, 7}             # newest drop first
    assert m.dropped == [2, 3]
    g2 = m.grow("node1")
    assert set(g2.added) == {2, 3}
    _assert_invariants(m)


def test_grow_never_mixes_drop_groups():
    """A node shrink followed by a process-level shrink: the rejoined
    node re-admits its OWN group (one shrink = one group = one pinned
    cut), never a mix of ranks from two different cuts — and the
    process-dropped rank stays out until a later event re-admits it."""
    m = _build(3, 2, 0, 1)
    m.shrink(FailureEvent(kind=FailureType.NODE, rank=4, node="node2"))
    m.shrink(FailureEvent(kind=FailureType.PROCESS, rank=1))
    assert m.dropped == [4, 5, 1]
    g = m.grow("node2")
    assert set(g.added) == {4, 5}             # node2's own group
    assert m.dropped == [1]
    assert m.mesh.data_parallel == 3          # full group restored
    _assert_invariants(m)


def test_oracle_matches_sim_on_edge_repairs():
    """The two derivations of the elastic policy (declarative
    `elastic_transitions` vs the sim's MembershipMachine replay) agree
    on the edge shapes the catalog does not reach: a repair after a
    process-level shrink (its node never died -> no-op) and a repair of
    a node that never left the world."""
    from repro.scenarios import (Fault, Repair, Scenario, Topology,
                                 elastic_transitions,
                                 expected_resume_steps)
    from repro.sim.cluster import simulate_scenario
    proc = Scenario(name="edge-proc", topology=Topology(2, 2, 0), steps=7,
                    faults=(Fault("rank", 1, 3),), repairs=(Repair(1, 5),),
                    strategies=("shrink",), expect_bit_identical=False)
    assert [k for k, _, _ in elastic_transitions(proc)] == \
        ["shrink", "noop"]
    out = simulate_scenario(proc, "shrink")
    assert out.resume_steps == expected_resume_steps(proc, "shrink") == [3]
    assert not any(r.get("grow") for r in out.rows)

    live = Scenario(name="edge-live", topology=Topology(2, 2, 0), steps=7,
                    faults=(Fault("node", 2, 4),), repairs=(Repair(0, 2),),
                    strategies=("shrink",), expect_bit_identical=False)
    assert [k for k, _, _ in elastic_transitions(live)] == \
        ["noop", "shrink"]
    out = simulate_scenario(live, "shrink")
    assert out.resume_steps == expected_resume_steps(live, "shrink") == [4]
    assert out.rows[0]["shrink"]


def test_floor_blocks_shrink_and_machine_respawns():
    m = _build(2, 2, 0, 2)                    # floor == initial world
    f = FailureEvent(kind=FailureType.NODE, rank=2, node="node1")
    assert m.decide(f) == "respawn"           # would cross the floor
    proc = FailureEvent(kind=FailureType.PROCESS, rank=1)
    assert m.decide(proc) == "respawn"
    with pytest.raises(AssertionError):
        m.shrink(f)                           # forcing it trips the guard


def test_elastic_manager_is_the_membership_machine():
    """The historical name stays importable and IS the machine — the
    centralization the refactor promises (one state owner, not three)."""
    assert issubclass(ElasticManager, MembershipMachine)
