"""Property test for the rollback-consensus rule the rejoin barrier uses.

The consistent cut after a failure is min(latest checkpoint per rank):
ranks in a BSP loop with a per-step barrier can be at most one step apart,
and every rank retains ≥3 checkpoints — so the agreed step is always
restorable by everyone. This mirrors root._join_arrive + worker.body.
"""
from _hyp import given, settings, st


def join_release(avails: dict[int, int]) -> int:
    return min(avails.values())


@given(st.integers(0, 1000), st.integers(2, 64), st.data())
@settings(max_examples=50, deadline=None)
def test_consensus_step_restorable_by_all(base, world, data):
    # BSP skew: each rank is at base or base+1
    avails = {r: base + data.draw(st.integers(0, 1))
              for r in range(world)}
    resume = join_release(avails)
    assert resume in (base, base + 1)
    # retention window: every rank keeps steps [avail-2, avail]
    for r, a in avails.items():
        retained = set(range(max(a - 2, 0), a + 1))
        assert resume in retained or resume == 0


@given(st.dictionaries(st.integers(0, 63), st.integers(0, 100),
                       min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_consensus_never_exceeds_any_rank(avails):
    resume = join_release(avails)
    assert all(resume <= a for a in avails.values())


def test_buddy_store_retention():
    from repro.checkpoint.memory_ckpt import BuddyStore
    s = BuddyStore(rank=0, world=4)
    for step in range(1, 8):
        s.save(step, bytes([step]))
    kept = sorted(s.local_map())
    assert kept == [5, 6, 7]          # last 3 retained
    s.hold(3, 5, b"a")
    s.hold(3, 6, b"b")
    s.hold(3, 9, b"c")
    assert sorted(s.held_map(3)) == [9]   # hold prunes < step-2
    assert s.buddy == 1
