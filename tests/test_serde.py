"""Zero-copy serde frames: roundtrip, alignment, memmap, corruption."""
import os

import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import FileCheckpointer, serde

BF16 = np.dtype(ml_dtypes.bfloat16)


def _bit_equal(a: np.ndarray, b: np.ndarray) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return (str(a.dtype) == str(b.dtype) and a.shape == b.shape
            and np.ascontiguousarray(a).reshape(-1).view(np.uint8).tobytes()
            == np.ascontiguousarray(b).reshape(-1).view(np.uint8).tobytes())


def test_roundtrip_explicit_dtypes():
    rng = np.random.default_rng(0)
    flat = {
        "f32": rng.standard_normal((5, 7)).astype(np.float32),
        "bf16": rng.standard_normal(33).astype(BF16),
        "f16": rng.standard_normal(9).astype(np.float16),
        "i8": rng.integers(-100, 100, 13).astype(np.int8),
        "u64": rng.integers(0, 2**40, 4).astype(np.uint64),
        "zero_d": np.float32(2.5).reshape(()),
        "empty": np.zeros((0, 3), np.int32),
        "bool": rng.random(10) > 0.5,
    }
    extra = {"step": 17, "tag": "t"}
    buf = serde.to_bytes(flat, extra)
    got_extra, back = serde.from_bytes(buf)
    assert got_extra == extra
    assert set(back) == set(flat)
    for k in flat:
        assert _bit_equal(flat[k], back[k]), k


def test_file_and_bytes_agree(tmp_path):
    flat = {"a": np.arange(100, dtype=np.float32),
            "b": np.ones((3, 4), np.float64)}
    p = str(tmp_path / "f.bin")
    n = serde.write_file(p, flat, {"x": 1})
    buf = serde.to_bytes(flat, {"x": 1})
    assert os.path.getsize(p) == n == len(buf)
    with open(p, "rb") as f:
        assert f.read() == buf


def test_memmap_views_and_alignment(tmp_path):
    flat = {"a": np.arange(64, dtype=np.float32),
            "b": np.arange(7, dtype=np.int8)}
    p = str(tmp_path / "f.bin")
    serde.write_file(p, flat)
    _, mapped = serde.open_file(p, mmap=True)
    import mmap
    for k in flat:
        assert _bit_equal(flat[k], mapped[k])
        base = mapped[k]
        while getattr(base, "base", None) is not None:
            base = base.base
        # the view chain bottoms out in the file mapping, not a copy
        assert isinstance(base, (np.memmap, mmap.mmap)), (k, type(base))
    buf = serde.to_bytes(flat)
    import json
    import struct
    _, hlen, _ = struct.unpack("<8sII", buf[:16])
    hdr = json.loads(buf[16:16 + hlen])
    assert all(e["offset"] % serde.ALIGN == 0 for e in hdr["leaves"])


def test_bad_magic_rejected():
    with pytest.raises(IOError):
        serde.from_bytes(b"NOTMAGIC" + b"\0" * 64)
    with pytest.raises(IOError):
        serde.from_bytes(b"\x01")


def test_header_growth_fixpoint():
    """Many leaves push offsets across digit/alignment boundaries; the
    header must still describe exactly where the data landed."""
    flat = {f"leaf_{i:03d}": np.full((11,), i, np.float32)
            for i in range(40)}
    _, back = serde.from_bytes(serde.to_bytes(flat))
    for k, v in flat.items():
        assert _bit_equal(v, back[k]), k


@st.composite
def pytree_leaves(draw):
    dtype = draw(st.sampled_from(
        [np.float32, np.float16, np.int32, np.int8, BF16]))
    shape = tuple(draw(st.lists(st.integers(0, 5), min_size=0,
                                max_size=3)))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


@given(st.dictionaries(st.text(alphabet="abcdef/", min_size=1, max_size=8)
                       .filter(lambda s: "//" not in s
                               and not s.startswith("/")
                               and not s.endswith("/")),
                       pytree_leaves(), min_size=1, max_size=8))
@settings(max_examples=30, deadline=None)
def test_roundtrip_property(flat):
    buf = serde.to_bytes(flat, {"step": 1})
    extra, back = serde.from_bytes(buf)
    assert extra == {"step": 1}
    assert set(back) == set(flat)
    for k in flat:
        assert _bit_equal(flat[k], back[k]), k


def test_corruption_caught_by_parallel_verify(tmp_path):
    """A flipped byte in a memmapped shard is caught by the per-shard
    parallel verify pass — on whichever shard it lands."""
    ck = FileCheckpointer(str(tmp_path), n_shards=3)
    state = {"a": jnp.arange(512.0), "b": jnp.ones((64, 4)),
             "c": jnp.zeros(33, jnp.int32)}
    ck.save(5, state)
    d = str(tmp_path / "step_0000000005")
    # flip one data byte in every shard that has payload; each must trip
    import json
    import struct
    tripped = 0
    for i in range(3):
        p = os.path.join(d, f"shard_{i:05d}.bin")
        with open(p, "rb") as f:
            buf = f.read()
        _, hlen, _ = struct.unpack("<8sII", buf[:16])
        leaves = json.loads(buf[16:16 + hlen])["leaves"]
        leaves = [e for e in leaves if e["nbytes"]]
        if not leaves:
            continue
        pos = leaves[0]["offset"] + leaves[0]["nbytes"] // 2
        with open(p, "r+b") as f:
            f.seek(pos)
            old = f.read(1)
            f.seek(pos)
            f.write(bytes([old[0] ^ 0x01]))
        with pytest.raises(IOError, match="corrupt"):
            ck.load(5)
        with open(p, "r+b") as f:          # restore for the next shard
            f.seek(pos)
            f.write(old)
        tripped += 1
    assert tripped >= 2
    ck.load(5)                              # pristine again: verifies clean
