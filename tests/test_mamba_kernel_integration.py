"""The Pallas selective-scan kernel, driven by REAL model parameters,
must match the model's chunked-jnp scan path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.mamba import mamba1_forward, mamba1_forward_pallas


def test_model_forward_matches_pallas_kernel():
    cfg = reduced(get_config("falcon-mamba-7b")).replace(
        d_model=64, ssm_state=16, ssm_chunk=16)
    from repro.models.mamba import mamba1_init
    p = mamba1_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.float32)
    ref = mamba1_forward(p, x, cfg, compute_dtype=jnp.float32)
    out = mamba1_forward_pallas(p, x, cfg, compute_dtype=jnp.float32,
                                interpret=True, chunk=16, block_d=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)
