"""The simulator must reproduce the paper's measured claims (§5)."""
import pytest

from repro.sim import APPS, recovery_time, simulate_run


RANKS = [16, 32, 64, 128, 256, 512, 1024]


def _rec(strategy, n, kind="process"):
    return recovery_time(strategy, n, kind)["mpi_recovery_s"]


def test_cr_recovery_flat_and_about_3s():
    ts = [_rec("cr", n) for n in RANKS]
    assert max(ts) / min(ts) < 1.05          # "scales excellently"
    assert 2.0 < ts[0] < 4.0                 # ≈3 s (paper §5.3)


def test_reinit_recovery_flat_and_about_half_second():
    ts = [_rec("reinit", n) for n in RANKS]
    assert max(ts) / min(ts) < 1.05
    assert 0.3 < ts[0] < 0.7                 # ≈0.5 s (paper §5.3)


def test_reinit_up_to_6x_faster_than_cr():
    ratios = [_rec("cr", n) / _rec("reinit", n) for n in RANKS]
    assert all(4.0 < r < 9.0 for r in ratios)    # paper: "up to 6×"


def test_ulfm_on_par_small_3x_at_1024():
    r64 = _rec("ulfm", 64) / _rec("reinit", 64)
    r1024 = _rec("ulfm", 1024) / _rec("reinit", 1024)
    assert r64 < 1.5                          # on par up to 64 ranks
    assert 2.5 < r1024 < 4.0                  # ≈3× at 1024 (paper §5.3)
    # and it grows monotonically
    rs = [_rec("ulfm", n) for n in RANKS]
    assert all(a <= b for a, b in zip(rs, rs[1:]))


def test_node_failure_reinit_about_2x_faster_than_cr():
    for n in [16, 256, 1024]:
        cr = _rec("cr", n, "node")
        re = _rec("reinit", n, "node")
        assert 1.5 < cr / re < 3.0            # paper §5.4: ≈2×
        assert 1.0 < re < 2.0                 # ≈1.5 s


def test_node_recovery_slower_than_process_for_reinit():
    assert _rec("reinit", 256, "node") > 2 * _rec("reinit", 256, "process")


def test_cr_total_time_grows_with_ranks_due_to_lustre():
    t16 = simulate_run(APPS["comd"], 16, "cr").total_s
    t1024 = simulate_run(APPS["comd"], 1024, "cr").total_s
    assert t1024 > 1.5 * t16                  # Fig 4: writes dominate


def test_reinit_total_time_flat():
    t16 = simulate_run(APPS["comd"], 16, "reinit").total_s
    t1024 = simulate_run(APPS["comd"], 1024, "reinit").total_s
    assert t1024 / t16 < 1.1


def test_ulfm_inflates_pure_app_time():
    a16 = simulate_run(APPS["hpccg"], 16, "ulfm").app_time_s
    a1024 = simulate_run(APPS["hpccg"], 1024, "ulfm").app_time_s
    r1024 = simulate_run(APPS["hpccg"], 1024, "reinit").app_time_s
    assert a1024 > a16                        # Fig 5 divergence
    assert a1024 > 1.02 * r1024               # visibly above Reinit++
    # CR and Reinit++ are interference-free
    c1024 = simulate_run(APPS["hpccg"], 1024, "cr").app_time_s
    assert abs(c1024 - r1024) < 1e-9


def test_recovery_time_app_independent():
    """Fig 6: recovery depends only on rank count, not the app."""
    rs = [simulate_run(APPS[a], 256, "reinit").mpi_recovery_s
          for a in APPS]
    assert max(rs) - min(rs) < 1e-9


@pytest.mark.parametrize("strategy", ["cr", "reinit", "ulfm"])
def test_breakdown_positive(strategy):
    r = simulate_run(APPS["lulesh"], 128, strategy)
    assert r.ckpt_write_s > 0 and r.mpi_recovery_s > 0
    assert r.ckpt_read_s > 0 and r.app_time_s > 0
    assert r.total_s > r.app_time_s
