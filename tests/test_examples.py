"""Examples must stay runnable: import + execute every examples/ script
in dry-run mode (REPRO_DRYRUN=1 — print the plan, skip the heavy work).

Catches the classic rot mode where a runtime/trainer API moves and the
examples silently stop matching it (the fate of the pre-PR-3
cluster_failover.py).
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
EXAMPLES_DIR = os.path.join(ROOT, "examples")
EXAMPLES = sorted(n for n in os.listdir(EXAMPLES_DIR)
                  if n.endswith(".py"))


def test_every_example_is_covered():
    """If a new example appears it must run under this smoke test."""
    assert EXAMPLES, "examples/ is empty?"
    assert len(EXAMPLES) >= 4


@pytest.mark.parametrize("name", EXAMPLES)
def test_example_dry_run(name):
    proc = subprocess.run(
        [sys.executable, os.path.join(EXAMPLES_DIR, name)],
        env=dict(os.environ, PYTHONPATH=SRC, REPRO_DRYRUN="1"),
        capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, \
        f"{name} dry-run failed:\n{proc.stdout[-2000:]}" \
        f"\n{proc.stderr[-2000:]}"
    assert proc.stdout.strip(), f"{name} dry-run printed nothing"
