"""reinit_main semantics, fault injection, optimizer, data pipeline."""
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (FailureType, FaultInjector, RankState, ROLLBACK,
                        RollbackSignal, reinit_main)
from repro.core.elastic import ElasticManager, MeshEpoch
from repro.core.protocol import ClusterView
from repro.core.recovery import get_strategy
from repro.train.data import TokenPipeline
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, \
    lr_at


def test_reinit_main_states():
    calls = []

    def fn(state):
        calls.append(state)
        if len(calls) < 3:
            ROLLBACK.arm(len(calls))
            ROLLBACK.check()
        return 7

    assert reinit_main(fn) == 7
    assert calls == [RankState.NEW, RankState.REINITED, RankState.REINITED]


def test_reinit_main_restarted_initial_state():
    seen = []
    reinit_main(lambda s: seen.append(s),
                initial_state=RankState.RESTARTED)
    assert seen == [RankState.RESTARTED]


def test_reinit_main_exhausts():
    def always_roll(state):
        raise RollbackSignal(0)
    with pytest.raises(RuntimeError):
        reinit_main(always_roll, max_restarts=3)


def test_fault_injector_deterministic():
    a = FaultInjector(n_ranks=64, n_steps=100, seed=9)
    b = FaultInjector(n_ranks=64, n_steps=100, seed=9)
    assert (a.fail_step, a.fail_rank) == (b.fail_step, b.fail_rank)
    # fires exactly once
    hits = [s for s in range(100) if a.check(s) is not None]
    assert hits == [a.fail_step]


def test_fault_injector_node_kind_names_node():
    view = ClusterView.build(4, 4)
    inj = FaultInjector(n_ranks=16, n_steps=10, kind=FailureType.NODE,
                        seed=1)
    ev = inj.check(inj.fail_step, view)
    assert ev.kind is FailureType.NODE and ev.node is not None


def test_strategy_lookup_aliases():
    assert get_strategy("Reinit++").name == "Reinit++"
    assert get_strategy("CR").redeploys
    assert get_strategy("ulfm").heartbeat is not None
    assert get_strategy("replica").replicates


def test_strategy_registry_single_source_of_truth():
    """Drift guard: every strategy-keyed surface — the scenario schema,
    the Table-2 checkpoint policy, the real-runtime engine and the root
    CLI — must derive from (or exactly cover) core.recovery.STRATEGIES.
    The checks live in reprolint's registry checker (so drift also
    fails the static-analysis CI job); this is a thin wrapper over the
    analyzer API plus the one literal the checker can't know: the
    paper's strategy set itself."""
    import repro.analysis as analysis
    from repro.analysis import registry
    from repro.core.recovery import STRATEGIES

    assert set(STRATEGIES) == {"reinit", "cr", "ulfm", "shrink",
                               "replica"}
    findings = registry.check(analysis.live_source_tree())
    assert findings == [], "\n".join(f.render() for f in findings)


def test_elastic_shrink_transition():
    from repro.core.events import FailureEvent
    em = ElasticManager(ClusterView.build(2, 4, 0),
                        MeshEpoch(0, data_parallel=2, model_parallel=4))
    node_f = FailureEvent(kind=FailureType.NODE, rank=4, node="node1")
    assert em.decide(node_f) == "shrink"          # no spares, above floor
    cmd = em.shrink(node_f)
    assert set(cmd.dropped) == {4, 5, 6, 7}
    assert em.mesh.data_parallel == 1 and em.mesh.epoch == 1
    # at the floor: shrinking is refused, recovery falls back to respawn
    proc_f = FailureEvent(kind=FailureType.PROCESS, rank=0)
    em.min_data_parallel = 1                      # floor = 4 = |world|
    assert em.decide(proc_f) == "respawn"


# ----------------------------------------------------------- optimizer

def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert abs(float(lr_at(cfg, 10)) - 1.0) < 1e-6
    assert float(lr_at(cfg, 55)) < 1.0
    assert abs(float(lr_at(cfg, 100)) - 0.1) < 1e-6


def test_adamw_decreases_quadratic_loss():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=1000, min_lr_ratio=1.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 1e-2


def test_grad_clip_caps_update_norm():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, _, metrics = adamw_update(params, g, opt, cfg)
    assert float(metrics["grad_norm"]) > 1e5   # reported raw


# ------------------------------------------------------------- data

@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_data_step_indexed_deterministic(step):
    p = TokenPipeline(vocab_size=512, global_batch=2, seq_len=16, seed=3)
    a = p.batch(step)
    b = p.batch(step)
    assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    # labels are next-token
    assert a["tokens"].shape == a["labels"].shape == (2, 16)


def test_data_different_steps_differ():
    p = TokenPipeline(vocab_size=512, global_batch=2, seq_len=16, seed=3)
    a, b = p.batch(1), p.batch(2)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))


def test_data_tokens_in_vocab():
    p = TokenPipeline(vocab_size=100, global_batch=4, seq_len=32, seed=0)
    t = np.asarray(p.batch(5)["tokens"])
    assert t.min() >= 0 and t.max() < 100
