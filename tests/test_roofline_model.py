"""Validation of the roofline methodology:

1. The analytic FLOP model (models/flops.py) must agree with XLA's
   cost_analysis on a small UNROLLED single-device config (where XLA
   counts every op exactly once and nothing is sharded away).
2. The HLO while-trip-count extraction must recover known scan lengths.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=env, capture_output=True, text=True,
                          timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


@pytest.mark.parametrize("arch", ["qwen2-7b", "olmoe-1b-7b",
                                  "falcon-mamba-7b"])
def test_analytic_flops_vs_cost_analysis(arch):
    out = _run(f"""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, reduced
        from repro.models.model import Model
        from repro.models.flops import forward_flops
        from repro.models.transformer import ExecConfig
        cfg = reduced(get_config("{arch}")).replace(
            d_model=128, d_ff=256, n_layers=2, vocab_size=512,
            n_heads=4, n_kv_heads=2 if "{arch}" != "olmoe-1b-7b" else 4,
            head_dim=32)
        ec = ExecConfig(scan_layers=False, remat_policy="none",
                        xent_chunks=1, attn_impl="naive")
        model = Model(cfg, ec)
        B, S = 2, 128
        batch = {{"tokens": jax.ShapeDtypeStruct((B,S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B,S), jnp.int32)}}
        def fwd(p, b):
            return model.loss_fn(p, b)[0]
        params = model.abstract_params()
        comp = jax.jit(fwd).lower(params, batch).compile()
        from repro.launch.hlo_analysis import compiled_cost_analysis
        measured = compiled_cost_analysis(comp)["flops"]
        analytic = forward_flops(cfg, B, S, flash=False)
        ratio = analytic / measured
        print("RATIO", ratio)
    """)
    ratio = float(out.split("RATIO")[1].strip())
    # analytic counts matmuls only; XLA adds elementwise/transcendental
    # flops, so analytic is a slight undercount — accept 0.7..1.1
    assert 0.7 < ratio < 1.1, ratio


def test_while_trip_count_extraction():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from repro.launch.hlo_analysis import while_report, \\
            collective_summary
        from repro.launch.mesh import make_host_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = make_host_mesh((2,2), ("data","model"))
        def fn(params, x):
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, params)
            return jnp.sum(h)
        params = jax.ShapeDtypeStruct((13, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        comp = jax.jit(fn,
            in_shardings=(NamedSharding(mesh, P(None, "model", None)),
                          NamedSharding(mesh, P("data", "model"))),
            out_shardings=NamedSharding(mesh, P())).lower(params, x)\\
            .compile()
        hlo = comp.as_text()
        trips = [w["trip"] for w in while_report(hlo)]
        print("TRIPS", trips)
        s = collective_summary(hlo)
        print("COLL", s.get("all-reduce", 0))
    """)
    trips = eval(out.split("TRIPS")[1].splitlines()[0])
    assert 13 in trips
    # in-loop all-reduce of (16,64) f32 x 13 trips + 2 scalar reductions
    coll = int(out.split("COLL")[1].strip())
    assert coll >= 13 * 16 * 64 * 4


def test_shape_bytes():
    from repro.launch.hlo_analysis import shape_bytes
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[2,2]") == 8
    assert shape_bytes("(f32[4], s32[2])") == 24
    assert shape_bytes("pred[8]") == 8


def test_cell_cost_sanity():
    from repro.configs import get_config
    from repro.models.config import SHAPES
    from repro.models.flops import cell_cost
    cfg = get_config("qwen3-32b")
    train = cell_cost(cfg, SHAPES["train_4k"])
    decode = cell_cost(cfg, SHAPES["decode_32k"])
    # train ≈ 4x fwd; MODEL_FLOPS=6ND should be within ~2.5x of analytic
    assert 0.3 < train.details["model_flops"] / train.flops < 1.2
    # decode is memory-bound: bytes/flops ratio far above train's
    assert (decode.hbm_bytes / decode.flops) > \
        50 * (train.hbm_bytes / train.flops)
    # MoE active-param counting
    moe = get_config("qwen3-moe-30b-a3b")
    t = cell_cost(moe, SHAPES["train_4k"])
    assert t.details["model_flops"] < 0.5 * 6 * moe.param_count() * \
        SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len
