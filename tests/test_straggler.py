"""StragglerTracker: unit edges + seeded property suite.

The tracker is the gray-failure tentpole's detection layer — the root's
drain path and the trainer's mitigation both act on its verdicts, so its
three properties are proven directly:

  1. it never flags under i.i.d. noise within the threshold,
  2. it always flags a sustained x-k slowdown within the window,
  3. per-rank attribution never blames a healthy rank.
"""
import pytest

from _hyp import HAS_HYPOTHESIS, given, settings, st
from repro.train.straggler import StragglerTracker


# ---------------------------------------------------------------- units

def test_no_flag_before_min_samples():
    """The boundary is exact: the first `min_samples` observations can
    never flag (no baseline yet), the very next one can."""
    tr = StragglerTracker(min_samples=3, threshold_mads=4.0)
    assert not tr.observe(1, 100.0)      # huge, but no baseline
    assert not tr.observe(2, 1.0)
    assert not tr.observe(3, 1.0)        # len==2 < min_samples
    assert not tr.observe(4, 1.0)        # len==3: baseline armed, on time
    assert tr.observe(5, 300.0)          # and now outliers flag
    assert tr.flagged == [(5, 300.0)]


def test_flat_line_mad_zero_guard():
    """A perfectly flat window has MAD == 0; the epsilon guard and the
    1.5x-median relative floor keep tiny jitter from flagging while a
    real excursion still does."""
    tr = StragglerTracker(min_samples=4, threshold_mads=6.0)
    for s in range(4):
        tr.observe(s, 1.0)
    assert not tr.observe(10, 1.0001)    # jitter over a flat line
    assert not tr.observe(11, 1.4)       # below the 1.5x relative floor
    assert tr.observe(12, 2.0)           # a real excursion


def test_min_flag_s_absolute_floor():
    """Sub-resolution lateness is never a straggler, whatever the
    relative stats say."""
    tr = StragglerTracker(min_samples=3, threshold_mads=4.0,
                          min_flag_s=0.5)
    for s in range(4):
        tr.observe(s, 0.001)
    assert not tr.observe(5, 0.1)        # 100x the median, under floor
    assert tr.observe(6, 0.6)            # over both floors


def test_per_rank_attribution_and_streaks():
    """The docstring's contract: rank= observations attribute flags and
    consecutive-flag streaks to that rank; one on-time observation
    resets the streak; reset_streaks() wipes the slate."""
    tr = StragglerTracker(min_samples=4, threshold_mads=4.0)
    for s in range(4):
        for r in range(4):
            tr.observe(s, 1.0, rank=r)
    assert tr.observe(5, 6.0, rank=1)
    assert not tr.persistent(1, persist=2)
    assert tr.observe(6, 6.0, rank=1)
    assert tr.persistent(1, persist=2)
    assert tr.stragglers(persist=2) == {1}
    assert set(tr.flagged_by_rank) == {1}
    assert [s for s, _ in tr.flagged_by_rank[1]] == [5, 6]
    tr.observe(7, 1.0, rank=1)           # back on time: streak resets
    assert not tr.persistent(1, persist=1)
    assert tr.observe(8, 6.0, rank=1)
    tr.reset_streaks()                   # recovery boundary
    assert tr.stragglers(persist=1) == set()
    assert tr.median > 0


def test_on_straggler_callback_fires():
    seen = []
    tr = StragglerTracker(min_samples=2, threshold_mads=4.0,
                          on_straggler=lambda s, t, m: seen.append((s, t)))
    tr.observe(1, 1.0)
    tr.observe(2, 1.0)
    tr.observe(3, 9.0)
    assert seen == [(3, 9.0)]


# ----------------------------------------------------------- properties

@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(min_value=1.0, max_value=1.45),
                min_size=12, max_size=80))
def test_never_flags_iid_noise_within_threshold(samples):
    """Noise whose spread stays under the 1.5x-median relative floor can
    NEVER flag: max <= 1.45 < 1.5 * median (median >= 1.0), whatever
    the MAD works out to."""
    tr = StragglerTracker(min_samples=10, threshold_mads=6.0)
    for s, dt in enumerate(samples):
        assert not tr.observe(s, dt, rank=s % 4)
    assert tr.flagged == [] and tr.flagged_by_rank == {}
    assert tr.stragglers(persist=1) == set()


@settings(max_examples=60, deadline=None)
@given(st.floats(min_value=2.0, max_value=50.0),
       st.integers(min_value=1, max_value=4))
def test_always_flags_sustained_slowdown_within_window(factor, persist):
    """A sustained x-factor (>= 2) slowdown over a ~1 s healthy baseline
    flags on EVERY degraded observation, so any persistence threshold
    is reached in exactly `persist` observations — within the window."""
    tr = StragglerTracker(window=32, min_samples=10, threshold_mads=6.0)
    for s in range(10):
        tr.observe(s, 1.0, rank=s % 4)
    for i in range(persist):
        assert tr.observe(10 + i, factor * 1.0, rank=1)
    assert tr.persistent(1, persist=persist)
    assert tr.stragglers(persist=persist) == {1}


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(min_value=0.9, max_value=1.1),
                min_size=24, max_size=24),
       st.integers(min_value=0, max_value=3),
       st.floats(min_value=4.0, max_value=20.0))
def test_healthy_ranks_never_blamed(noise, victim, factor):
    """Mixed population: three healthy ranks inside the noise band, one
    sustained straggler. Attribution lands on the victim alone — the
    population baseline keeps healthy jitter (<= 1.1 < 1.5 * median,
    median >= 0.9) unflaggable even while the victim inflates the
    window."""
    tr = StragglerTracker(window=32, min_samples=10, threshold_mads=6.0)
    it = iter(noise)
    for s in range(6):
        for r in range(4):
            dt = factor * 1.0 if r == victim and s >= 3 else next(it)
            tr.observe(s, dt, rank=r)
    healthy = set(range(4)) - {victim}
    assert set(tr.flagged_by_rank) <= {victim}
    assert tr.stragglers(persist=1) <= {victim}
    for r in healthy:
        assert not tr.persistent(r, persist=1)
    # and the victim was in fact caught
    assert tr.persistent(victim, persist=2)


def test_property_suite_is_live():
    """Guard for the seeded-fallback shim: when hypothesis IS available
    the three properties above must be real tests, not skips."""
    if not HAS_HYPOTHESIS:
        pytest.skip("hypothesis not installed; properties skip too")
    assert callable(st.floats)
