"""Device dirty-tile gather + background delta re-base.

The load-bearing properties:

  - the gather kernel (ref / jnp / Pallas-interpret) is an exact tile
    permutation: gathered bytes are the dirty tiles, bit-for-bit;
  - a delta frame built from gathered tiles is byte-identical to one
    built from the full host state — readers cannot tell them apart;
  - dirtiness detection is sound against uniform scalings: fp32 `x *= 2`
    shifts every word of a tile by the same amount, which aliases to
    zero in both linear sum columns (1024 * 2^23 ≡ 0 mod 2^32), so only
    the nonlinear mix column flags the tile;
  - a delta save with the gather on moves D2H bytes proportional to
    dirt, not state size;
  - a background re-base compacts a delta chain into a self-contained
    base without changing a single restored bit, and a crash at ANY of
    its hook points leaves the old chain authoritative and loadable.
"""
import os
import signal
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

import repro.checkpoint
from repro.checkpoint import FileCheckpointer, serde
from repro.kernels.checksum.ref import (TILE_BYTES, TILE_WORDS,
                                        gather_tiles_ref,
                                        tile_checksums_ref)
from repro.scenarios import hooks

SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(repro.checkpoint.__file__))))


def _rng_state(seed=0, leaves=3, tiles_per_leaf=8):
    rng = np.random.default_rng(seed)
    n = tiles_per_leaf * TILE_BYTES // 4
    return {f"w{i}": jnp.asarray(
        rng.standard_normal(n).astype(np.float32))
        for i in range(leaves)}


def _dirty(state, keys, frac=0.05, bump=1.0001):
    out = dict(state)
    for k in keys:
        a = np.asarray(state[k]).copy()
        w = max(1, int(a.size * frac))
        a[:w] *= bump
        out[k] = jnp.asarray(a)
    return out


# --------------------------------------------------------- gather kernel

@pytest.mark.parametrize("dtype,n", [
    (np.float32, 5 * TILE_WORDS + 7),      # partial trailing tile
    (np.uint8, 3 * TILE_BYTES),            # exact tiles, sub-word dtype
    (np.float16, 2 * TILE_WORDS),
])
def test_gather_tiles_parity(dtype, n):
    from repro.kernels.checksum.kernel import gather_tiles_kernel
    from repro.kernels.checksum.ops import (_device_tiles2d,
                                            gather_tiles_device)
    rng = np.random.default_rng(1)
    a = (rng.standard_normal(n) * 10).astype(dtype)
    nt = tile_checksums_ref(a).shape[0]
    idx = np.asarray(sorted(rng.choice(nt, size=min(3, nt),
                                       replace=False)), np.int32)
    ref = gather_tiles_ref(a, idx)
    dev = np.asarray(gather_tiles_device(jnp.asarray(a), idx))
    assert np.array_equal(ref, dev)
    tiles2d = _device_tiles2d(jnp.asarray(a)).reshape(-1, 128)
    pallas = np.asarray(gather_tiles_kernel(tiles2d, jnp.asarray(idx),
                                            interpret=True))
    assert np.array_equal(ref, pallas)


def test_gathered_frame_bit_identical_to_host_frame():
    """A delta frame assembled from device-gathered tile buffers must be
    byte-identical to one assembled from full host arrays — the reader
    cannot tell which path produced it."""
    prev = {k: np.asarray(v) for k, v in _rng_state(2).items()}
    cur = {k: v.copy() for k, v in prev.items()}
    cur["w0"][100:300] += 1.0                       # 1 dirty tile
    cur["w1"][0:TILE_BYTES // 4 * 3] *= 2.0         # 3-tile run
    plan = serde.delta_plan(cur, serde.tile_digests(prev))
    host_frame = serde.to_delta_bytes(cur, plan, base_step=1)
    # rebuild the same frame from gathered tile buffers (the device path)
    gathered = {}
    for k, rng_ in plan.entries.items():
        v = cur[k]
        if rng_ is None:
            bv = v.reshape(-1).view(np.uint8)
            gathered[k] = serde.GatherLeaf(str(v.dtype), v.shape, True,
                                           [(0, bv.size, bv)])
            continue
        buf = gather_tiles_ref(v, serde.range_tiles(rng_))
        bv = buf.reshape(-1).view(np.uint8)
        runs, pos = [], 0
        for o, n in rng_:
            runs.append((o, n, bv[pos:pos + n]))
            pos += (-(-n // TILE_BYTES)) * TILE_BYTES
        gathered[k] = serde.GatherLeaf(str(v.dtype), v.shape, False, runs)
    dev_frame = serde.to_delta_bytes_gathered(gathered, base_step=1)
    assert host_frame == dev_frame


# --------------------------------------- dirtiness vs uniform scalings

def _scaling_aliases_linear_columns(tile: np.ndarray,
                                    scaled: np.ndarray) -> bool:
    """True when the scaling is invisible to both linear sum columns."""
    ta, tb = tile_checksums_ref(tile), tile_checksums_ref(scaled)
    return bool(np.all(ta[:, :2] == tb[:, :2]))


def test_fp32_times_two_aliases_linear_sums_but_mix_catches_it():
    # every word is a same-exponent float: *2 adds exactly 2^23 to each
    # of the 1024 words of the tile, and 1024 * 2^23 = 2^33 ≡ 0 mod 2^32
    # in s0; s1's weighted sum is 2^23 * 1024*1025/2 = 1025 * 2^32 ≡ 0.
    # A linear-only digest would call this tile clean.
    a = np.full(TILE_WORDS, 1.5, np.float32)
    b = a * 2.0
    assert _scaling_aliases_linear_columns(a, b)     # the trap is real
    ta, tb = tile_checksums_ref(a), tile_checksums_ref(b)
    assert np.any(ta[:, 2] != tb[:, 2])              # mix column differs
    plan = serde.delta_plan({"x": b},
                            serde.tile_digests({"x": a}))
    assert plan.entries["x"] is not None             # flagged dirty


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2.0, 0.5, 4.0]),
       st.integers(1, 4))
def test_uniform_scaling_flags_dirty_tiles(seed, scale, tiles):
    _check_uniform_scaling(seed, scale, tiles)


@pytest.mark.parametrize("seed,scale,tiles",
                         [(0, 2.0, 1), (1, 0.5, 2), (7, 4.0, 3)])
def test_uniform_scaling_flags_dirty_tiles_seeded(seed, scale, tiles):
    _check_uniform_scaling(seed, scale, tiles)


def _check_uniform_scaling(seed, scale, tiles):
    """Scaling any prefix of same-exponent fp32 tiles must mark exactly
    the touched tiles dirty — and the composed delta restores bit-exact."""
    rng = np.random.default_rng(seed)
    n = 4 * TILE_WORDS
    # same-exponent mantissas: the adversarial case for linear sums
    a = (1.0 + rng.random(n, np.float32) * 0.5).astype(np.float32)
    b = a.copy()
    b[:tiles * TILE_WORDS] *= np.float32(scale)
    prev_tiles = serde.tile_digests({"x": a})
    plan = serde.delta_plan({"x": b}, prev_tiles)
    assert plan.entries.get("x") is not None
    covered = set(serde.range_tiles(plan.entries["x"]).tolist())
    assert covered == set(range(tiles))              # exact localization
    restored = dict(serde.apply_delta(
        {"x": a.copy()},
        np.frombuffer(serde.to_delta_bytes({"x": b}, plan, base_step=0),
                      np.uint8), set())[2])
    assert np.asarray(restored["x"]).tobytes() == b.tobytes()


# ------------------------------------------------- FileCheckpointer paths

def test_sync_save_uses_device_digests(monkeypatch, tmp_path):
    """satellite: a sync save must ride the same on-device digest path
    as async — if any leaf fell back to host hashing, this bombs."""
    import repro.checkpoint.file_ckpt as fc

    def bomb(_):
        raise AssertionError("host leaf_digest called on device path")

    monkeypatch.setattr(fc, "leaf_digest", bomb)
    state = _rng_state(3)
    ck = FileCheckpointer(str(tmp_path), delta_every=4, gather="on",
                          n_shards=2)
    ck.save(1, state, async_=False)
    ck.save(2, _dirty(state, ["w0"]), async_=False)
    assert ck.last_write["kind"] == "delta"
    ck.close()
    monkeypatch.undo()
    ck2 = FileCheckpointer(str(tmp_path))
    step, st_ = ck2.load_latest(verify=True)
    assert step == 2
    ck2.close()


def test_npz_delta_every_forced_full(tmp_path):
    """satellite: npz shards are always full archives — a delta_every
    request must be coerced to full frames with no chain commits."""
    state = {k: np.asarray(v) for k, v in _rng_state(4).items()}
    ck = FileCheckpointer(str(tmp_path), fmt="npz", delta_every=8)
    assert ck.delta_every == 0 and not ck._delta_on
    for s in (1, 2, 3):
        ck.save(s, state)
        assert ck.last_write["kind"] == "full"
        assert ck._manifest(s).kind == "full"
    assert ck._chain.prev is None        # planner never engaged
    step, st_ = ck.load_latest(verify=True)
    assert step == 3
    assert all(np.array_equal(np.asarray(st_[k]), state[k])
               for k in state)
    ck.close()


def test_gather_e2e_bit_exact_and_d2h_proportional(tmp_path):
    """End-to-end over mixed sync/async saves with the gather forced on:
    every step restores bit-exactly, and a sparse-dirty delta save moves
    D2H bytes <= 0.25x of a full-state drain (the acceptance bound)."""
    ck = FileCheckpointer(str(tmp_path), keep=20, n_shards=2,
                          delta_every=8, gather="on")
    state = _rng_state(5, tiles_per_leaf=16)
    hist = {}
    for s in range(1, 7):
        if s > 1:
            state = _dirty(state, [f"w{s % 3}"], frac=0.05)
        ck.save(s, state, async_=(s % 2 == 0))
        hist[s] = {k: np.asarray(v).copy() for k, v in state.items()}
    ck.wait()
    full_d2h = sum(v.nbytes for v in state.values())
    assert ck.last_write["kind"] == "delta"
    assert ck.last_write["d2h_bytes"] <= 0.25 * full_d2h
    for s in ck.steps():
        _, st_ = ck.load(s, verify=True)
        for k in hist[s]:
            assert np.asarray(st_[k]).tobytes() == hist[s][k].tobytes(), \
                (s, k)
    ck.close()


# ---------------------------------------------------------------- rebase

def _chain_with_rebase(tmp_path, *, rebase_after, steps=6, keep=20):
    ck = FileCheckpointer(str(tmp_path), keep=keep, n_shards=2,
                          delta_every=32, gather="on",
                          rebase_after=rebase_after)
    state = _rng_state(6)
    hist = {}
    for s in range(1, steps + 1):
        if s > 1:
            state = _dirty(state, [f"w{s % 3}"])
        ck.save(s, state)
        hist[s] = {k: np.asarray(v).copy() for k, v in state.items()}
    return ck, state, hist


def test_rebase_compacts_chain_and_restores_bit_exact(tmp_path):
    ck, state, hist = _chain_with_rebase(tmp_path, rebase_after=3)
    ck.wait()
    assert ck.last_rebase.get("ok"), ck.last_rebase
    tip = ck.last_rebase["step"]
    # the rebased step now reads back as a self-contained full frame
    assert ck._manifest(tip).kind == "full"
    assert os.path.exists(os.path.join(ck._step_dir(tip), "rebase",
                                       "COMMITTED"))
    links, _ = ck._chain_cost(ck.steps()[-1])
    assert links < 3                     # chain cost reset at the tip
    for s in ck.steps():                 # every step still bit-exact
        _, st_ = ck.load(s, verify=True)
        for k in hist[s]:
            assert np.asarray(st_[k]).tobytes() == hist[s][k].tobytes()
    ck.close()


def test_rebase_releases_old_anchor_to_gc(tmp_path):
    """Once the re-based frame commits, the old chain anchor is no
    longer in any kept chain's closure — the normal GC reaps it."""
    ck, state, hist = _chain_with_rebase(tmp_path, rebase_after=2,
                                         steps=4, keep=3)
    ck.wait()
    assert ck.last_rebase.get("ok"), ck.last_rebase
    for s in (5, 6, 7):                  # age the window past step 1
        state = _dirty(state, ["w0"])
        ck.save(s, state)
        hist[s] = {k: np.asarray(v).copy() for k, v in state.items()}
    ck.wait()
    kept = ck.steps()
    assert 1 not in kept                 # anchor reaped post-rebase
    for s in kept[-3:]:
        _, st_ = ck.load(s, verify=True)
        for k in hist[s]:
            assert np.asarray(st_[k]).tobytes() == hist[s][k].tobytes()
    ck.close()


@pytest.mark.parametrize("point", ["ckpt.file.rebase.begin",
                                   "ckpt.file.rebase.pre_commit"])
def test_rebase_crash_at_hook_leaves_chain_authoritative(tmp_path, point):
    """An exception at either re-base hook soft-fails the compaction:
    the old chain stays authoritative and bit-exact, and a retried
    re-base (same step) cleans the stale staging dir and succeeds."""

    def injector(p, **ctx):
        if p == point:
            raise RuntimeError(f"injected at {p}")

    hooks.install(injector)
    try:
        ck, state, hist = _chain_with_rebase(tmp_path, rebase_after=3)
        ck.wait()
        assert ck.last_rebase.get("ok") is False
        tip = ck.last_rebase["step"]
        assert ck._manifest(tip).kind == "delta"     # nothing committed
        for s in ck.steps():
            _, st_ = ck.load(s, verify=True)
            for k in hist[s]:
                assert np.asarray(st_[k]).tobytes() \
                    == hist[s][k].tobytes()
    finally:
        hooks.clear()
    # retry the same step: stale rebase.tmp_* from the aborted attempt
    # is swept and the compaction lands
    ck._rebase(tip)
    assert ck._manifest(tip).kind == "full"
    assert not [n for n in os.listdir(ck._step_dir(tip))
                if n.startswith("rebase.tmp")]
    _, st_ = ck.load(tip, verify=True)
    for k in hist[tip]:
        assert np.asarray(st_[k]).tobytes() == hist[tip][k].tobytes()
    ck.close()


_CHILD = r"""
import os, signal, sys
import numpy as np
import jax.numpy as jnp
from repro.checkpoint import FileCheckpointer
from repro.scenarios import hooks

d, side = sys.argv[1], sys.argv[2]
rng = np.random.default_rng(0)
state = {f"w{i}": jnp.asarray(rng.standard_normal(8192).astype(np.float32))
         for i in range(3)}
ck = FileCheckpointer(d, keep=20, n_shards=2, delta_every=32,
                      gather="on")
hist = {}
for s in range(1, 7):
    if s > 1:
        k = f"w{s % 3}"
        a = np.asarray(state[k]).copy(); a[:100] *= 1.0001
        state[k] = jnp.asarray(a)
    ck.save(s, state)
    hist[s] = {k: np.asarray(v) for k, v in state.items()}
np.savez(side, **{f"{s}/{k}": v for s, fl in hist.items()
                  for k, v in fl.items()})

def die(p, **ctx):
    if p == "ckpt.file.rebase.pre_commit":
        os.kill(os.getpid(), signal.SIGKILL)

hooks.install(die)
ck._rebase(6)                 # staged frame fires the hook -> SIGKILL
"""


def test_rebase_sigkill_mid_stage_then_recover(tmp_path):
    """SIGKILL the whole process while the re-based frame is staged but
    not committed: a fresh process must see the old chain bit-exactly,
    and its own re-base of the same directory must succeed."""
    d = str(tmp_path / "ckpt")
    side = str(tmp_path / "expected.npz")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (SRC, env.get("PYTHONPATH")) if p)
    proc = subprocess.run([sys.executable, "-c", _CHILD, d, side],
                          env=env, capture_output=True, timeout=300)
    assert proc.returncode == -signal.SIGKILL, proc.stderr.decode()
    expected = dict(np.load(side).items())
    ck = FileCheckpointer(d, keep=20, n_shards=2, delta_every=32,
                          gather="on", rebase_after=3)
    steps = ck.steps()
    assert steps == list(range(1, 7))
    for s in steps:
        _, st_ = ck.load(s, verify=True)
        for k in st_:
            assert np.asarray(st_[k]).tobytes() \
                == expected[f"{s}/{k}"].tobytes(), (s, k)
    ck._rebase(steps[-1])                # survivor compacts the chain
    assert ck._manifest(steps[-1]).kind == "full"
    _, st_ = ck.load(steps[-1], verify=True)
    for k in st_:
        assert np.asarray(st_[k]).tobytes() \
            == expected[f"{steps[-1]}/{k}"].tobytes()
    ck.close()
