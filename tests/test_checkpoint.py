"""Checkpoint substrate: roundtrip, integrity, async, Table 2."""
import os

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.checkpoint import (CheckpointPolicy, FileCheckpointer,
                              checkpoint_kind_for, flatten_state,
                              tree_digest, unflatten_state)
from repro.checkpoint.manifest import Manifest, leaf_digest


@st.composite
def pytrees(draw):
    leaf = st.builds(
        lambda shape, seed: np.random.default_rng(seed).standard_normal(
            shape).astype(np.float32),
        st.lists(st.integers(1, 4), min_size=0, max_size=3).map(tuple),
        st.integers(0, 2**31 - 1))
    return draw(st.dictionaries(
        st.text(alphabet="abcdefg", min_size=1, max_size=4),
        st.one_of(leaf, st.dictionaries(
            st.text(alphabet="hij", min_size=1, max_size=3), leaf,
            min_size=1, max_size=3)),
        min_size=1, max_size=4))


@given(pytrees())
@settings(max_examples=25, deadline=None)
def test_flatten_roundtrip(tree):
    flat = flatten_state(tree)
    rebuilt = unflatten_state(flat)
    assert tree_digest(rebuilt) == tree_digest(tree)


def test_file_roundtrip_and_gc(tmp_path):
    ck = FileCheckpointer(str(tmp_path), keep=2, n_shards=3)
    state = {"a": jnp.arange(8.0), "nest": {"b": jnp.ones((2, 3))},
             "lst": [jnp.zeros(1), jnp.ones(1)]}
    for step in [1, 2, 3, 4]:
        ck.save(step, state)
    assert ck.steps() == [3, 4]                  # keep=2 GC'd older
    step, loaded = ck.load_latest()
    assert step == 4
    assert tree_digest(loaded) == tree_digest(jax.device_get(state))
    assert isinstance(loaded["lst"], list)


def test_corruption_detected(tmp_path):
    ck = FileCheckpointer(str(tmp_path))
    ck.save(7, {"w": jnp.arange(128.0)})
    shard = os.path.join(str(tmp_path), "step_0000000007",
                         "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(200)
        f.write(b"\x00" * 64)
    with pytest.raises(Exception):
        ck.load(7)


def test_uncommitted_ignored(tmp_path):
    ck = FileCheckpointer(str(tmp_path))
    ck.save(3, {"w": jnp.ones(4)})
    fake = os.path.join(str(tmp_path), "step_0000000009")
    os.makedirs(fake)
    assert ck.steps() == [3]                     # no COMMITTED marker
    step, _ = ck.load_latest()
    assert step == 3


def test_async_write(tmp_path):
    ck = FileCheckpointer(str(tmp_path))
    ck.save(5, {"w": jnp.full((64,), 2.0)}, async_=True)
    ck.wait()
    assert ck.steps() == [5]


def test_manifest_verify():
    flat = {"x": np.arange(10, dtype=np.float32)}
    man = Manifest.build(1, flat, lambda k: 0, 1)
    assert man.verify(flat) == []
    bad = {"x": np.arange(10, dtype=np.float32) + 1}
    assert man.verify(bad) == ["x"]
    assert man.verify({}) == ["x"]


def test_table2():
    assert checkpoint_kind_for("process", "cr") == "file"
    assert checkpoint_kind_for("process", "ulfm") == "memory"
    assert checkpoint_kind_for("process", "reinit") == "memory"
    assert checkpoint_kind_for("node", "cr") == "file"
    assert checkpoint_kind_for("node", "ulfm") == "file"
    assert checkpoint_kind_for("node", "reinit") == "file"


def test_policy_cadence():
    p = CheckpointPolicy(every_steps=3)
    assert [s for s in range(1, 10) if p.should_checkpoint(s)] == [3, 6, 9]


def test_leaf_digest_sensitive_to_dtype_and_shape():
    a = np.zeros((4,), np.float32)
    assert leaf_digest(a) != leaf_digest(a.astype(np.float64))
    assert leaf_digest(a) != leaf_digest(a.reshape(2, 2))
