"""Checkpoint substrate: roundtrip, integrity, async, Table 2."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import (CheckpointPolicy, FileCheckpointer,
                              checkpoint_kind_for, flatten_state,
                              tree_digest, unflatten_state)
from repro.checkpoint.manifest import Manifest, leaf_digest


@st.composite
def pytrees(draw):
    leaf = st.builds(
        lambda shape, seed: np.random.default_rng(seed).standard_normal(
            shape).astype(np.float32),
        st.lists(st.integers(1, 4), min_size=0, max_size=3).map(tuple),
        st.integers(0, 2**31 - 1))
    return draw(st.dictionaries(
        st.text(alphabet="abcdefg", min_size=1, max_size=4),
        st.one_of(leaf, st.dictionaries(
            st.text(alphabet="hij", min_size=1, max_size=3), leaf,
            min_size=1, max_size=3)),
        min_size=1, max_size=4))


@given(pytrees())
@settings(max_examples=25, deadline=None)
def test_flatten_roundtrip(tree):
    flat = flatten_state(tree)
    rebuilt = unflatten_state(flat)
    assert tree_digest(rebuilt) == tree_digest(tree)


def test_file_roundtrip_and_gc(tmp_path):
    ck = FileCheckpointer(str(tmp_path), keep=2, n_shards=3)
    state = {"a": jnp.arange(8.0), "nest": {"b": jnp.ones((2, 3))},
             "lst": [jnp.zeros(1), jnp.ones(1)]}
    for step in [1, 2, 3, 4]:
        ck.save(step, state)
    assert ck.steps() == [3, 4]                  # keep=2 GC'd older
    step, loaded = ck.load_latest()
    assert step == 4
    assert tree_digest(loaded) == tree_digest(jax.device_get(state))
    assert isinstance(loaded["lst"], list)


def _flip_leaf_byte(shard_path: str, leaf: str, byte_in_leaf: int = 0):
    """Flip one byte inside `leaf`'s data region of a serde frame."""
    import json
    import struct
    with open(shard_path, "rb") as f:
        buf = f.read()
    _, hlen, _ = struct.unpack("<8sII", buf[:16])
    hdr = json.loads(buf[16:16 + hlen])
    (entry,) = [e for e in hdr["leaves"] if e["path"] == leaf]
    pos = entry["offset"] + byte_in_leaf
    with open(shard_path, "r+b") as f:
        f.seek(pos)
        old = f.read(1)
        f.seek(pos)
        f.write(bytes([old[0] ^ 0xFF]))


def test_corruption_detected(tmp_path):
    ck = FileCheckpointer(str(tmp_path))
    ck.save(7, {"w": jnp.arange(128.0)})
    shard = os.path.join(str(tmp_path), "step_0000000007",
                         "shard_00000.bin")
    _flip_leaf_byte(shard, "w", 200)
    with pytest.raises(Exception):
        ck.load(7)


def test_corruption_detected_npz_legacy(tmp_path):
    ck = FileCheckpointer(str(tmp_path), fmt="npz")
    ck.save(7, {"w": jnp.arange(128.0)})
    shard = os.path.join(str(tmp_path), "step_0000000007",
                         "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(200)
        f.write(b"\x00" * 64)
    with pytest.raises(Exception):
        ck.load(7)


def test_npz_legacy_roundtrip(tmp_path):
    ck = FileCheckpointer(str(tmp_path), fmt="npz", n_shards=2)
    state = {"a": jnp.arange(8.0), "nest": {"b": jnp.ones((2, 3))}}
    ck.save(1, state)
    step, loaded = ck.load_latest()
    assert step == 1
    assert tree_digest(loaded) == tree_digest(jax.device_get(state))


def test_gc_spares_live_tmp_dir(tmp_path):
    """With zero committed steps, an in-flight writer's tmp dir must not
    be swept — the old endswith(()) guard reaped it mid-write."""
    ck = FileCheckpointer(str(tmp_path))
    live = tmp_path / f"tmp_0000000001_{os.getpid()}"
    live.mkdir()
    ck._live_tmps.add(live.name)
    stale = tmp_path / "tmp_0000000009_99999"
    stale.mkdir()
    ck._gc()
    assert live.exists()                     # in-flight writer untouched
    assert not stale.exists()                # crashed-writer junk swept


def test_uncommitted_ignored(tmp_path):
    ck = FileCheckpointer(str(tmp_path))
    ck.save(3, {"w": jnp.ones(4)})
    fake = os.path.join(str(tmp_path), "step_0000000009")
    os.makedirs(fake)
    assert ck.steps() == [3]                     # no COMMITTED marker
    step, _ = ck.load_latest()
    assert step == 3


def test_async_write(tmp_path):
    ck = FileCheckpointer(str(tmp_path))
    ck.save(5, {"w": jnp.full((64,), 2.0)}, async_=True)
    ck.wait()
    assert ck.steps() == [5]


def test_async_double_buffering(tmp_path):
    """Back-to-back async saves overlap (bounded queue of 2); every
    committed checkpoint round-trips bit-identically."""
    ck = FileCheckpointer(str(tmp_path), keep=4, n_shards=2)
    state = {"w": jnp.arange(256.0), "s": jnp.zeros((), jnp.int32)}
    want = tree_digest(jax.device_get(state))
    for step in [1, 2, 3, 4]:
        ck.save(step, state, async_=True)
    ck.wait()
    assert ck.steps() == [1, 2, 3, 4]
    for step in [1, 4]:
        _, loaded = ck.load(step)
        assert tree_digest(loaded) == want


def test_async_device_digest_path(tmp_path, monkeypatch):
    """On accelerator backends the async save enqueues device word-sums
    and the writer finalizes them; the digests must verify against the
    mapped bytes. Simulated here by faking a non-cpu backend."""
    import repro.checkpoint.file_ckpt as fc
    monkeypatch.setattr(fc.jax, "default_backend", lambda: "fake_accel")
    ck = FileCheckpointer(str(tmp_path), n_shards=2)
    state = {"w": jnp.arange(64.0), "b": jnp.ones((3, 5))}
    ck.save(3, state, async_=True)
    ck.wait()
    _, loaded = ck.load(3)                  # verify=True: digests match
    assert tree_digest(loaded) == tree_digest(jax.device_get(state))


def test_manifest_verify():
    flat = {"x": np.arange(10, dtype=np.float32)}
    man = Manifest.build(1, flat, lambda k: 0, 1)
    assert man.verify(flat) == []
    bad = {"x": np.arange(10, dtype=np.float32) + 1}
    assert man.verify(bad) == ["x"]
    assert man.verify({}) == ["x"]


def test_table2():
    assert checkpoint_kind_for("process", "cr") == "file"
    assert checkpoint_kind_for("process", "ulfm") == "memory"
    assert checkpoint_kind_for("process", "reinit") == "memory"
    assert checkpoint_kind_for("node", "cr") == "file"
    assert checkpoint_kind_for("node", "ulfm") == "file"
    assert checkpoint_kind_for("node", "reinit") == "file"


def test_policy_cadence():
    p = CheckpointPolicy(every_steps=3)
    assert [s for s in range(1, 10) if p.should_checkpoint(s)] == [3, 6, 9]


def test_leaf_digest_sensitive_to_dtype_and_shape():
    a = np.zeros((4,), np.float32)
    assert leaf_digest(a) != leaf_digest(a.astype(np.float64))
    assert leaf_digest(a) != leaf_digest(a.reshape(2, 2))
