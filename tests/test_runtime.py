"""Real-process runtime: deploy, inject SIGKILL, recover, verify.

These spawn actual root/daemon/worker process trees over TCP on this host,
so they are the slowest tests in the suite (~10-30 s each).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _run_root(tmp_path, *extra, timeout=150):
    env = dict(os.environ, PYTHONPATH=SRC)
    report = str(tmp_path / "report.json")
    cmd = [sys.executable, "-m", "repro.runtime.root",
           "--nodes", "2", "--ranks-per-node", "2", "--spares", "1",
           "--steps", "6", "--dim", "256",
           "--ckpt-dir", str(tmp_path / "ckpt"),
           "--report", report] + list(extra)
    os.makedirs(str(tmp_path / "ckpt"), exist_ok=True)
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    with open(report) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def fault_free_checksums(tmp_path_factory):
    rep = _run_root(tmp_path_factory.mktemp("ff"), "--mode", "reinit")
    assert rep["events"] == []
    return rep["checksums"]


def test_fault_free_completes(fault_free_checksums):
    assert len(fault_free_checksums) == 4


@pytest.mark.parametrize("kind", ["process", "node"])
def test_reinit_recovery(tmp_path, kind, fault_free_checksums):
    rep = _run_root(tmp_path, "--mode", "reinit", "--fail-kind", kind,
                    "--fail-step", "3", "--fail-rank", "1")
    assert len(rep["events"]) >= 1
    ev = rep["events"][-1]
    assert ev["mpi_recovery_s"] < 10
    assert "resume_step" in ev
    # the recovered run computes the SAME final state as fault-free
    assert rep["checksums"] == fault_free_checksums


@pytest.mark.parametrize("kind", ["process", "node"])
def test_cr_recovery(tmp_path, kind, fault_free_checksums):
    rep = _run_root(tmp_path, "--mode", "cr", "--fail-kind", kind,
                    "--fail-step", "3", "--fail-rank", "1", timeout=300)
    ev = rep["events"][-1]
    assert ev["mpi_recovery_s"] < 30
    assert rep["checksums"] == fault_free_checksums


def test_reinit_faster_than_cr(tmp_path):
    """The paper's headline, at our miniature scale."""
    rep_r = _run_root(tmp_path / "r", "--mode", "reinit",
                      "--fail-kind", "process", "--fail-step", "3",
                      "--fail-rank", "1")
    rep_c = _run_root(tmp_path / "c", "--mode", "cr",
                      "--fail-kind", "process", "--fail-step", "3",
                      "--fail-rank", "1", timeout=300)
    t_r = rep_r["events"][-1]["mpi_recovery_s"]
    t_c = rep_c["events"][-1]["mpi_recovery_s"]
    assert t_r < t_c, f"reinit {t_r:.2f}s !< cr {t_c:.2f}s"
