import os
import sys

# tests run on the single real CPU device — only the dry-run uses the
# 512-placeholder fleet, and it does so in its own subprocesses.
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
