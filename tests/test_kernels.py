"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref

RNG = np.random.default_rng(42)


def _mk(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,hd", [
    (2, 128, 128, 4, 4, 64),        # MHA
    (1, 256, 256, 8, 2, 64),        # GQA 4:1
    (2, 128, 256, 4, 1, 128),       # MQA, longer KV (decode-suffix case)
    (1, 128, 128, 4, 4, 128),
    (1, 512, 512, 2, 2, 64),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Sq, Sk, H, Hkv, hd, causal, dtype):
    q = _mk((B, Sq, H, hd), dtype)
    k = _mk((B, Sk, Hkv, hd), dtype)
    v = _mk((B, Sk, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_block_sizes():
    q = _mk((1, 256, 2, 64), jnp.float32)
    k = _mk((1, 256, 2, 64), jnp.float32)
    v = _mk((1, 256, 2, 64), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 256), (256, 128)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_flash_attention_tiny_fallback():
    """Degenerate shapes fall back to the reference (no kernel launch)."""
    q = _mk((1, 4, 2, 16), jnp.float32)
    k = _mk((1, 4, 2, 16), jnp.float32)
    v = _mk((1, 4, 2, 16), jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("b,S,di,ds", [
    (2, 64, 32, 8),
    (1, 256, 128, 16),
    (2, 128, 64, 16),
    (1, 128, 256, 32),
])
@pytest.mark.parametrize("chunk,block_d", [(32, 32), (64, 128)])
def test_mamba_scan_matches_ref(b, S, di, ds, chunk, block_d):
    x = _mk((b, S, di), jnp.float32) * 0.5
    dt = jnp.abs(_mk((b, S, di), jnp.float32)) * 0.1
    B = _mk((b, S, ds), jnp.float32)
    C = _mk((b, S, ds), jnp.float32)
    A = -jnp.abs(_mk((di, ds), jnp.float32)) - 0.1
    y, h = mamba_scan(x, dt, B, C, A, interpret=True, chunk=chunk,
                      block_d=block_d)
    yr, hr = selective_scan_ref(x, dt, B, C, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=1e-4, rtol=1e-4)


def test_mamba_scan_state_continuity():
    """Scanning two halves with carried state == one full scan."""
    b, S, di, ds = 1, 128, 32, 8
    x = _mk((b, S, di), jnp.float32) * 0.5
    dt = jnp.abs(_mk((b, S, di), jnp.float32)) * 0.1
    B = _mk((b, S, ds), jnp.float32)
    C = _mk((b, S, ds), jnp.float32)
    A = -jnp.abs(_mk((di, ds), jnp.float32)) - 0.1
    y_full, h_full = selective_scan_ref(x, dt, B, C, A)
    half = S // 2
    y1, h1 = selective_scan_ref(x[:, :half], dt[:, :half], B[:, :half],
                                C[:, :half], A)
    y2, h2 = selective_scan_ref(x[:, half:], dt[:, half:], B[:, half:],
                                C[:, half:], A, h0=h1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, half:]),
                               atol=1e-5)
