"""Checksum kernel: Pallas (interpret) vs jnp fallback vs numpy oracle."""
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.checkpoint.manifest import leaf_digest
from repro.kernels.checksum.kernel import checksum_kernel
from repro.kernels.checksum.ops import _device_words, checksum_words
from repro.kernels.checksum.ref import checksum_words_ref

RNG = np.random.default_rng(7)
BF16 = np.dtype(ml_dtypes.bfloat16)


def _cases():
    return [
        RNG.standard_normal(8).astype(np.float32),
        RNG.standard_normal((33, 7)).astype(np.float32),
        RNG.standard_normal(4096).astype(np.float32),
        RNG.standard_normal(513).astype(np.float16),
        RNG.standard_normal(513).astype(BF16),
        RNG.integers(0, 255, 1001).astype(np.uint8),
        RNG.integers(-10, 10, 129).astype(np.int32),
        np.float32(1.5).reshape(()),
        (RNG.random(65) > 0.5),
    ]


@pytest.mark.parametrize("idx", range(9))
def test_pallas_matches_numpy_ref(idx):
    a = _cases()[idx]
    ref = checksum_words_ref(a)
    assert checksum_words(jnp.asarray(a), interpret=True) == ref
    assert checksum_words(jnp.asarray(a)) == ref          # jnp fallback


def test_pallas_block_sizes():
    a = RNG.standard_normal(10_000).astype(np.float32)
    ref = checksum_words_ref(a)
    words = _device_words(jnp.asarray(a))
    for br in (1, 4, 8, 16):
        s0, s1 = checksum_kernel(words, block_rows=br, interpret=True)
        assert (int(s0), int(s1)) == ref, br


def test_order_sensitivity():
    a = np.arange(256, dtype=np.float32)
    b = a.copy()
    b[0], b[1] = b[1], b[0]
    assert checksum_words_ref(a) != checksum_words_ref(b)


def test_single_bit_flip_changes_digest():
    a = RNG.standard_normal(1024).astype(np.float32)
    b = a.copy()
    raw = b.view(np.uint8)
    raw[2048] ^= 0x01
    assert leaf_digest(a) != leaf_digest(b)


def test_digest_sensitive_to_dtype_and_shape():
    # all-zero bytes: word-sums are 0 for every layout — the metadata
    # mixed into the digest must still tell them apart
    a = np.zeros((4,), np.float32)
    assert leaf_digest(a) != leaf_digest(a.astype(np.float64))
    assert leaf_digest(a) != leaf_digest(a.reshape(2, 2))
    assert leaf_digest(a) != leaf_digest(np.zeros((8,), np.float32))


def test_empty_and_tail_bytes():
    assert checksum_words_ref(np.zeros((0,), np.float32)) == (0, 0)
    # 3 trailing bytes exercise the tail path
    a = RNG.integers(0, 255, 7).astype(np.uint8)
    ref = checksum_words_ref(a)
    assert checksum_words(jnp.asarray(a)) == ref
    assert checksum_words(jnp.asarray(a), interpret=True) == ref


def test_device_digest_matches_host_digest():
    """manifest.leaf_digest must agree across host/device residency —
    a checkpoint digested on device verifies against its mapped bytes."""
    a = RNG.standard_normal(2048).astype(np.float32)
    assert leaf_digest(a) == leaf_digest(jnp.asarray(a))
