"""Sharding rules + buddy exchange on a multi-device (subprocess) mesh."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.models.model import Model
from repro.sharding.rules import PRESETS, spec_for_path, tree_specs

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
RULES = PRESETS["pod"]


def test_param_rules_basic():
    assert spec_for_path("embedding/table", 2, RULES) == \
        P("model", "data")
    assert spec_for_path("stack/layers/attn/wq", 3, RULES) == \
        P(None, "data", "model")
    assert spec_for_path("stack/layers/mlp/wo", 3, RULES) == \
        P(None, "model", "data")
    assert spec_for_path("stack/layers/moe/wi_gate", 4, RULES) == \
        P(None, "model", "data", None)
    assert spec_for_path("stack/layers/ln1/scale", 2, RULES) == \
        P(None, None)
    assert spec_for_path("stack/layers/mamba/in_x", 3, RULES) == \
        P(None, "data", "model")
    assert spec_for_path("stack/layers/mamba/in_bc", 3, RULES) == \
        P(None, "data", None)
    # kv heads are replicated over the model axis (GQA convention)
    assert spec_for_path("stack/layers/attn/wk", 3, RULES) == \
        P(None, "data", None)


def test_every_param_leaf_gets_a_spec():
    """No leaf falls through to an accidental full replication for the big
    tables (norms may replicate, matmuls must shard)."""
    for arch in ["qwen2-7b", "olmoe-1b-7b", "falcon-mamba-7b",
                 "zamba2-7b", "seamless-m4t-medium"]:
        cfg = reduced(get_config(arch))
        params = jax.eval_shape(
            lambda c=cfg: Model(c).init(jax.random.PRNGKey(0)))
        specs = tree_specs(params, RULES)
        flat = jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda s: isinstance(s, P))
        big_unsharded = []
        leaves = jax.tree_util.tree_leaves_with_path(params)
        for (path, spec), (_, leaf) in zip(flat, leaves):
            if np.prod(leaf.shape) > 4096 and spec == P():
                big_unsharded.append(jax.tree_util.keystr(path))
        assert not big_unsharded, f"{arch}: {big_unsharded}"


def test_divisible_drops_nondividing_axes():
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.partition import _divisible
    mesh = make_host_mesh((1,), ("model",))
    # 1-way axis always divides
    assert _divisible(P("model"), (7,), mesh) == P("model")


def test_buddy_exchange_multidevice():
    """Run on 8 simulated CPU devices in a subprocess: the buddy copy is a
    cyclic shift along the data axis, and restore inverts it."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import buddy_exchange, restore_from_buddy
        from repro.launch.mesh import make_host_mesh
        from repro.sharding.rules import ShardingRules
        # vocab axis (dim 0 of the table) carries the data sharding here
        rules = ShardingRules(batch="data", vocab="data")
        mesh = make_host_mesh((8,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        x = jnp.arange(32.0).reshape(8, 4)
        state = {"embedding": {"table": jax.device_put(
            x, NamedSharding(mesh, P("data", None)))}}
        buddy = buddy_exchange(state, mesh, rules)
        b = np.asarray(buddy["embedding"]["table"])
        expect = np.roll(np.asarray(x), 1, axis=0)
        assert np.array_equal(b, expect), (b, expect)
        back = restore_from_buddy(buddy, mesh, rules)
        assert np.array_equal(np.asarray(back["embedding"]["table"]),
                              np.asarray(x))
        print("BUDDY_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert "BUDDY_OK" in proc.stdout, proc.stderr[-2000:]


def test_shard_constraint_noop_outside_scope():
    from repro.sharding.partition import shard_constraint
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shard_constraint(x, "batch", None)
    assert np.array_equal(np.asarray(x), np.asarray(y))
