"""Fault-tolerant trainer: recovery correctness for all three strategies.

The strongest property the paper's design implies: with a step-indexed
data pipeline and per-step checkpoints, a failure-and-recovery run must
converge to the BIT-IDENTICAL final state of an uninterrupted run.
"""
import jax
import pytest

from repro.checkpoint.manifest import tree_digest
from repro.configs import get_config, reduced
from repro.core import FailureType, FaultInjector
from repro.models.model import Model
from repro.train import AdamWConfig, TokenPipeline, TrainConfig, Trainer

CFG = reduced(get_config("paper-demo"))
STEPS = 10


def _run(tmp_path, strategy, injector=None, tag=""):
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path / tag),
                     strategy=strategy)
    tr = Trainer(model, data, opt, tc, injector=injector)
    res = tr.run()
    return tr, res


@pytest.fixture(scope="module")
def reference(tmp_path_factory):
    d = tmp_path_factory.mktemp("ref")
    tr, res = _run(d, "reinit", tag="ref")
    return tree_digest(jax.device_get(tr.state["params"])), res


@pytest.mark.parametrize("strategy", ["reinit", "ulfm", "cr"])
def test_bitwise_identical_recovery_process_failure(tmp_path, strategy,
                                                    reference):
    ref_digest, _ = reference
    inj = FaultInjector(n_ranks=8, n_steps=STEPS,
                        kind=FailureType.PROCESS, seed=3)
    tr, res = _run(tmp_path, strategy, injector=inj, tag=strategy)
    assert res["final_step"] == STEPS
    assert len(res["reports"]) == 1
    rep = res["reports"][0]
    assert rep.rollback_step == inj.fail_step
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


@pytest.mark.parametrize("strategy", ["reinit", "cr"])
def test_bitwise_identical_recovery_node_failure(tmp_path, strategy,
                                                 reference):
    ref_digest, _ = reference
    inj = FaultInjector(n_ranks=8, n_steps=STEPS, kind=FailureType.NODE,
                        seed=5)
    tr, res = _run(tmp_path, strategy, injector=inj, tag=strategy)
    assert res["final_step"] == STEPS
    # node failure forces the FILE checkpoint path (Table 2)
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


def test_cr_recovery_through_delta_checkpoints(tmp_path):
    """CR restores by composing base + dirty-tile deltas from disk; the
    recovered run still lands on the bit-identical final state."""
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    inj = FaultInjector(n_ranks=8, n_steps=STEPS,
                        kind=FailureType.NODE, seed=5)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path / "d"),
                     strategy="cr", ckpt_delta_every=3)
    tr = Trainer(model, data, opt, tc, injector=inj)
    # AdamW dirties ~every tile, which correctly degrades deltas to full
    # frames; lift the degrade threshold so the restore really walks a
    # base + delta chain
    tr.file_ckpt.delta_max_dirty = 1.0
    res = tr.run()
    assert res["final_step"] == STEPS
    # at least one on-disk step must actually be a delta frame
    kinds = {s: tr.file_ckpt._manifest(s).kind for s in tr.file_ckpt.steps()}
    assert "delta" in kinds.values(), kinds
    tc_ref = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path / "ref"))
    tr_ref = Trainer(model, data, opt, tc_ref)
    tr_ref.run()
    assert tree_digest(jax.device_get(tr.state["params"])) == \
        tree_digest(jax.device_get(tr_ref.state["params"]))


def test_resume_from_disk(tmp_path):
    """Stopping and restarting the trainer resumes from the checkpoint."""
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc5 = TrainConfig(total_steps=5, ckpt_dir=str(tmp_path))
    Trainer(model, data, opt, tc5).run()
    tc10 = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path))
    tr2 = Trainer(model, data, opt, tc10)
    res = tr2.run()
    assert res["final_step"] == STEPS
    # matches a straight-through run
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path) + "_x")
    tr3 = Trainer(model, data, opt, tc)
    tr3.run()
    assert tree_digest(jax.device_get(tr2.state["params"])) == \
        tree_digest(jax.device_get(tr3.state["params"]))


def _shrink_scenario(n_nodes, rpn, spares, fail_rank, fail_step,
                     repairs=(), faults=None):
    from repro.scenarios import Fault, Scenario, Topology
    return Scenario(
        name="trainer-node-loss", steps=STEPS,
        topology=Topology(nodes=n_nodes, ranks_per_node=rpn,
                          spares=spares),
        faults=faults if faults is not None
        else (Fault("node", fail_rank, fail_step),),
        repairs=repairs,
        strategies=("shrink",), expect_bit_identical=False)


def test_elastic_shrink_trainer_continues(tmp_path, reference):
    """ScenarioInjector routes a shrink cell through the in-process SPMD
    trainer: with zero spares, a node loss contracts the world instead of
    re-hosting — the run finishes on the shrunk mesh, resumes from the
    checkpointed cut, and (global batch unchanged) still lands on the
    bit-identical final state."""
    from repro.core import ScenarioInjector
    ref_digest, _ = reference
    inj = ScenarioInjector(_shrink_scenario(2, 4, 0, fail_rank=2,
                                            fail_step=4))
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path),
                     strategy="shrink", n_nodes=2, ranks_per_node=4,
                     spare_nodes=0)
    tr = Trainer(model, data, opt, tc, injector=inj)
    res = tr.run()
    assert res["final_step"] == STEPS
    rep = res["reports"][0]
    assert rep.world_after == 4 and tr.n_ranks == 4
    assert rep.rollback_step == 4
    assert sorted(tr.view.ranks()) == [4, 5, 6, 7]
    assert tr.elastic.mesh.data_parallel == 1 \
        and tr.elastic.mesh.epoch == 1
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


def test_elastic_trainer_spare_absorbs_first_node_loss(tmp_path,
                                                       reference):
    """With a spare in the pool, the same node loss under the elastic
    strategy re-hosts (Algorithm 1) instead of shrinking."""
    from repro.core import ScenarioInjector
    ref_digest, _ = reference
    inj = ScenarioInjector(_shrink_scenario(2, 4, 1, fail_rank=2,
                                            fail_step=4))
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path),
                     strategy="shrink", n_nodes=2, ranks_per_node=4,
                     spare_nodes=1)
    tr = Trainer(model, data, opt, tc, injector=inj)
    res = tr.run()
    assert res["final_step"] == STEPS
    rep = res["reports"][0]
    assert rep.world_after is None and tr.n_ranks == 8
    assert tr.elastic.spares() == []        # the spare absorbed the loss
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


def test_elastic_trainer_grows_back_after_shrink(tmp_path, reference):
    """The full elastic lifecycle through the in-process SPMD driver: a
    node loss shrinks the world (mesh epoch 1, recompile), the repaired
    node's rejoin at a later checkpoint boundary grows it back (mesh
    epoch 2, second recompile) — and the run still lands on the
    bit-identical final state."""
    from repro.core import ScenarioInjector
    from repro.scenarios import Repair
    ref_digest, _ = reference
    inj = ScenarioInjector(_shrink_scenario(2, 4, 0, fail_rank=2,
                                            fail_step=4,
                                            repairs=(Repair(2, 7),)))
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path),
                     strategy="shrink", n_nodes=2, ranks_per_node=4,
                     spare_nodes=0)
    tr = Trainer(model, data, opt, tc, injector=inj)
    res = tr.run()
    assert res["final_step"] == STEPS
    shrink_rep, grow_rep = res["reports"]
    assert shrink_rep.world_after == 4
    assert grow_rep.world_after == 8 and tr.n_ranks == 8
    assert sorted(tr.view.ranks()) == list(range(8))
    assert tr.elastic.mesh.data_parallel == 2
    assert tr.elastic.mesh.epoch == 2       # strictly monotonic remesh
    assert tr.elastic.dropped == []
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


def test_trainer_process_shrink_uneven_groups(tmp_path, reference):
    """Process-level shrink in the driver: a single-rank loss with no
    spares drops that rank (uneven groups), keeps the survivors' memory
    tier, and still finishes bit-identically (global batch unchanged)."""
    from repro.core import ScenarioInjector
    from repro.scenarios import Fault
    ref_digest, _ = reference
    inj = ScenarioInjector(_shrink_scenario(
        2, 4, 0, 0, 0, faults=(Fault("rank", 2, 4),)))
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path),
                     strategy="shrink", n_nodes=2, ranks_per_node=4,
                     spare_nodes=0)
    tr = Trainer(model, data, opt, tc, injector=inj)
    res = tr.run()
    assert res["final_step"] == STEPS
    rep = res["reports"][0]
    assert rep.world_after == 7 and tr.n_ranks == 7   # uneven groups
    assert rep.rollback_step == 4       # survivor memory tier at the cut
    assert sorted(tr.view.ranks()) == [0, 1, 3, 4, 5, 6, 7]
    assert tr.elastic.dropped == [2]
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


def test_trainer_growback_mid_cascade(tmp_path, reference):
    """The growback-mid-cascade shape in-process: the cascade's victim
    is dropped by the shrink, so the fault defers until the grow
    re-admits it, then merges as a respawn (never a second shrink) —
    three reports, world restored, bit-identical continuation."""
    from repro.core import ScenarioInjector
    from repro.scenarios import Fault, Repair
    ref_digest, _ = reference
    inj = ScenarioInjector(_shrink_scenario(
        2, 4, 0, 0, 0,
        faults=(Fault("node", 2, 4),
                Fault("rank", 2, None, point="worker.recovery.pulled")),
        repairs=(Repair(2, 7),)))
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path),
                     strategy="shrink", n_nodes=2, ranks_per_node=4,
                     spare_nodes=0)
    tr = Trainer(model, data, opt, tc, injector=inj)
    res = tr.run()
    assert res["final_step"] == STEPS
    shrink_rep, grow_rep, casc_rep = res["reports"]
    assert shrink_rep.world_after == 4
    assert grow_rep.world_after == 8
    assert casc_rep.world_after is None       # merged respawn, no shrink
    assert tr.n_ranks == 8 and sorted(tr.view.ranks()) == list(range(8))
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


def test_trainer_min_data_parallel_floor(tmp_path, reference):
    """The surfaced floor knob: with min_data_parallel == n_nodes the
    same node loss refuses to shrink and respawns instead."""
    from repro.core import ScenarioInjector
    ref_digest, _ = reference
    inj = ScenarioInjector(_shrink_scenario(2, 4, 0, fail_rank=2,
                                            fail_step=4))
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path),
                     strategy="shrink", n_nodes=2, ranks_per_node=4,
                     spare_nodes=0, min_data_parallel=2)
    tr = Trainer(model, data, opt, tc, injector=inj)
    res = tr.run()
    assert res["final_step"] == STEPS
    rep = res["reports"][0]
    assert rep.world_after is None and tr.n_ranks == 8   # respawned
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


@pytest.mark.parametrize("point,expect_offset", [
    ("worker.ckpt.mid_write", -1),    # save never committed: resume s-1
    ("worker.ckpt.pre_push", 0),      # file committed, buddy not: resume s
])
def test_trainer_checkpoint_phase_faults(tmp_path, reference, point,
                                         expect_offset):
    """ROADMAP satellite: checkpoint-phase injection points flow through
    the in-process trainer via ScenarioInjector — a mid-write death
    resumes one step back, a pre-push death resumes at the committed
    file via the merged buddy+file restore; both continue
    bit-identically."""
    from repro.core import ScenarioInjector
    from repro.scenarios import Fault, Scenario, Topology
    ref_digest, _ = reference
    sc = Scenario(name=f"trainer-{point.rsplit('.', 1)[-1]}", steps=STEPS,
                  topology=Topology(nodes=1, ranks_per_node=8, spares=0),
                  faults=(Fault("rank", 3, 5, point=point),),
                  strategies=("reinit",))
    inj = ScenarioInjector(sc)
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path),
                     strategy="reinit")
    tr = Trainer(model, data, opt, tc, injector=inj)
    res = tr.run()
    assert res["final_step"] == STEPS
    assert len(res["reports"]) == 1
    assert res["reports"][0].rollback_step == 5 + expect_offset
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


def test_trainer_cascade_during_recovery(tmp_path, reference):
    """ROADMAP satellite: cascade points flow through the in-process
    trainer — a second failure during the first recovery triggers a
    nested recovery over the same frames; both land on the same cut and
    the continuation stays bit-identical."""
    from repro.core import ScenarioInjector
    from repro.scenarios import Fault, Scenario, Topology
    ref_digest, _ = reference
    sc = Scenario(name="trainer-cascade", steps=STEPS,
                  topology=Topology(nodes=1, ranks_per_node=8, spares=0),
                  faults=(Fault("rank", 3, 4),
                          Fault("rank", 3, None,
                                point="worker.recovery.pulled")),
                  strategies=("reinit",))
    inj = ScenarioInjector(sc)
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path),
                     strategy="reinit")
    tr = Trainer(model, data, opt, tc, injector=inj)
    res = tr.run()
    assert res["final_step"] == STEPS
    assert len(res["reports"]) == 2           # primary + merged cascade
    assert [r.rollback_step for r in res["reports"]] == [4, 4]
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


def test_ulfm_charges_heartbeat_overhead(tmp_path):
    _, res_u = _run(tmp_path, "ulfm", tag="u")
    model = Model(CFG)
    assert all(l > 0 for l in
               [lg.heartbeat_overhead for lg in []] or [1])  # smoke
    tr_u, _ = _run(tmp_path, "ulfm", tag="u2")
    assert tr_u.logs[0].heartbeat_overhead > 0
    tr_r, _ = _run(tmp_path, "reinit", tag="r2")
    assert tr_r.logs[0].heartbeat_overhead == 0


def test_straggler_tracker_flags_outlier():
    from repro.train.straggler import StragglerTracker
    t = StragglerTracker(window=20, min_samples=5, threshold_mads=4.0)
    for i in range(10):
        assert not t.observe(i, 0.10 + 0.001 * (i % 3))
    assert t.observe(10, 0.50)
    assert t.flagged and t.flagged[0][0] == 10
    # a small wobble is not flagged
    assert not t.observe(11, 0.12)


def _gray_scenario(mitigate, faults, steps=STEPS):
    from repro.scenarios import Scenario, Topology
    return Scenario(
        name="trainer-gray", steps=steps,
        topology=Topology(nodes=2, ranks_per_node=4, spares=0),
        faults=faults, mitigate=mitigate,
        strategies=("shrink",), expect_bit_identical=not mitigate)


def test_gray_tolerate_matches_fault_free(tmp_path, reference):
    """mitigate=off: a x6 slow rank degrades throughput but nothing
    dies — zero recovery reports, per-rank attribution blames only the
    victim, and the run finishes bit-identical to fault-free."""
    from repro.core import ScenarioInjector
    from repro.scenarios import Fault
    ref_digest, _ = reference
    inj = ScenarioInjector(_gray_scenario(
        False, (Fault("rank", 1, 4, how="slow", factor=6.0),)))
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path),
                     strategy="shrink", n_nodes=2, ranks_per_node=4,
                     spare_nodes=0)
    tr = Trainer(model, data, opt, tc, injector=inj)
    res = tr.run()
    assert res["final_step"] == STEPS
    assert res["reports"] == []
    assert set(res["stragglers_by_rank"]) == {1}
    assert tr.n_ranks == 8
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest


def test_gray_drain_rehosts_bit_identically(tmp_path, reference):
    """mitigate=on: the tracker's per-rank streak flags the sustained
    slowdown, the drain path contracts the world through an ordinary
    shrink at the drain cut (before the degraded step's checkpoint
    commits), and the shrunk run still lands on the bit-identical final
    state (global batch unchanged)."""
    from repro.core import ScenarioInjector
    from repro.scenarios import Fault
    from repro.scenarios.schema import gray_drain_cut
    ref_digest, _ = reference
    f = Fault("rank", 1, 4, how="slow", factor=6.0)
    inj = ScenarioInjector(_gray_scenario(True, (f,)))
    model = Model(CFG)
    data = TokenPipeline(CFG.vocab_size, 4, 32, seed=7)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=STEPS)
    tc = TrainConfig(total_steps=STEPS, ckpt_dir=str(tmp_path),
                     strategy="shrink", n_nodes=2, ranks_per_node=4,
                     spare_nodes=0, mitigate=True)
    tr = Trainer(model, data, opt, tc, injector=inj)
    res = tr.run()
    assert res["final_step"] == STEPS
    rep = res["reports"][0]
    assert rep.rollback_step == gray_drain_cut(f)
    assert rep.world_after == 7 and tr.n_ranks == 7
    assert tr.elastic.dropped == [1]
    assert set(res["stragglers_by_rank"]) == {1}
    assert tree_digest(jax.device_get(tr.state["params"])) == ref_digest
