"""reprolint: each checker catches its seeded fixture violation at the
exact file:line, the live tree is clean under --strict, and the
baseline machinery accepts/greys findings correctly."""
import json
import os
import shutil

import pytest

import repro.analysis as analysis
from repro.analysis import (Finding, determinism, hook_points, locks,
                            protocol, registry)
from repro.analysis.__main__ import find_repo_root, main
from repro.analysis.source import SourceTree

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures",
                        "reprolint")


def fixture_tree(name):
    return SourceTree(os.path.join(FIXTURES, name))


def by_code(findings, code):
    return [f for f in findings if f.code == code]


# ------------------------------------------------------------- hook-point

def test_hookpoint_fixture_findings():
    fs = hook_points.check(fixture_tree("hookpoints"))
    typo = by_code(fs, "unknown-point")
    assert [(f.path, f.line, f.subject) for f in typo] == [
        ("repro/runtime/worker.py", 7, "worker.ckpt.midwrite")]
    drift = by_code(fs, "kwarg-drift")
    assert [(f.path, f.line, f.subject) for f in drift] == [
        ("repro/runtime/worker.py", 13, "worker.ckpt.mid_write")]
    dead = by_code(fs, "dead-point")
    assert [(f.path, f.line, f.subject) for f in dead] == [
        ("repro/scenarios/schema.py", 5, "never.fired.point")]
    unfired = by_code(fs, "unfired-point")
    assert [(f.path, f.line, f.subject) for f in unfired] == [
        ("repro/scenarios/catalog.py", 7, "ckpt.file.shard")]
    assert len(fs) == 4


def test_hookpoint_live_tree_clean():
    """Every fire() site is registered, every registered point fires,
    and every catalog cell's fault point has a live fire site — the
    satellite audit of SCENARIO/SERVE_CATALOG is this assertion."""
    assert hook_points.check(analysis.live_source_tree()) == []


# --------------------------------------------------------------- protocol

def test_protocol_fixture_findings():
    fs = protocol.check(fixture_tree("protocol"))
    orphan = by_code(fs, "orphan-tag")
    assert [(f.path, f.line, f.subject) for f in orphan] == [
        ("repro/runtime/worker.py", 7, "ORPHAN_TAG")]
    dead = by_code(fs, "dead-handler")
    assert [(f.path, f.line, f.subject) for f in dead] == [
        ("repro/runtime/root.py", 6, "NEVER_SENT")]
    assert len(fs) == 2


def test_protocol_live_tree_only_reply_tags():
    """The only undispatched tags in the live tree are the inline
    request/response replies the baseline documents."""
    fs = protocol.check(analysis.live_source_tree())
    assert sorted(f.subject for f in fs) == ["ACK", "CKPT", "HB_ACK"]
    assert all(f.code == "orphan-tag" for f in fs)


# ------------------------------------------------------------------ locks

def test_locks_fixture_findings():
    fs = locks.check(fixture_tree("locks"))
    assert [(f.path, f.line, f.subject, f.code) for f in fs] == [
        ("repro/runtime/daemon.py", 15, "workers", "unguarded-access")]


def test_locks_live_tree_clean():
    assert locks.check(analysis.live_source_tree()) == []


# ------------------------------------------------------------ determinism

def test_determinism_fixture_findings():
    fs = determinism.check(fixture_tree("determinism"))
    got = {(f.path, f.line, f.code) for f in fs}
    assert got == {
        ("repro/runtime/root.py", 12, "wall-clock"),
        ("repro/runtime/root.py", 15, "unseeded-random"),
        ("repro/runtime/root.py", 18, "set-iteration"),
    }


def test_determinism_live_tree_clean():
    assert determinism.check(analysis.live_source_tree()) == []


# --------------------------------------------------------------- registry

def test_registry_live_tree_clean():
    assert registry.check(analysis.live_source_tree()) == []


def test_registry_checker_catches_drift(monkeypatch):
    from repro.scenarios import engine
    monkeypatch.setattr(engine, "REAL_MODES",
                        {k: v for k, v in engine.REAL_MODES.items()
                         if k != "replica"})
    fs = registry.check(analysis.live_source_tree())
    assert any(f.subject == "REAL_MODES" and f.code == "strategy-drift"
               and f.path == "repro/scenarios/engine.py" and f.line > 1
               for f in fs)


# ------------------------------------------------- baseline + CLI + keys

def test_finding_key_is_line_independent():
    a = Finding("protocol", "repro/runtime/worker.py", 10,
                "orphan-tag", "ACK", "msg")
    b = Finding("protocol", "repro/runtime/worker.py", 99,
                "orphan-tag", "ACK", "other msg")
    assert a.key == b.key


def test_baseline_roundtrip_and_split(tmp_path):
    fs = protocol.check(fixture_tree("protocol"))
    path = str(tmp_path / "baseline.json")
    analysis.save_baseline(path, fs, {fs[0].key: "accepted for test"})
    baseline = analysis.load_baseline(path)
    assert set(baseline) == {f.key for f in fs}
    new, accepted, stale = analysis.split_by_baseline(fs, baseline)
    assert new == [] and len(accepted) == len(fs) and stale == []
    # a finding outside the baseline is "new"; a vanished one is stale
    extra = Finding("protocol", "x.py", 1, "orphan-tag", "ZZZ", "m")
    new, _, _ = analysis.split_by_baseline(fs + [extra], baseline)
    assert new == [extra]
    _, _, stale = analysis.split_by_baseline([], baseline)
    assert stale == sorted(baseline)


def test_cli_strict_fails_on_fixture_tree(tmp_path):
    root = tmp_path / "repo"
    shutil.copytree(os.path.join(FIXTURES, "protocol"),
                    str(root / "src"))
    rc = main(["--root", str(root), "--checker", "protocol",
               "--strict"])
    assert rc == 1
    # baselining the two findings makes strict pass
    fs = protocol.check(SourceTree(str(root / "src")))
    analysis.save_baseline(str(root / "reprolint-baseline.json"), fs)
    rc = main(["--root", str(root), "--checker", "protocol",
               "--strict"])
    assert rc == 0


def test_cli_write_baseline_keeps_reasons(tmp_path):
    root = tmp_path / "repo"
    shutil.copytree(os.path.join(FIXTURES, "protocol"),
                    str(root / "src"))
    fs = protocol.check(SourceTree(str(root / "src")))
    bpath = str(root / "reprolint-baseline.json")
    analysis.save_baseline(bpath, fs[:1], {fs[0].key: "kept reason"})
    rc = main(["--root", str(root), "--checker", "protocol",
               "--write-baseline"])
    assert rc == 0
    with open(bpath) as f:
        entries = {e["key"]: e["reason"]
                   for e in json.load(f)["entries"]}
    assert entries[fs[0].key] == "kept reason"
    assert set(entries) == {f.key for f in fs}


def test_parse_error_surfaces_as_finding(tmp_path):
    src = tmp_path / "src" / "repro"
    src.mkdir(parents=True)
    (src / "broken.py").write_text("def oops(:\n")
    fs = analysis.run(SourceTree(str(tmp_path / "src")),
                      checkers=["protocol"])
    assert [f.checker for f in fs] == ["parse"]
    assert fs[0].code == "syntax-error"


# -------------------------------------------------------------- self-run

def test_live_tree_clean_under_strict():
    """The tier-1 gate: the committed tree with the committed baseline
    passes `python -m repro.analysis --strict` — every checker, zero
    new findings."""
    root = find_repo_root()
    assert main(["--root", root, "--strict"]) == 0
