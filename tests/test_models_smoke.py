"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs forward + one train step + prefill/decode on CPU with
finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, SHAPES, get_config, reduced, \
    shape_applicable
from repro.models.model import Model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["enc_emb"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["frontend_emb"] = jax.random.normal(
            key, (B, 8, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        model.loss_fn, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), arch
    # one optimizer step changes the params
    opt = adamw_init(params)
    new_p, new_opt, om = adamw_update(params, grads, opt,
                                      AdamWConfig(lr=1e-3))
    assert float(om["grad_norm"]) > 0
    changed = any(
        not np.allclose(np.asarray(a, np.float32),
                        np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)))
    assert changed, f"{arch}: step did not update params"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_decode(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    batch.pop("labels")
    logits, state = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=S + 4))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, state2 = jax.jit(model.decode_step)(params, tok, state,
                                                 jnp.int32(S))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch
    # state structure preserved
    assert jax.tree.structure(state) == jax.tree.structure(state2)


@pytest.mark.parametrize("arch", ["qwen2-7b", "falcon-mamba-7b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must agree with the parallel forward pass."""
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0,
                              cfg.vocab_size)
    full_logits, _ = model.logits(params, {"tokens": toks})

    n_pre = 8
    logits_p, state = model.prefill(params, {"tokens": toks[:, :n_pre]},
                                    max_len=20)
    np.testing.assert_allclose(
        np.asarray(logits_p[0, -1], np.float32),
        np.asarray(full_logits[0, n_pre - 1], np.float32),
        atol=0.25, rtol=0.1)
    # step through the rest token by token
    for i in range(n_pre, 12):
        logits_d, state = model.decode_step(params, toks[:, i:i + 1],
                                            state, jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits_d[0, 0], np.float32),
            np.asarray(full_logits[0, i], np.float32),
            atol=0.25, rtol=0.1)


def test_shape_applicability_rules():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    long = SHAPES["long_500k"]
    runs = {a: shape_applicable(get_config(a), long)[0] for a in ASSIGNED}
    assert runs["falcon-mamba-7b"] and runs["zamba2-7b"]
    assert not runs["yi-34b"] and not runs["qwen3-32b"]
    assert sum(runs.values()) == 2


def test_param_counts_roughly_match_names():
    """Sanity: *-7b are ~7B total, yi-34b ~34B, olmoe ~7B total/1B active."""
    def count(a, active=False):
        return get_config(a).param_count(active_only=active) / 1e9
    assert 6.0 < count("qwen2-7b") < 9.0
    assert 30.0 < count("yi-34b") < 38.0
    assert 6.0 < count("olmoe-1b-7b") < 8.5
    assert 0.8 < count("olmoe-1b-7b", active=True) < 2.2
    assert 25.0 < count("qwen3-moe-30b-a3b") < 34.0
    assert 2.0 < count("qwen3-moe-30b-a3b", active=True) < 4.5
    assert 6.0 < count("falcon-mamba-7b") < 9.0
    assert 15.0 < count("granite-20b") < 24.0


def test_vlm_frontend_overwrites_prefix():
    cfg = reduced(get_config("llava-next-34b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 16), jnp.int32)
    fe1 = jnp.ones((1, 8, cfg.d_model), jnp.bfloat16)
    fe2 = -jnp.ones((1, 8, cfg.d_model), jnp.bfloat16)
    h1, _ = model.forward(params, {"tokens": toks, "frontend_emb": fe1})
    h2, _ = model.forward(params, {"tokens": toks, "frontend_emb": fe2})
    assert not np.allclose(np.asarray(h1, np.float32),
                           np.asarray(h2, np.float32))
