"""Optional-hypothesis shim: property tests skip instead of erroring.

Usage in a test module:

    from _hyp import HAS_HYPOTHESIS, given, settings, st

When hypothesis is installed (declared in pyproject's [test] extra) the
real decorators come through untouched. When it isn't, `st` becomes an
inert strategy stub and `@given(...)` replaces the test with a function
that calls pytest.skip() — the suite degrades to skips, collection never
dies on ModuleNotFoundError.
"""
from __future__ import annotations

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    class _StrategyStub:
        """Absorbs any strategy construction (st.integers(...).map(f),
        @st.composite, ...) without ever touching hypothesis."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
